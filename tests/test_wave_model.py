"""Occupancy-stage (Alg. 4 chip-wide) + simulator cross-validation tests.

The model-fidelity check the paper's 95%-of-autotuned claim rests on:
for every preset, the event simulator — which schedules units round-robin
over real cores and measures reuse distances, sharing nothing with
``latency.py`` but the Topology constants — must reproduce the closed-form
model's wave counts, grid-step counts, and total moved bytes EXACTLY
(float64 1-ulp bounds), on a shape grid that includes ragged and skinny
GEMMs.  Per-level byte *splits* are measured (stack-distance) vs
closed-form (reuse windows) — structurally different mechanisms — so they
are cross-checked for conservation and direction, not equality.

Tier-1 runs a reduced grid; the full grid is ``-m slow`` (nightly CI).
"""
import math

import pytest

from repro.core import (
    PRESETS,
    GemmProblem,
    TileConfig,
    candidate_tiles,
    gemm_latency,
    grid_shape,
    hbm_traffic,
    schedule_extra_classes,
    select_gemm_config,
    simulate_gemm,
    wave_model,
)

MULTI_CORE = ("gpu_mi300x_like", "gpu_h100_like")

# Ragged + skinny + square + batched: the regimes where padded-vs-real
# accounting historically diverged.
SHAPE_GRID = [(4096, 4096, 4096), (1000, 1000, 1000), (100, 300, 77),
              (8, 8192, 512), (8192, 8, 512), (640, 256, 256),
              (1024, 6144, 4096), (129, 257, 513)]

CONFIG_GRID = [TileConfig(128, 128, 64), TileConfig(64, 64, 32, group_m=4),
               TileConfig(128, 64, 64, split_k=4),
               TileConfig(128, 128, 64, schedule="stream_k"),
               TileConfig(64, 128, 32, group_m=8, schedule="stream_k")]


def assert_sim_matches_model(p: GemmProblem, t: TileConfig, hw) -> None:
    """Waves / units / steps exact; total bytes to 1-ulp accumulation."""
    from repro.core import DTYPE_BYTES
    r = simulate_gemm(p, t, hw)
    units, waves, occ = wave_model(p, t, hw)
    Tm, Tn, Tk = grid_shape(p, t)
    assert r.steps == Tm * Tn * Tk * p.batch, (hw.name, p, t)
    assert r.units == units, (hw.name, p, t, r.units, units)
    assert r.waves == waves, (hw.name, p, t, r.waves, waves)
    assert r.cores == hw.total_cores()
    base = hbm_traffic(p, t, revisit=hw.total_cores() == 1)
    extra = sum(b for b, _ in schedule_extra_classes(p, t, hw))
    # Known exact-vs-mean convention gap: the simulator fetches the (bn,)
    # bias slice at every tile flush, the model prices the row once
    # (compulsory) — re-reads are cache-resident.  (M, N)-shaped epilogue
    # operands tile exactly, so only the bias row differs.
    bias_delta = ((Tm - 1) * p.batch * p.N * DTYPE_BYTES[p.in_dtype]
                  if p.epilogue.bias else 0)
    # Second convention gap, single-core chains only: with Tn == 1 and a
    # Tk == 1 grid the B block index never changes between consecutive
    # steps, so the event simulator revisit-skips EVERY B re-fetch (one
    # fetch per batch element); the closed form prices the mean skip
    # fraction (0 ungrouped, (g-1)/g grouped).  The model is deliberately
    # a mean — the delta is closed-form too, so the check stays exact.
    revisit_delta = 0.0
    if hw.total_cores() == 1 and Tk == 1 and Tn == 1 and Tm > 1:
        g = min(t.group_m, Tm)
        b_skip = (g - 1) / g if t.group_m > 1 else 0.0
        revisit_delta = (Tm * (1.0 - b_skip) - 1.0) \
            * p.K * p.N * DTYPE_BYTES[p.in_dtype] * p.batch
    want = base + extra + bias_delta - revisit_delta
    assert math.isclose(r.hbm_bytes, want, rel_tol=1e-12), (
        hw.name, p, t, r.hbm_bytes, want)
    # per-level counters conserve the total and never go negative
    assert math.isclose(sum(r.level_bytes.values()), r.hbm_bytes,
                        rel_tol=1e-12)
    assert all(v >= 0.0 for v in r.level_bytes.values())


# ---------------------------------------------------------------------------
# Closed-form wave model unit behaviour.
# ---------------------------------------------------------------------------

def test_wave_model_single_core_is_identity():
    """TPU chains: units == waves, factor == 1.0 EXACTLY (the bit-parity
    precondition for the whole occupancy stage)."""
    p = GemmProblem(M=4096, N=4096, K=4096)
    for name in ("tpu_v5e", "tpu_v5p", "tpu_v4"):
        hw = PRESETS[name]
        assert hw.total_cores() == 1
        for t in candidate_tiles(p, hw)[:10]:
            units, waves, occ = wave_model(p, t, hw)
            assert units == waves
            assert occ == 1.0  # exact float equality, not approx


def _divisor_tn(C: int) -> int:
    """A Tn that divides the core count so tiles can equal C exactly."""
    for tn in (8, 4, 2):
        if C % tn == 0:
            return tn
    return 1


def test_wave_model_quantization_cliff():
    """tiles == k*C fills the chip (factor 1.0); one more tile starts a new
    nearly-empty wave (factor ~2 at k == 1)."""
    for name in MULTI_CORE:
        hw = PRESETS[name]
        C = hw.total_cores()
        t = TileConfig(128, 128, 64)
        Tn = _divisor_tn(C)
        N = 128 * Tn
        M_full = (C // Tn) * 128                          # tiles == C exactly
        p_full = GemmProblem(M=M_full, N=N, K=4096)
        units, waves, occ = wave_model(p_full, t, hw)
        assert units == C and waves == 1 and occ == 1.0
        p_over = GemmProblem(M=M_full + 128, N=N, K=4096)
        units2, waves2, occ2 = wave_model(p_over, t, hw)
        assert waves2 == 2
        assert occ2 > 1.9                                 # tail-wave waste
        # the model's total latency reproduces the cliff
        lat_full = gemm_latency(p_full, t, hw)
        lat_over = gemm_latency(p_over, t, hw)
        assert lat_over.total > lat_full.total * 1.5
        assert lat_full.occupancy == 1.0
        assert lat_over.occupancy < 0.6


def test_stream_k_erases_tile_granular_tail():
    """At a tile-count cliff, stream_k's k-step-granular strips keep the
    quantization factor ~1 where data_parallel pays ~2x."""
    for name in MULTI_CORE:
        hw = PRESETS[name]
        C = hw.total_cores()
        M = ((C // 8) + 1) * 128                          # one tile over
        p = GemmProblem(M=M, N=1024, K=4096)
        dp = TileConfig(128, 128, 64)
        sk = TileConfig(128, 128, 64, schedule="stream_k")
        _, _, occ_dp = wave_model(p, dp, hw)
        _, _, occ_sk = wave_model(p, sk, hw)
        assert occ_dp > 1.5
        assert occ_sk < 1.05
        assert gemm_latency(p, sk, hw).total < gemm_latency(p, dp, hw).total


def test_split_k_multiplies_units():
    """split_k multiplies data-parallel units — its restored GPU rationale —
    and pays combine traffic for it."""
    hw = PRESETS["gpu_mi300x_like"]
    p = GemmProblem(M=512, N=1024, K=8192)
    t1 = TileConfig(128, 128, 64, split_k=1)
    t4 = TileConfig(128, 128, 64, split_k=4)
    u1, _, occ1 = wave_model(p, t1, hw)
    u4, _, occ4 = wave_model(p, t4, hw)
    assert u4 == 4 * u1
    assert occ4 < occ1                                    # better occupancy
    assert schedule_extra_classes(p, t1, hw) == []
    (bytes4, window4), = schedule_extra_classes(p, t4, hw)
    Tm, Tn, _ = grid_shape(p, t4)
    assert bytes4 == 2.0 * 4 * Tm * Tn * 128 * 128 * 4    # f32 block partials
    # on the single-core TPU chain split-K stays in-kernel: no partials
    assert schedule_extra_classes(p, t4, PRESETS["tpu_v5e"]) == []


def test_tail_wave_selects_k_split_or_stream_k():
    """Acceptance: on the GPU presets the tail-wave llama3 shapes select
    split_k > 1 or stream_k — the wave model restored their rationale."""
    from benchmarks.llama3_shapes import llama3_gemms
    for name in MULTI_CORE:
        hw = PRESETS[name]
        hits = 0
        tail_shapes = 0
        for (gname, M, N, K) in llama3_gemms("8b", tokens=(1024,)):
            sel = select_gemm_config(M, N, K, hw=hw)
            c = sel.config
            # a shape is tail-wave-prone if the dp/sk1 twin underfills
            twin = TileConfig(c.bm, c.bn, c.bk, split_k=1,
                              group_m=c.group_m)
            _, _, occ_twin = wave_model(
                GemmProblem(M=M, N=N, K=K), twin, hw)
            if occ_twin > 1.1:
                tail_shapes += 1
                hits += c.split_k > 1 or c.schedule == "stream_k"
        assert tail_shapes > 0, name                      # grid has them
        assert hits == tail_shapes, (name, hits, tail_shapes)


# ---------------------------------------------------------------------------
# Simulator cross-validation (tier-1 reduced grid).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hw_name", sorted(PRESETS))
def test_simulator_matches_wave_model_tier1(hw_name):
    hw = PRESETS[hw_name]
    for (M, N, K) in SHAPE_GRID[:5]:
        p = GemmProblem(M=M, N=N, K=K)
        for t in CONFIG_GRID[:3]:
            assert_sim_matches_model(p, t, hw)


def test_simulator_reproduces_tail_wave_cliff():
    """Acceptance: the simulator independently reproduces the modeled
    tail-wave latency cliff (it schedules units over cores; it never reads
    the closed form)."""
    for name in MULTI_CORE:
        hw = PRESETS[name]
        C = hw.total_cores()
        t = TileConfig(128, 128, 64)
        N = 128 * 8
        M_full = (C // 8) * 128
        p_full = GemmProblem(M=M_full, N=N, K=2048)
        p_over = GemmProblem(M=M_full + 128, N=N, K=2048)
        r_full = simulate_gemm(p_full, t, hw)
        r_over = simulate_gemm(p_over, t, hw)
        assert r_full.waves == 1 and r_over.waves == 2
        # one extra tile, nearly double the time: the cliff
        assert r_over.time > r_full.time * 1.5, name
        # stream_k recovers it in the simulator too
        r_sk = simulate_gemm(p_over,
                             TileConfig(128, 128, 64, schedule="stream_k"),
                             hw)
        assert r_sk.time < r_over.time * 0.75, name


def test_simulator_batched_and_epilogue_cross_check():
    from repro.core import Epilogue
    p = GemmProblem(M=300, N=500, K=700, batch=3,
                    epilogue=Epilogue(bias=True, activation="gelu"))
    for name in MULTI_CORE:
        assert_sim_matches_model(p, TileConfig(64, 64, 32), PRESETS[name])
        assert_sim_matches_model(
            p, TileConfig(64, 64, 32, schedule="stream_k"), PRESETS[name])


# ---------------------------------------------------------------------------
# Nightly full grid (slow): every preset x full shape grid x full config
# grid, plus the selected config of every llama3 sweep shape.
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("hw_name", sorted(PRESETS))
def test_simulator_matches_wave_model_full(hw_name):
    hw = PRESETS[hw_name]
    for (M, N, K) in SHAPE_GRID:
        p = GemmProblem(M=M, N=N, K=K)
        for t in CONFIG_GRID:
            assert_sim_matches_model(p, t, hw)
        # and the model's own choice for the shape
        sel = select_gemm_config(M, N, K, hw=hw)
        assert_sim_matches_model(p, sel.config, hw)


@pytest.mark.slow
def test_simulator_matches_wave_model_llama3():
    from benchmarks.llama3_shapes import llama3_gemms
    for hw_name in MULTI_CORE:
        hw = PRESETS[hw_name]
        for size in ("8b", "70b"):
            for (name, M, N, K) in llama3_gemms(size):
                p = GemmProblem(M=M, N=N, K=K)
                sel = select_gemm_config(M, N, K, hw=hw)
                assert_sim_matches_model(p, sel.config, hw)
