"""Model-priced shape bucketing (core/bucketing.py): the planner must beat
the pow2 baseline on its own objective across presets, land edges off
power-of-two positions when tail-wave cliffs make that cheaper (the whole
point of pricing edges with the wave model), respect the bucket budget,
and validate its inputs."""
import numpy as np
import pytest

from repro.core import get_hardware
from repro.core.bucketing import (BucketPlan, plan_buckets, pow2_plan,
                                  step_gemms)

GEMMS = step_gemms(4096, 14336, kv_dim=1024, vocab=None, swiglu=True)


def _sizes(n=40, lo=64, hi=900, seed=0):
    return np.random.default_rng(seed).integers(lo, hi + 1, size=n).tolist()


@pytest.mark.parametrize("hw_name", ["tpu_v5e", "gpu_h100_like",
                                     "gpu_mi300x_like"])
def test_beats_pow2_on_modeled_latency(hw_name):
    hw = get_hardware(hw_name)
    sizes = _sizes()
    priced = plan_buckets(sizes, gemms=GEMMS, hw=hw, max_buckets=8)
    pow2 = pow2_plan(sizes, gemms=GEMMS, hw=hw)
    assert priced.modeled_total_s < pow2.modeled_total_s, (
        priced.edges, pow2.edges)


def test_edges_land_off_pow2_on_multicore():
    """On a multi-core preset the per-step cost is non-monotone in M
    (tail-wave cliffs), so the suffix-argmin pulls edges onto wave
    boundaries — at least one chosen edge is not a power of two."""
    hw = get_hardware("gpu_h100_like")
    priced = plan_buckets(_sizes(), gemms=GEMMS, hw=hw, max_buckets=8)
    assert any(e & (e - 1) for e in priced.edges), priced.edges


def test_edge_cost_no_worse_than_minimal_cover():
    """Every chosen edge must price no worse than the minimal covering
    candidate for the sizes it serves — padding PAST a cliff is only done
    when the model says it is cheaper."""
    hw = get_hardware("gpu_mi300x_like")
    sizes = _sizes(seed=3)
    priced = plan_buckets(sizes, gemms=GEMMS, hw=hw, max_buckets=6)
    for s in sizes:
        e = priced.bucket_for(s)
        assert e >= s
    # The plan's own receipts are consistent.
    assert set(priced.edge_step_s) == set(priced.edges)
    assert all(v > 0 for v in priced.edge_step_s.values())


def test_max_buckets_respected_and_weights():
    hw = get_hardware("tpu_v5e")
    sizes = _sizes(n=30)
    for k in (1, 2, 4):
        plan = plan_buckets(sizes, gemms=GEMMS, hw=hw, max_buckets=k)
        assert 1 <= len(plan.edges) <= k
        assert plan.bucket_for(min(sizes)) >= min(sizes)
    # Heavier weight on small sizes pulls the plan's mean request cost down
    # or keeps it equal — never up.
    w_small = [1e3 if s <= 256 else 1.0 for s in sizes]
    p_uni = plan_buckets(sizes, gemms=GEMMS, hw=hw, max_buckets=4)
    p_sm = plan_buckets(sizes, w_small, gemms=GEMMS, hw=hw, max_buckets=4)
    assert p_sm.modeled_request_s <= p_uni.modeled_request_s * 1.0001


def test_bucket_for_raises_beyond_largest_edge():
    hw = get_hardware("tpu_v5e")
    plan = plan_buckets([64, 128], gemms=GEMMS, hw=hw)
    with pytest.raises(ValueError, match="exceeds largest bucket edge"):
        plan.bucket_for(max(plan.edges) + 1)


def test_input_validation():
    hw = get_hardware("tpu_v5e")
    with pytest.raises(ValueError, match="at least one"):
        plan_buckets([], gemms=GEMMS, hw=hw)
    with pytest.raises(ValueError, match="weights"):
        plan_buckets([64, 128], [1.0], gemms=GEMMS, hw=hw)
    with pytest.raises(ValueError, match="negative weight"):
        plan_buckets([64], [-1.0], gemms=GEMMS, hw=hw)
    with pytest.raises(ValueError, match="size 0"):
        plan_buckets([0], gemms=GEMMS, hw=hw)
    with pytest.raises(ValueError, match="max_buckets"):
        plan_buckets([64], gemms=GEMMS, hw=hw, max_buckets=0)
    with pytest.raises(ValueError, match="granularity"):
        plan_buckets([64], gemms=GEMMS, hw=hw, granularity=0)


def test_step_gemms_shapes():
    g = step_gemms(1024, 4096, kv_dim=256, vocab=32000, swiglu=True)
    assert g[0] == (1024 + 512, 1024)          # fused QKV
    assert g[1] == (1024, 1024)                # attention out
    assert g[2] == (8192, 1024)                # gated up
    assert g[3] == (1024, 4096)                # down
    assert g[4] == (32000, 1024)               # LM head
    assert step_gemms(1024, 4096, swiglu=False)[2] == (4096, 1024)


def test_plan_is_deterministic():
    hw = get_hardware("tpu_v5e")
    sizes = _sizes(n=20, seed=7)
    a = plan_buckets(sizes, gemms=GEMMS, hw=hw)
    b = plan_buckets(sizes, gemms=GEMMS, hw=hw)
    assert a.edges == b.edges
    assert a.modeled_total_s == b.modeled_total_s
    assert isinstance(a, BucketPlan) and a.policy == "model_priced"
