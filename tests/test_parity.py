"""Three-way scoring parity suite (satellite of the occupancy-stage PR).

The latency model exists in three hand-synced copies — the scalar
``score_candidate``, the vectorized ``score_candidates`` /
``score_candidate_arrays``, and the static-term-cached
``selector.select_fast`` — and every model change (the PR 2 cache
recurrence, this PR's wave/occupancy stage and stream-K pricing) must land
in all three.  This suite pins the contract exhaustively instead of
spot-checking: identical candidate enumeration, identical latency arrays,
identical argmin, across random problems x ALL presets x dtypes x
epilogues.

Tier-1 runs a reduced grid; the full property grid is ``-m slow``
(nightly CI).
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # CPU container: shim
    from _hypothesis_compat import given, settings, st

from repro.core import (
    PRESETS,
    SCHEDULES,
    Epilogue,
    GemmProblem,
    argmin_candidate,
    candidate_arrays,
    candidate_tiles,
    gemm_latency,
    score_candidate,
    score_candidates,
)
from repro.core.selector import select_fast

DIMS = st.integers(min_value=1, max_value=8192)
DTYPES = ("bfloat16", "float32", "int8")
EPILOGUES = (Epilogue(), Epilogue(bias=True, activation="gelu"),
             Epilogue(activation="swiglu_gate", residual=True))


def _sequential_argmin(p, cands, hw, scores):
    """The seed's sequential scoring loop: the reference argmin/tie-break
    policy all vectorized paths must reproduce."""
    best, best_score = None, None
    for t, s in zip(cands, scores):
        if best_score is None or s < best_score - 1e-15 or (
                abs(s - best_score) <= 1e-15
                and (t.bm * t.bn * t.bk) > (best.bm * best.bn * best.bk)):
            best, best_score = t, s
    return best


def assert_three_way_parity(p: GemmProblem, hw) -> None:
    """The whole contract for one (problem, preset):

    1. vectorized enumeration == scalar enumeration (order included);
    2. scalar fast path == full model, vectorized batch == full model;
    3. select_fast argmin == vectorized argmin == sequential-loop argmin.
    """
    cands = candidate_tiles(p, hw)
    assert cands, (hw.name, p)
    bm, bn, bk, sk, gm, sched = candidate_arrays(p, hw)
    assert len(bm) == len(cands), (hw.name, p)
    for i, t in enumerate(cands):
        assert (t.bm, t.bn, t.bk, t.split_k, t.group_m, t.schedule) == (
            int(bm[i]), int(bn[i]), int(bk[i]), int(sk[i]), int(gm[i]),
            SCHEDULES[int(sched[i])]), (hw.name, p, i)

    vec = score_candidates(p, cands, hw)
    scal = np.array([score_candidate(p, t, hw) for t in cands])
    assert np.allclose(vec, scal, rtol=1e-9), (hw.name, p)
    # both against the full-breakdown model on a stride of the space
    for t, v in list(zip(cands, vec))[::7]:
        full = gemm_latency(p, t, hw).total
        assert math.isclose(score_candidate(p, t, hw), full,
                            rel_tol=1e-12), (hw.name, p, t)
        assert math.isclose(float(v), full, rel_tol=1e-9), (hw.name, p, t)

    best_fast, n = select_fast(p, hw)
    assert n == len(cands), (hw.name, p)
    best_vec = argmin_candidate(p, cands, hw)
    best_seq = _sequential_argmin(p, cands, hw, scal)
    assert best_fast == best_vec == best_seq, (
        hw.name, p, best_fast, best_vec, best_seq)


# ---------------------------------------------------------------------------
# Tier-1: reduced grid — every preset, two dtypes, problem shapes chosen to
# hit the regimes that have historically diverged (ragged, skinny, square,
# tail-wave, batched).
# ---------------------------------------------------------------------------

TIER1_SHAPES = [(4096, 4096, 4096), (100, 300, 77), (8, 8192, 8192),
                (1024, 6144, 4096), (640, 256, 256), (13, 77, 100)]


@pytest.mark.parametrize("hw_name", sorted(PRESETS))
def test_three_way_parity_tier1(hw_name):
    hw = PRESETS[hw_name]
    for (M, N, K) in TIER1_SHAPES:
        for dt in ("bfloat16", "float32"):
            assert_three_way_parity(
                GemmProblem(M=M, N=N, K=K, in_dtype=dt), hw)


def test_three_way_parity_epilogue_and_batch():
    for hw_name in ("tpu_v5e", "gpu_mi300x_like"):
        hw = PRESETS[hw_name]
        for ep in EPILOGUES:
            assert_three_way_parity(
                GemmProblem(M=1024, N=4096, K=4096, epilogue=ep), hw)
        assert_three_way_parity(
            GemmProblem(M=512, N=1024, K=2048, batch=4), hw)


@settings(max_examples=10, deadline=None)
@given(M=DIMS, N=DIMS, K=DIMS)
def test_three_way_parity_property_small(M, N, K):
    """Property slice kept in tier-1: random shapes on the 1-level TPU chain
    and one multi-core multi-level chain."""
    for hw_name in ("tpu_v5e", "gpu_h100_like"):
        assert_three_way_parity(GemmProblem(M=M, N=N, K=K),
                                PRESETS[hw_name])


# ---------------------------------------------------------------------------
# Nightly: the full grid — random problems x all presets x all dtypes x
# epilogues (marked slow; `pytest -q -m slow`).
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(M=DIMS, N=DIMS, K=DIMS)
def test_three_way_parity_full_grid(M, N, K):
    for hw in PRESETS.values():
        for dt in DTYPES:
            assert_three_way_parity(
                GemmProblem(M=M, N=N, K=K, in_dtype=dt), hw)


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(M=DIMS, N=DIMS, K=DIMS, batch=st.integers(min_value=1, max_value=8))
def test_three_way_parity_full_epilogue_batch(M, N, K, batch):
    for hw in PRESETS.values():
        for ep in EPILOGUES:
            assert_three_way_parity(
                GemmProblem(M=M, N=N, K=K, batch=batch, epilogue=ep), hw)
