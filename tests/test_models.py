"""Per-arch smoke tests: reduced config of the same family, one forward /
train / decode step on CPU, asserting shapes and finiteness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, all_cells, get_config
from repro.nn import Model, SHAPES, shape_applicable
from repro.nn.frontends import synth_frontend_inputs

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        m = Model(cfg)
        out[arch] = (m, m.init(RNG))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_finite(built, arch):
    model, params = built[arch]
    cfg = model.cfg
    B, S = 2, 32
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    extras = synth_frontend_inputs(cfg, RNG, B, S)
    loss = model.loss(params, {"tokens": tokens, **extras})
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_improves(built, arch):
    from repro.launch.steps import TrainState, make_train_step
    from repro.optim import AdamW
    model, params = built[arch]
    cfg = model.cfg
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    step = jax.jit(make_train_step(model, opt))
    B, S = 2, 32
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    extras = synth_frontend_inputs(cfg, RNG, B, S)
    batch = {"tokens": tokens, **extras}
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(metrics["grad_norm"])
    # same batch repeated: the optimizer must make progress
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_shapes(built, arch):
    model, params = built[arch]
    cfg = model.cfg
    B = 2
    cache = model.init_cache(B, 16)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache = model.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits, cache = model.decode_step(params, cache,
                                      jnp.argmax(logits, -1).astype(jnp.int32),
                                      jnp.int32(1))
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "mamba2-370m",
                                  "zamba2-7b", "mixtral-8x22b"])
def test_prefill_matches_stepwise_decode(built, arch):
    """Prefill cache + logits == token-by-token decode (the serving
    consistency invariant), for one arch per family."""
    model, params = built[arch]
    cfg = model.cfg
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                cfg.vocab_size)
    logits_pre, _ = model.prefill(params, tokens)
    cache = model.init_cache(B, S + 1)
    for i in range(S):
        logits, cache = model.decode_step(params, cache, tokens[:, i],
                                          jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(logits),
                               rtol=5e-2, atol=5e-1)


def test_ssd_chunked_equals_naive_recurrence():
    """SSD chunked algorithm vs the literal per-step SSM recurrence."""
    from repro.nn.mamba2 import ssd_chunked
    rng = np.random.default_rng(0)
    B, S, nh, hd, ns = 1, 32, 2, 8, 4
    x = jnp.asarray(rng.standard_normal((B, S, nh, hd)), dtype=jnp.float32)
    dA = -jnp.asarray(rng.random((B, S, nh)), dtype=jnp.float32) * 0.5
    Bm = jnp.asarray(rng.standard_normal((B, S, ns)), dtype=jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, ns)), dtype=jnp.float32)
    y, final = ssd_chunked(x, dA, Bm, Cm, chunk=8)

    h = np.zeros((B, nh, hd, ns), np.float32)
    ys = []
    for t in range(S):
        decay = np.exp(np.asarray(dA[:, t]))                  # (B, nh)
        h = h * decay[..., None, None] + np.einsum(
            "bhp,bn->bhpn", np.asarray(x[:, t]), np.asarray(Bm[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(Cm[:, t])))
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), h, rtol=2e-4, atol=2e-4)


def test_cell_applicability_table():
    cells = all_cells(include_skipped=True)
    assert len(cells) == 40                       # 10 archs x 4 shapes
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 32                    # 8 long_500k skips
    assert all(s == "long_500k" for _, s, ok, _ in skipped)
    assert {a for a, *_ in skipped} == {
        "musicgen-large", "phi4-mini-3.8b", "minitron-8b", "stablelm-12b",
        "internlm2-20b", "llava-next-mistral-7b", "mixtral-8x22b",
        "qwen3-moe-30b-a3b"}


def test_param_counts_plausible():
    expect = {
        "phi4-mini-3.8b": (3.5e9, 4.3e9),
        "minitron-8b": (8e9, 11e9),
        "stablelm-12b": (11e9, 13e9),
        "internlm2-20b": (18e9, 21e9),
        "llava-next-mistral-7b": (6.9e9, 7.6e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "mixtral-8x22b": (135e9, 145e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "zamba2-7b": (6e9, 8e9),
        "musicgen-large": (2e9, 3.4e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_microbatched_train_step_equivalent(built):
    """Gradient accumulation (mb=4) must match the single-shot step: same
    loss, same updated params (linearity of grads; f32 accumulate)."""
    from repro.launch.steps import TrainState, make_train_step
    from repro.optim import AdamW
    model, params = built["phi4-mini-3.8b"]
    cfg = model.cfg
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    B, S = 4, 32
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    s0 = TrainState(params=params, opt=opt.init(params),
                    step=jnp.zeros((), jnp.int32))
    s1, m1 = jax.jit(make_train_step(model, opt))(s0, batch)
    s4, m4 = jax.jit(make_train_step(model, opt, microbatches=4))(s0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-3)
    w1 = np.asarray(s1.params["layers"]["mlp"]["wg"], np.float32)
    w4 = np.asarray(s4.params["layers"]["mlp"]["wg"], np.float32)
    np.testing.assert_allclose(w1, w4, rtol=2e-2, atol=2e-3)


def test_sp_stash_flag_numerically_neutral(built):
    """sp_stash only adds sharding constraints — on a single device the
    forward must be bit-identical."""
    import dataclasses
    model, params = built["phi4-mini-3.8b"]
    cfg2 = dataclasses.replace(model.cfg, sp_stash=True)
    from repro.nn import Model
    m2 = Model(cfg2)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0,
                                model.cfg.vocab_size)
    a = np.asarray(model.loss(params, {"tokens": tokens}))
    b = np.asarray(m2.loss(params, {"tokens": tokens}))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_moe_dispatch_conservation():
    """Sort-based dispatch: with ample capacity every token's output is a
    convex combination of its top-k experts (gates sum to 1)."""
    from repro.nn.moe import moe_forward
    from repro.nn.layers import init_tree
    from repro.nn.moe import moe_defs
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    p = init_tree(jax.random.PRNGKey(0), moe_defs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y, aux = moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 1.0 - 1e-3   # aux loss >= 1 by Cauchy-Schwarz
