"""Topology refactor tests: 1-level parity with the seed/PR-1 model,
per-level capacity filters, preset round-trips, hierarchy-priced selection,
and the persistent selection table."""
import dataclasses
import json
import math
import os

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # CPU container: shim
    from _hypothesis_compat import given, settings, st

from repro.core import (
    GPU_H100_LIKE,
    GPU_MI300X_LIKE,
    PRESETS,
    TPU_V5E,
    GemmProblem,
    MemoryLevel,
    TileConfig,
    Topology,
    calibrate,
    candidate_tiles,
    clear_selection_cache,
    fits_placement,
    gemm_latency,
    hbm_traffic,
    level_traffic,
    score_candidate,
    score_candidates,
    select_gemm_config,
    simulate_gemm,
    staging_working_set,
)
from repro.core.selector import (
    argmin_candidate,
    candidate_arrays,
    load_selection_cache,
    select_fast,
    unload_selection_cache,
)

MULTI_LEVEL = (GPU_MI300X_LIKE, GPU_H100_LIKE)

# The PR 1 bit-for-bit golden table that used to live here moved to
# tests/goldens/llama3_selections.json (tpu_v5e section, verified identical
# at migration) — tests/test_golden_selections.py diffs the full sweep for
# EVERY preset and prints a readable table on mismatch.
DIMS = st.integers(min_value=1, max_value=8192)


def test_tpu_chain_is_one_level():
    for name in ("tpu_v5e", "tpu_v5p", "tpu_v4"):
        hw = PRESETS[name]
        assert hw.cache_levels == ()
        assert hw.backing.name == "hbm" and hw.staging.name == "vmem"
        assert hw.staging.holds_accumulator


@settings(max_examples=30, deadline=None)
@given(M=DIMS, N=DIMS, K=DIMS)
def test_per_level_capacity_filter_property(M, N, K):
    """Every enumerated candidate fits the budget of every placement level,
    on every preset (the generalized VMEM/LDS filter)."""
    p = GemmProblem(M=M, N=N, K=K)
    for hw in PRESETS.values():
        cands = candidate_tiles(p, hw)
        assert cands, (hw.name, M, N, K)
        for t in cands[:25]:
            assert fits_placement(t, p.in_dtype, hw)
            ws = staging_working_set(t, p.in_dtype, hw)
            for lvl in hw.placement_levels():
                assert ws <= lvl.budget(), (hw.name, t, lvl.name)


def test_gpu_staging_excludes_accumulator():
    """GPU-shaped staging (LDS/SMEM) holds only the pipelined input blocks;
    TPU VMEM also hosts the f32 accumulator."""
    t = TileConfig(bm=128, bn=128, bk=64)
    gpu = staging_working_set(t, "bfloat16", GPU_H100_LIKE)
    tpu = staging_working_set(t, "bfloat16", TPU_V5E)
    assert tpu - gpu == 128 * 128 * 4


@settings(max_examples=25, deadline=None)
@given(M=DIMS, N=DIMS, K=DIMS)
def test_level_traffic_conservation(M, N, K):
    """Per-level served bytes sum to the all-HBM base plus the schedule's
    partial/fixup traffic: caches redirect traffic, they never create or
    destroy it.  On 1-level chains the single entry IS the base."""
    from repro.core import schedule_extra_classes
    p = GemmProblem(M=M, N=N, K=K)
    flat = level_traffic(p, TileConfig(bm=128, bn=128, bk=128), TPU_V5E)
    assert flat == {"hbm": hbm_traffic(
        p, TileConfig(bm=128, bn=128, bk=128))}
    for hw in MULTI_LEVEL:
        revisit = hw.total_cores() == 1
        for t in candidate_tiles(p, hw)[:12]:
            served = level_traffic(p, t, hw)
            base = hbm_traffic(p, t, revisit=revisit)
            extra = sum(b for b, _ in schedule_extra_classes(p, t, hw))
            assert math.isclose(sum(served.values()), base + extra,
                                rel_tol=1e-9)
            assert served[hw.backing.name] >= 0.0
            # backing serves at least the compulsory traffic
            assert served[hw.backing.name] >= p.min_bytes * 0.999


@settings(max_examples=12, deadline=None)
@given(M=DIMS, N=DIMS, K=DIMS)
def test_multi_level_scoring_parity(M, N, K):
    """Scalar fast path == full model and == vectorized batch scorer on the
    multi-level presets (the three hand-synced copies stay in lockstep)."""
    import numpy as np
    p = GemmProblem(M=M, N=N, K=K)
    for hw in MULTI_LEVEL:
        cands = candidate_tiles(p, hw)[:40]
        vec = score_candidates(p, cands, hw)
        for t, v in zip(cands, vec):
            full = gemm_latency(p, t, hw).total
            assert math.isclose(score_candidate(p, t, hw), full,
                                rel_tol=1e-12)
            assert math.isclose(v, full, rel_tol=1e-9), (hw.name, t)


def test_scoring_parity_group_clamped_to_single_row():
    """group_m > 1 with Tm == 1 clamps to ungrouped in BOTH the scalar and
    vectorized spill recurrences (regression: the vectorized path once
    branched on raw gm and billed phantom cache hits)."""
    p = GemmProblem(M=128, N=8192, K=8192)
    t = TileConfig(bm=128, bn=128, bk=128, group_m=8)   # Tm == 1
    for hw in MULTI_LEVEL:
        full = gemm_latency(p, t, hw).total
        assert math.isclose(score_candidate(p, t, hw), full, rel_tol=1e-12)
        assert math.isclose(float(score_candidates(p, [t], hw)[0]), full,
                            rel_tol=1e-9), hw.name


def test_calibrated_same_name_topology_gets_fresh_filter():
    """with_calibration keeps the preset name; the cached menu grid must
    not serve the old capacity filter (regression: name-only cache key)."""
    shrunk = TPU_V5E.with_calibration(vmem_bytes=2 * 1024**2)
    p = GemmProblem(M=4096, N=4096, K=4096)
    budget = shrunk.vmem_budget()
    assert select_gemm_config(4096, 4096, 4096, hw=TPU_V5E).config  # warm
    clear_selection_cache()
    s = select_gemm_config(4096, 4096, 4096, hw=shrunk)
    assert staging_working_set(s.config, p.in_dtype, shrunk) <= budget
    for t in candidate_tiles(p, shrunk):
        assert staging_working_set(t, p.in_dtype, shrunk) <= budget


def test_select_fast_parity_on_multi_level():
    """The cached-menu-grid fast selector agrees with the explicit
    enumeration + vectorized argmin on multi-level presets too."""
    shapes = [(4096, 4096, 4096), (100, 300, 77), (8, 8192, 8192),
              (640, 256, 256), (1024, 6144, 4096)]
    from repro.core import SCHEDULES
    for hw in MULTI_LEVEL:
        for (M, N, K) in shapes:
            p = GemmProblem(M=M, N=N, K=K)
            tiles = candidate_tiles(p, hw)
            bm, bn, bk, sk, gm, sched = candidate_arrays(p, hw)
            assert len(bm) == len(tiles)
            for i, t in enumerate(tiles):
                assert (t.bm, t.bn, t.bk, t.split_k, t.group_m,
                        t.schedule) == \
                    (int(bm[i]), int(bn[i]), int(bk[i]),
                     int(sk[i]), int(gm[i]), SCHEDULES[int(sched[i])])
            best, n = select_fast(p, hw)
            assert n == len(tiles)
            assert best == argmin_candidate(p, tiles, hw), (hw.name, M, N, K)


def test_hierarchy_changes_selection_on_llama3_shapes():
    """Acceptance: on a multi-level preset at least one llama3 sweep shape
    selects a different group_m / tiling BECAUSE OF the cache terms — the
    cache-stripped ablation (same constants, (backing, staging) only)
    chooses differently."""
    from benchmarks.hierarchy_sweep import strip_caches
    from benchmarks.llama3_shapes import llama3_gemms
    for full in MULTI_LEVEL:
        flat = strip_caches(full)
        flips = gm_flips = 0
        for size in ("8b", "70b"):
            for (_, M, N, K) in llama3_gemms(size):
                a = select_gemm_config(M, N, K, hw=full).config
                b = select_gemm_config(M, N, K, hw=flat).config
                flips += a != b
                gm_flips += a.group_m != b.group_m
        assert flips >= 1, full.name
        assert gm_flips >= 1, full.name


def test_grouped_swizzle_priced_not_gated():
    """On multi-level chains group_m > 1 stays in the candidate space for
    Tk > 1 (priced by L2 residency); on the TPU 1-level chain it is pruned
    unless the revisit model can trigger (Tk == 1)."""
    from repro.core import grid_shape
    p = GemmProblem(M=4096, N=4096, K=8192)
    for t in candidate_tiles(p, TPU_V5E):
        if t.group_m > 1:
            assert grid_shape(p, t)[2] == 1           # revisit-gated
    for hw in MULTI_LEVEL:
        assert any(t.group_m > 1 and grid_shape(p, t)[2] > 1
                   for t in candidate_tiles(p, hw)), hw.name


def test_bottleneck_can_be_cache_level():
    """A multi-level breakdown reports per-level bytes/seconds and may
    bottleneck on a cache port."""
    from repro.core import schedule_extra_classes
    p = GemmProblem(M=8192, N=8192, K=28672)
    s = select_gemm_config(8192, 8192, 28672, hw=GPU_MI300X_LIKE)
    b = s.predicted
    assert set(b.level_bytes) == {"hbm", "mall", "l2"}
    assert set(b.level_seconds) == {"hbm", "mall", "l2"}
    base = hbm_traffic(p, s.config, revisit=False)    # multi-core chain
    extra = sum(
        x for x, _ in schedule_extra_classes(p, s.config, GPU_MI300X_LIKE))
    assert math.isclose(sum(b.level_bytes.values()), base + extra,
                        rel_tol=1e-9)
    assert b.hbm_traffic == b.level_bytes["hbm"]
    assert b.hbm_traffic < base                       # caches absorbed some


def test_simulator_level_counters():
    """The event simulator's measured reuse-distance counters split bytes
    across levels; on 1-level chains all fetch+write bytes are HBM."""
    p = GemmProblem(M=2048, N=2048, K=2048)
    t = TileConfig(bm=256, bn=256, bk=256)
    r = simulate_gemm(p, t, TPU_V5E)
    assert set(r.level_bytes) == {"hbm"}
    assert r.level_bytes["hbm"] == r.hbm_bytes
    tg = TileConfig(bm=128, bn=128, bk=64, group_m=4)
    rg = simulate_gemm(p, tg, GPU_H100_LIKE)
    assert set(rg.level_bytes) == {"hbm", "l2"}
    assert math.isclose(sum(rg.level_bytes.values()), rg.hbm_bytes,
                        rel_tol=1e-9)
    assert rg.level_bytes["l2"] > 0.0                 # reuse hits measured
    assert rg.level_bytes["hbm"] >= p.min_bytes * 0.999


# ---------------------------------------------------------------------------
# Preset serialization round-trip.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PRESETS))
def test_preset_serialization_round_trip(name):
    hw = PRESETS[name]
    assert Topology.from_dict(hw.to_dict()) == hw
    assert Topology.from_json(hw.to_json()) == hw
    # JSON text itself is stable/parseable
    d = json.loads(hw.to_json())
    assert d["name"] == name
    assert [lv["name"] for lv in d["levels"]] == [l.name for l in hw.levels]


def test_with_calibration_legacy_aliases():
    hw = TPU_V5E.with_calibration(hbm_bandwidth=1e12, vmem_bytes=2**20)
    assert hw.hbm_bandwidth == 1e12
    assert hw.vmem_bytes == 2**20
    assert hw.levels[0].bandwidth == 1e12
    assert TPU_V5E.hbm_bandwidth == 819e9             # original untouched


# ---------------------------------------------------------------------------
# Satellite: flops() unknown-dtype KeyError + calibrate() error path.
# ---------------------------------------------------------------------------

def test_flops_unknown_dtype_raises():
    with pytest.raises(KeyError) as e:
        TPU_V5E.flops("float64")
    msg = str(e.value)
    assert "float64" in msg and "bfloat16" in msg    # lists known dtypes
    assert TPU_V5E.flops("bfloat16") == 197e12


def test_calibrate_unknown_field_raises():
    with pytest.raises(KeyError) as e:
        calibrate(TPU_V5E, {"warp_speed": lambda: 1.0})
    assert "warp_speed" in str(e.value)
    assert "hbm_bandwidth" in str(e.value)           # lists calibratables
    hw = calibrate(TPU_V5E, {"hbm_bandwidth": lambda: 900e9})
    assert hw.hbm_bandwidth == 900e9


def test_memory_level_validation():
    with pytest.raises(ValueError):
        MemoryLevel(name="x", capacity=1, bandwidth=1.0, scope="galaxy")
    with pytest.raises(ValueError):
        MemoryLevel(name="x", capacity=0, bandwidth=1.0)
    with pytest.raises(ValueError):
        dataclasses.replace(TPU_V5E, levels=(TPU_V5E.levels[0],))
    with pytest.raises(ValueError):
        dataclasses.replace(TPU_V5E, bm_menu=(8, 24))  # not a power of two


# ---------------------------------------------------------------------------
# Satellite: persistent on-disk selection table.
# ---------------------------------------------------------------------------

def test_disk_selection_cache_warm_start(tmp_path, monkeypatch):
    import repro.core.selector as selmod
    path = str(tmp_path / "selections.json")
    monkeypatch.setenv("REPRO_SELECTION_CACHE", path)
    load_selection_cache(path)                        # activate (empty)
    clear_selection_cache()
    s1 = select_gemm_config(1536, 1536, 1536)
    assert os.path.exists(path)                       # write-through
    table = json.load(open(path))
    assert len(table) == 1

    # New "process": fresh in-memory caches, table re-read from disk; the
    # cold scoring path must never run (zero cold-path scoring).
    clear_selection_cache()
    assert load_selection_cache(path) == 1

    def boom(*a, **kw):
        raise AssertionError("cold scoring ran despite warm table")
    monkeypatch.setattr(selmod, "select_fast", boom)
    s2 = select_gemm_config(1536, 1536, 1536)
    assert s2.config == s1.config
    assert s2.n_candidates == s1.n_candidates
    assert s2.predicted.total == s1.predicted.total   # repriced identically

    # A corrupt/stale entry must fall back to cold scoring, not crash or
    # return an illegal config.
    monkeypatch.setattr(selmod, "select_fast",
                        lambda *a, **kw: (s1.config, s1.n_candidates))
    table = json.load(open(path))
    k = next(iter(table))
    table[k] = {"config": {"bm": 1 << 20, "bn": 1 << 20, "bk": 1 << 20,
                           "split_k": 1, "group_m": 1},
                "n_candidates": 1}
    json.dump(table, open(path, "w"))
    clear_selection_cache()
    assert load_selection_cache(path) == 1
    s3 = select_gemm_config(1536, 1536, 1536)         # oversized -> cold
    assert s3.config == s1.config

    # deactivate persistence for the rest of the suite
    monkeypatch.delenv("REPRO_SELECTION_CACHE")
    unload_selection_cache()
    clear_selection_cache()
