"""Golden-selection regression (satellite of the occupancy-stage PR).

``tests/goldens/llama3_selections.json`` pins the FULL llama3-sweep
selection — config 6-tuple, candidate count, and the exact float64
predicted latency (hex, bit-for-bit) — for every preset.  This replaces
the ad-hoc PR1_GOLDEN table that lived in ``tests/test_topology.py``:

* the ``tpu_v5e`` section IS that table (verified identical when this
  file was generated) — single-core chains must reproduce the PR 1/2
  model bit-for-bit through every refactor;
* the GPU sections pin the occupancy-aware behaviour: stream-K / split-K
  on tail-wave shapes, cache-priced group_m.

On mismatch the test prints a human-readable diff table and writes it to
``experiments/golden_diff.txt`` (uploaded as a CI artifact by the nightly
job).  Regenerate deliberately with
``PYTHONPATH=src python tools/regen_goldens.py`` and review the diff.
"""
import json
import os

from benchmarks.llama3_shapes import llama3_gemms
from repro.core import PRESETS, select_gemm_config

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "llama3_selections.json")
DIFF_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                         "experiments", "golden_diff.txt")


def _current_entry(M, N, K, hw):
    s = select_gemm_config(M, N, K, hw=hw)
    c = s.config
    return {
        "M": M, "N": N, "K": K,
        "config": {"bm": c.bm, "bn": c.bn, "bk": c.bk,
                   "split_k": c.split_k, "group_m": c.group_m,
                   "schedule": c.schedule},
        "n_candidates": s.n_candidates,
        "total_hex": s.predicted.total.hex(),
    }


def _fmt(e):
    c = e["config"]
    sched = "" if c["schedule"] == "data_parallel" else "/streamk"
    return (f"{c['bm']}x{c['bn']}x{c['bk']}/sk{c['split_k']}"
            f"/g{c['group_m']}{sched} "
            f"P={e['n_candidates']} {e['total_hex']}")


def test_llama3_selection_goldens():
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    assert set(golden) == set(PRESETS), (
        "golden file presets out of date — regenerate deliberately with "
        "tools/regen_goldens.py")
    mismatches = []
    for hw_name in sorted(PRESETS):
        hw = PRESETS[hw_name]
        want_entries = golden[hw_name]
        seen = set()
        for size in ("8b", "70b"):
            for (name, M, N, K) in llama3_gemms(size):
                seen.add(name)
                got = _current_entry(M, N, K, hw)
                want = want_entries.get(name)
                if want != got:
                    mismatches.append((hw_name, name, want, got))
        assert seen == set(want_entries), (hw_name, "sweep drifted")
    if mismatches:
        lines = [
            f"{len(mismatches)} golden selection mismatch(es) — if the "
            "model change is deliberate, regenerate with "
            "tools/regen_goldens.py and review:",
            f"{'preset':18} {'gemm':20} {'golden':44} current",
        ]
        for hw_name, name, want, got in mismatches:
            lines.append(f"{hw_name:18} {name:20} "
                         f"{'<missing>' if want is None else _fmt(want):44} "
                         f"{_fmt(got)}")
        table = "\n".join(lines)
        os.makedirs(os.path.dirname(DIFF_PATH), exist_ok=True)
        with open(DIFF_PATH, "w") as f:
            f.write(table + "\n")
        raise AssertionError(table)


def test_goldens_pin_single_core_bit_parity():
    """The tpu_v5e golden section carries the PR 1/2 lineage: every entry
    is a sk=1, data_parallel selection whose hex latency is bit-stable."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for name, e in golden["tpu_v5e"].items():
        assert e["config"]["split_k"] == 1, name
        assert e["config"]["schedule"] == "data_parallel", name
        assert float.fromhex(e["total_hex"]) > 0, name
    # spot anchor: the first PR 1 golden, hard-coded so a wholesale
    # regeneration of the file cannot silently rewrite the lineage
    qkv = golden["tpu_v5e"]["8b/qkv/t1024"]
    assert qkv["config"] == {"bm": 512, "bn": 1024, "bk": 128,
                             "split_k": 1, "group_m": 1,
                             "schedule": "data_parallel"}
    assert qkv["n_candidates"] == 176
    assert qkv["total_hex"] == "0x1.19b6b4bb2dfd5p-12"


def test_goldens_pin_gpu_tail_wave_behaviour():
    """Acceptance: on the multi-core GPU presets the golden selections use
    split_k > 1 or stream_k for the tail-wave llama3 shapes (small-token
    rows), pinning the wave model's restored split-K rationale."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for hw_name in ("gpu_mi300x_like", "gpu_h100_like"):
        t1024 = {n: e for n, e in golden[hw_name].items() if "/t1024" in n}
        assert t1024
        n_ksplit = sum(e["config"]["split_k"] > 1
                       or e["config"]["schedule"] == "stream_k"
                       for e in t1024.values())
        assert n_ksplit >= len(t1024) // 2, (hw_name, n_ksplit, len(t1024))
