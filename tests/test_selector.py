"""Core analytical model + selector: unit and property tests (paper Alg 3-9)."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # CPU container: shim
    from _hypothesis_compat import given, settings, st

from repro.core import (
    TPU_V4,
    TPU_V5E,
    TPU_V5P,
    GemmProblem,
    TileConfig,
    candidate_tiles,
    chip_waves,
    clear_selection_cache,
    gemm_latency,
    grid_shape,
    hbm_traffic,
    rank_candidates,
    reuse_fraction,
    select_gemm_config,
    selection_cache_size,
    simulate_gemm,
    vmem_working_set,
)
from repro.core.latency import score_candidate

DIMS = st.integers(min_value=1, max_value=8192)
DIMS128 = st.integers(min_value=1, max_value=64).map(lambda k: k * 128)


@settings(max_examples=40, deadline=None)
@given(M=DIMS, N=DIMS, K=DIMS)
def test_candidates_respect_vmem_and_alignment(M, N, K):
    p = GemmProblem(M=M, N=N, K=K)
    cands = candidate_tiles(p, TPU_V5E)
    assert cands, (M, N, K)
    budget = TPU_V5E.vmem_budget()
    sub = TPU_V5E.sublane(p.in_dtype)
    for t in cands:
        assert vmem_working_set(t, p.in_dtype, TPU_V5E) <= budget
        assert t.bm % sub == 0
        assert t.bn % TPU_V5E.lane_width == 0
        assert t.bk % TPU_V5E.lane_width == 0


@settings(max_examples=40, deadline=None)
@given(M=DIMS, N=DIMS, K=DIMS)
def test_latency_model_properties(M, N, K):
    p = GemmProblem(M=M, N=N, K=K)
    for t in candidate_tiles(p, TPU_V5E)[:20]:
        b = gemm_latency(p, t, TPU_V5E)
        assert b.total > 0
        assert b.bottleneck in ("mxu_compute", "vmem_bandwidth",
                                "hbm_bandwidth", "dma_issue",
                                "pipeline_fill")
        # paper Alg. 5: hit rate bounded
        assert 0.0 <= reuse_fraction(p, t) <= 1.0
        # traffic at least compulsory
        assert hbm_traffic(p, t) >= p.min_bytes * 0.999
        # fast scoring path identical to the full model
        assert math.isclose(score_candidate(p, t, TPU_V5E), b.total,
                            rel_tol=1e-12)


@settings(max_examples=20, deadline=None)
@given(M=DIMS128, N=DIMS128, K=DIMS128)
def test_selection_deterministic_and_cached(M, N, K):
    clear_selection_cache()
    s1 = select_gemm_config(M, N, K)
    n = selection_cache_size()
    s2 = select_gemm_config(M, N, K)
    assert selection_cache_size() == n
    assert s1.config == s2.config
    assert s1.predicted.total == s2.predicted.total


def test_latency_monotonic_in_k():
    """Same tile, growing K -> latency must not decrease (more grid steps)."""
    t = TileConfig(bm=256, bn=256, bk=256)
    prev = 0.0
    for K in (256, 512, 1024, 2048, 4096):
        cur = gemm_latency(GemmProblem(M=1024, N=1024, K=K), t, TPU_V5E).total
        assert cur > prev
        prev = cur


def test_large_square_gemm_is_compute_bound():
    s = select_gemm_config(8192, 8192, 8192)
    assert s.predicted.bottleneck == "mxu_compute"
    # near-peak predicted throughput
    assert s.predicted_tflops > 150


def test_memory_bound_gemm_identified():
    # skinny: M=8 -> heavy padding, HBM-dominated
    s = select_gemm_config(8, 8192, 8192)
    assert s.predicted.bottleneck in ("hbm_bandwidth", "dma_issue")


def test_chip_waves_matches_paper_alg4():
    p = GemmProblem(M=4096, N=4096, K=128)
    t = TileConfig(bm=256, bn=256, bk=128)
    active, waves = chip_waves(p, t, 256)
    assert waves == 1 and active == 256          # exactly one full wave
    active, waves = chip_waves(p, t, 100)
    assert waves == 3 and active == 56           # 256 tiles over 100 chips


def test_grid_shape_split_k():
    p = GemmProblem(M=256, N=256, K=4096)
    t = TileConfig(bm=256, bn=256, bk=256, split_k=4)
    Tm, Tn, Tk = grid_shape(p, t)
    assert (Tm, Tn, Tk) == (1, 1, 16)


@pytest.mark.parametrize("hw", [TPU_V5E, TPU_V5P, TPU_V4])
def test_architecture_portability(hw):
    """Paper Fig. 5: the same model retargets by swapping constants only."""
    s = select_gemm_config(4096, 4096, 4096, hw=hw)
    assert s.hardware == hw.name
    assert s.predicted.total > 0
    # faster chips must predict faster GEMMs for the compute-bound case
    if hw is not TPU_V5E:
        base = select_gemm_config(4096, 4096, 4096, hw=TPU_V5E)
        assert s.predicted.total < base.predicted.total


def test_selection_efficiency_vs_simulator_spot():
    """Fig. 3 in miniature: selector reaches >=85% of the simulator's
    exhaustive argmin on a few representative shapes."""
    shapes = [(4096, 4096, 4096), (256, 256, 8192), (2048, 512, 1024),
              (128, 4096, 512), (1024, 1024, 256)]
    effs = []
    for (M, N, K) in shapes:
        p = GemmProblem(M=M, N=N, K=K)
        cands = candidate_tiles(p, TPU_V5E)
        best_t, best_r = None, None
        for t in cands:
            r = simulate_gemm(p, t, TPU_V5E)
            if best_r is None or r.time < best_r.time:
                best_t, best_r = t, r
        sel = select_gemm_config(M, N, K)
        eff = best_r.time / simulate_gemm(p, sel.config, TPU_V5E).time
        effs.append(eff)
    assert sum(effs) / len(effs) >= 0.85, effs


def test_vectorized_scoring_matches_scalar_and_argmin():
    """The numpy batch scorer must reproduce the scalar fast path exactly and
    the vectorized argmin must return the identical config the sequential
    scoring loop (seed behaviour) would pick."""
    import numpy as np
    from repro.core import Epilogue, argmin_candidate, score_candidates

    shapes = [(4096, 4096, 4096), (100, 300, 77), (8, 8192, 8192),
              (64, 128, 2048), (2048, 512, 1024), (1, 128, 128),
              (640, 256, 256), (256, 256, 8192)]
    eps = [Epilogue(), Epilogue(bias=True, activation="gelu"),
           Epilogue(activation="swiglu_gate", residual=True)]
    for (M, N, K) in shapes:
        for ep in eps:
            p = GemmProblem(M=M, N=N, K=K, epilogue=ep)
            cands = candidate_tiles(p, TPU_V5E)
            vec = score_candidates(p, cands, TPU_V5E)
            scal = np.array([score_candidate(p, t, TPU_V5E) for t in cands])
            assert np.allclose(vec, scal, rtol=1e-14)
            # reference sequential argmin (the seed's scoring loop)
            best, best_score = None, None
            for t, s in zip(cands, scal):
                if best_score is None or s < best_score - 1e-15 or (
                        abs(s - best_score) <= 1e-15
                        and (t.bm * t.bn * t.bk)
                        > (best.bm * best.bn * best.bk)):
                    best, best_score = t, s
            assert argmin_candidate(p, cands, TPU_V5E) == best, (M, N, K, ep)


def test_candidate_arrays_and_select_fast_parity():
    """The vectorized enumeration must reproduce candidate_tiles exactly
    (same filters, same order) and select_fast the sequential winner."""
    import numpy as np
    from repro.core import Epilogue, argmin_candidate, candidate_arrays
    from repro.core.selector import select_fast

    shapes = [(4096, 4096, 4096), (100, 300, 77), (8, 8192, 8192),
              (64, 128, 2048), (1, 128, 128), (640, 256, 256),
              (256, 256, 8192), (13, 77, 100)]
    for (M, N, K) in shapes:
        for ep in [Epilogue(), Epilogue(bias=True, activation="gelu")]:
            p = GemmProblem(M=M, N=N, K=K, epilogue=ep)
            tiles = candidate_tiles(p, TPU_V5E)
            bm, bn, bk, sk, gm, sched = candidate_arrays(p, TPU_V5E)
            assert len(bm) == len(tiles)
            from repro.core import SCHEDULES
            for i, t in enumerate(tiles):
                assert (t.bm, t.bn, t.bk, t.split_k, t.group_m,
                        t.schedule) == \
                    (int(bm[i]), int(bn[i]), int(bk[i]),
                     int(sk[i]), int(gm[i]), SCHEDULES[int(sched[i])])
            best, n = select_fast(p, TPU_V5E)
            assert n == len(tiles)
            assert best == argmin_candidate(p, tiles, TPU_V5E), (M, N, K, ep)


def test_epilogue_traffic_terms():
    """Fused epilogue operands add exactly their compulsory reads; the
    unfused formulation costs one full-output round trip per post-op more."""
    from repro.core import DTYPE_BYTES, Epilogue, epilogue_unfused_extra_bytes

    p0 = GemmProblem(M=1024, N=2048, K=512)
    ep = Epilogue(bias=True, activation="swiglu_gate", residual=True)
    p1 = GemmProblem(M=1024, N=2048, K=512, epilogue=ep)
    t = TileConfig(bm=256, bn=256, bk=256)
    bi = DTYPE_BYTES[p0.in_dtype]
    want_extra = (2 * 1024 * 2048 + 2048) * bi        # gate + residual + bias
    assert hbm_traffic(p1, t) - hbm_traffic(p0, t) == want_extra
    assert p1.min_bytes - p0.min_bytes == want_extra
    # unfused: 3 post-ops, each a full f32 output read+write, plus operands
    bo = DTYPE_BYTES[p0.out_dtype]
    assert epilogue_unfused_extra_bytes(p1) == \
        3 * 2 * 1024 * 2048 * bo + want_extra
    # fused latency strictly below unfused accounting
    lat = gemm_latency(p1, t, TPU_V5E)
    unfused = gemm_latency(p0, t, TPU_V5E).total \
        + epilogue_unfused_extra_bytes(p1) / TPU_V5E.hbm_bandwidth
    assert lat.total < unfused


def test_split_k_no_hbm_partials_in_model():
    """In-kernel split-K: same HBM traffic as the flat-K schedule (no
    (sk, M, N) partial write/read penalty), only K-padding can differ."""
    p = GemmProblem(M=256, N=256, K=4096)
    t1 = TileConfig(bm=256, bn=256, bk=256, split_k=1)
    t4 = TileConfig(bm=256, bn=256, bk=256, split_k=4)
    assert hbm_traffic(p, t4) == hbm_traffic(p, t1)
    r1 = simulate_gemm(p, t1, TPU_V5E)
    r4 = simulate_gemm(p, t4, TPU_V5E)
    assert r4.hbm_bytes == r1.hbm_bytes


def test_selection_epilogue_aware_and_cached_separately():
    from repro.core import Epilogue
    clear_selection_cache()
    s0 = select_gemm_config(512, 512, 512)
    n = selection_cache_size()
    ep = Epilogue(activation="swiglu_gate", residual=True)
    s1 = select_gemm_config(512, 512, 512, epilogue=ep)
    assert selection_cache_size() == n + 1
    assert s1.problem.epilogue == ep
    assert s1.predicted.total >= s0.predicted.total   # extra operand reads
    assert s1.predicted.hbm_traffic > s0.predicted.hbm_traffic


def test_simulator_conservation():
    """Simulator moves at least the compulsory bytes and its MXU busy time
    matches padded flops / peak."""
    p = GemmProblem(M=1000, N=1000, K=1000)
    t = TileConfig(bm=128, bn=128, bk=128)
    r = simulate_gemm(p, t, TPU_V5E)
    assert r.hbm_bytes >= p.min_bytes
    assert r.time >= r.mxu_busy > 0
