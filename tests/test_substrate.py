"""Data pipeline, optimizer, compression, checkpoint, fault-tolerance."""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # CPU container: shim
    from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.optim import (AdamW, compress_with_feedback, dequantize_int8,
                         quantize_int8, warmup_cosine)
from repro.runtime import StragglerMonitor, is_transient, retry


# ---------------------------------------------------------------------------
# Data.
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restart_safe():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8)
    a = SyntheticLM(cfg, process_index=0, process_count=1)
    b = SyntheticLM(cfg, process_index=0, process_count=1)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(a.batch_at(step)["tokens"],
                                      b.batch_at(step)["tokens"])
    # different steps differ
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              a.batch_at(1)["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticLM(cfg, process_index=0, process_count=2)
    assert h0.local_batch == 4


def test_data_tokens_in_range_and_prefetch():
    cfg = DataConfig(vocab_size=137, seq_len=32, global_batch=4)
    ds = SyntheticLM(cfg, process_index=0, process_count=1)
    it = Prefetcher(ds.iterate(0), depth=2)
    for _, batch in zip(range(3), it):
        t = batch["tokens"]
        assert t.shape == (4, 32)
        assert t.min() >= 0 and t.max() < 137
    it.close()


# ---------------------------------------------------------------------------
# Optimizer.
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}           # d/dw ||w||^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_grad_clipping():
    opt = AdamW(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    assert metrics["grad_norm"] > 1e5            # reported pre-clip


def test_warmup_cosine_shape():
    s = warmup_cosine(1e-3, 10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(s(jnp.int32(100))) < 2e-4 + 1e-9


# ---------------------------------------------------------------------------
# Compression (int8 + error feedback).
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2,
                max_size=64))
def test_quantize_int8_bounded_error(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, scale = quantize_int8(x)
    err = np.asarray(dequantize_int8(q, scale) - x)
    amax = float(jnp.max(jnp.abs(x)))
    assert np.all(np.abs(err) <= amax / 127.0 + 1e-6)


def test_error_feedback_accumulates_to_zero_mean():
    """With error feedback, the *accumulated* transmitted signal tracks the
    true signal: residual error stays bounded (doesn't drift)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    err = jnp.zeros_like(g)
    sent_total = jnp.zeros_like(g)
    for _ in range(50):
        q, scale, err = compress_with_feedback(g, err)
        sent_total = sent_total + dequantize_int8(q, scale)
    # average transmitted ~ g
    np.testing.assert_allclose(np.asarray(sent_total / 50), np.asarray(g),
                               atol=0.05)


# ---------------------------------------------------------------------------
# Checkpoint.
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nest": {"b": jnp.ones(4, jnp.bfloat16)}}
    save(str(tmp_path), 3, tree)
    save(str(tmp_path), 7, jax.tree_util.tree_map(lambda x: x * 2, tree))
    assert latest_step(str(tmp_path)) == 7
    step, back = restore(str(tmp_path),
                         jax.tree_util.tree_map(
                             lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             tree))
    assert step == 7
    np.testing.assert_allclose(np.asarray(back["a"]),
                               np.asarray(tree["a"] * 2))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(4, dtype=jnp.float32)}
    path = save(str(tmp_path), 1, tree)
    # corrupt the arrays file
    import numpy as _np
    _np.savez(os.path.join(path, "arrays.npz"),
              a=_np.zeros(4, _np.float32))
    with pytest.raises(IOError, match="corruption"):
        restore(str(tmp_path), jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))


def test_checkpoint_atomic_tmp_cleanup(tmp_path):
    tree = {"a": jnp.zeros(2)}
    p = save(str(tmp_path), 1, tree)
    assert not p.endswith(".tmp")
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# Fault tolerance.
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags_outlier():
    m = StragglerMonitor(window=20, z_threshold=3.0, min_steps=5)
    for _ in range(10):
        assert m.record(0.1) is None
    msg = m.record(1.5)
    assert msg is not None and "straggler" in msg


def test_retry_on_transient_only():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("UNAVAILABLE: preempted")
        return "ok"

    assert retry(flaky, retries=5, base_delay=0.01) == "ok"
    assert calls["n"] == 3

    def hard_fail():
        raise ValueError("deterministic bug")

    with pytest.raises(ValueError):
        retry(hard_fail, retries=5, base_delay=0.01)
    assert not is_transient(ValueError("x"))
    assert is_transient(RuntimeError("DEADLINE_EXCEEDED while xfer"))
