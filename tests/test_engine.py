"""Continuous-batching engine (launch/engine.py): the load-bearing claim
is EXACTNESS — a request's tokens do not depend on what else shares the
batch, which bucket padded it, when its slot was admitted, or whether a
transient fault/drain interrupted the run.  Everything here compares
engine output against isolated single-request runs or a clean reference.
"""
import numpy as np
import pytest

import jax

from repro.configs.registry import get_config
from repro.core.bucketing import plan_buckets, step_gemms
from repro.kernels import ops
from repro.launch.engine import ServingEngine
from repro.nn.model import Model


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ssm():
    cfg = get_config("mamba2-370m", smoke=True)
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
            for l in lens]


def _isolated(model, params, prompt, n, **kw):
    eng = ServingEngine(model, params, max_batch=1, max_len=64,
                        temperature=0.0, seed=0, **kw)
    eng.submit(prompt, max_new_tokens=n)
    return eng.run()["results"][0].tokens


def test_ragged_bucketed_matches_isolated(dense):
    """Ragged prompts padded to priced bucket edges, admitted into a
    slot-reusing batch: every request's tokens equal its solo run's
    (right-padding is invisible under causal attention; stale KV beyond a
    reused slot's prefix is overwritten before the mask reaches it)."""
    cfg, model, params = dense
    lens = [5, 9, 13, 7]
    prompts = _prompts(cfg, lens)
    plan = plan_buckets(
        lens, gemms=step_gemms(cfg.d_model, cfg.d_ff,
                               kv_dim=cfg.num_kv_heads * cfg.head_dim,
                               vocab=cfg.vocab_size,
                               swiglu=cfg.activation == "swiglu"),
        hw=ops.get_default_hardware(), max_buckets=2)
    eng = ServingEngine(model, params, max_batch=2, max_len=64, plan=plan,
                        temperature=0.0, seed=0, sync_every=4)
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    assert eng.warm_start() > 0
    stats = eng.run()
    assert stats["steps"] > 0 and not stats["drained"]
    assert sum(stats["bucket_hits"].values()) == len(prompts)
    assert 0.0 <= stats["pad_fraction"] < 1.0
    for i, p in enumerate(prompts):
        ref = _isolated(model, params, p, 4)
        got = stats["results"][i].tokens
        assert np.array_equal(ref, got), (i, ref.tolist(), got.tolist())
        assert stats["results"][i].finished
        assert stats["results"][i].padded_len == plan.bucket_for(lens[i])


def test_ssm_ragged_unpadded_matches_isolated(ssm):
    """SSM family: no padding (state would integrate pad tokens) — ragged
    admission still works via exact per-length prefills."""
    cfg, model, params = ssm
    prompts = _prompts(cfg, [8, 12, 10])
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        temperature=0.0, seed=0)
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    assert eng.warm_start() == 0               # no attention GEMM grid
    stats = eng.run()
    for i, p in enumerate(prompts):
        ref = _isolated(model, params, p, 4)
        assert np.array_equal(ref, stats["results"][i].tokens)
        assert stats["results"][i].padded_len == len(p)


def test_fault_retry_and_drain_prefix(ssm):
    """One injected transient (retried against the intact cache) plus a
    preemption drain: the interrupted run's tokens are a bit-exact prefix
    of the clean run's."""
    cfg, model, params = ssm
    prompts = _prompts(cfg, [8, 8])

    def run(hook):
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            temperature=0.0, seed=0, decode_fault=hook)
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        return eng.run()

    clean = run(None)
    assert clean["steps"] == 5 and not clean["drained"]

    fired = []

    def hook(step, guard):
        if step == 1 and not fired:
            fired.append(step)
            raise RuntimeError("transient: injected decode fault")
        if step == 3:
            guard.request_stop()

    faulted = run(hook)
    assert faulted["retries"] == 1 and fired == [1]
    assert faulted["drained"] and faulted["steps"] == 4
    for rid in (0, 1):
        f = faulted["results"][rid].tokens
        c = clean["results"][rid].tokens
        assert np.array_equal(f, c[:len(f)])
        assert not faulted["results"][rid].finished


def test_plan_rejected_for_recurrent_families(ssm):
    cfg, model, params = ssm
    plan = plan_buckets([8, 16], gemms=[(64, 64)],
                        hw=ops.get_default_hardware())
    with pytest.raises(ValueError, match="not exact for family"):
        ServingEngine(model, params, max_batch=2, max_len=64, plan=plan)


def test_submit_validation(dense):
    cfg, model, params = dense
    eng = ServingEngine(model, params, max_batch=1, max_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros(0, np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.zeros(4, np.int32), max_new_tokens=0)
    with pytest.raises(ValueError, match="cache rows"):
        eng.submit(np.zeros(10, np.int32), max_new_tokens=8)


def test_sampling_deterministic_per_seed(dense):
    """temperature>0: pre-split per-step keys make runs reproducible."""
    cfg, model, params = dense
    prompts = _prompts(cfg, [6, 6])

    def run():
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            temperature=0.9, seed=11)
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        return eng.run()

    a, b = run(), run()
    for rid in (0, 1):
        assert np.array_equal(a["results"][rid].tokens,
                              b["results"][rid].tokens)
