"""Worker script run in a subprocess with 8 fake CPU devices.

Each check exercises the distribution layer on a real (2, 4) mesh:
sharded train steps, tp_matmul via shard_map, compressed DP psum, elastic
checkpoint restore onto a different mesh shape.  Invoked by
tests/test_distributed.py; prints CHECK_OK markers the test asserts on.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.5 exports it at top level
    shard_map = jax.shard_map
    _NO_REPCHECK = {"check_vma": False}
except AttributeError:
    from jax.experimental.shard_map import shard_map
    _NO_REPCHECK = {"check_rep": False}   # pre-0.5 spelling

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.registry import get_config            # noqa: E402
from repro.distributed import (batch_shardings,           # noqa: E402
                               opt_shardings, param_shardings, replicated,
                               spec_for, rules_for, tp_matmul)
from repro.launch.mesh import make_local_mesh             # noqa: E402
from repro.launch.steps import (TrainState,               # noqa: E402
                                make_train_step)
from repro.nn.model import Model                          # noqa: E402
from repro.optim import AdamW, compressed_psum            # noqa: E402


def check_sharded_train_step():
    mesh = make_local_mesh(tp=4)                          # (2, 4) mesh
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    # widen smoke dims so the 4-way model axis divides everything
    import dataclasses
    cfg = dataclasses.replace(cfg, d_model=64, num_heads=4, num_kv_heads=4,
                              d_ff=256, vocab_size=512, fsdp=True)
    model = Model(cfg)
    opt = AdamW(lr=1e-2)
    p_sh = param_shardings(model, mesh)
    state_sh = TrainState(params=p_sh, opt=opt_shardings(p_sh, mesh),
                          step=replicated(mesh))
    params = jax.jit(model.init, out_shardings=p_sh)(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    B, S = 4, 32
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    b_sh = batch_shardings(specs, mesh)
    step = jax.jit(make_train_step(model, opt),
                   in_shardings=(state_sh, b_sh),
                   out_shardings=(state_sh, replicated(mesh)),
                   donate_argnums=(0,))
    batch = {"tokens": jax.device_put(
        jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 512),
        b_sh["tokens"])}
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    # params must actually be sharded over the model axis
    leaf = state.params["layers"]["mlp"]["wg"]
    assert len(leaf.sharding.spec) >= 1
    print("CHECK_OK sharded_train_step")


def check_tp_matmul():
    mesh = make_local_mesh(tp=4)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 128)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 256)), dtype=jnp.float32)
    want = np.asarray(x @ w)
    got = np.asarray(tp_matmul(x, w, mesh, "model", backend="reference"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
    got_k = np.asarray(tp_matmul(x, w, mesh, "model", reduce_k=True,
                                 backend="reference"))
    np.testing.assert_allclose(got_k, want, rtol=1e-4, atol=1e-3)
    print("CHECK_OK tp_matmul")


def check_compressed_psum():
    mesh = make_local_mesh(tp=1)                          # (8, 1)
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((8, 64)), dtype=jnp.float32)
    err = jnp.zeros((8, 64), jnp.float32)

    def f(gl, el):
        mean, new_err = compressed_psum(gl, el, "data")
        return mean, new_err

    # Replication check off: the all_gather+local-reduce result is replicated
    # by construction, but jax cannot prove invariance across "data".
    mean, new_err = shard_map(
        f, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P(None), P("data")), **_NO_REPCHECK)(g, err)
    # Each device's row of `mean` is the mean over devices within int8 error.
    want = np.asarray(jnp.mean(g, axis=0))
    got = np.asarray(mean)[0]
    amax = float(jnp.max(jnp.abs(g)))
    assert np.max(np.abs(got - want)) <= amax / 127.0 + 1e-5
    print("CHECK_OK compressed_psum")


def check_elastic_restore():
    import tempfile
    from repro.checkpoint import restore, save
    mesh_a = make_local_mesh(tp=4)
    mesh_b = make_local_mesh(tp=2)                        # different mesh!
    cfg = get_config("mamba2-370m", smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, d_model=64, vocab_size=512)
    model = Model(cfg)
    p_sh_a = param_shardings(model, mesh_a)
    params = jax.jit(model.init, out_shardings=p_sh_a)(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        save(d, 5, params)
        p_sh_b = param_shardings(model, mesh_b)
        step, back = restore(d, model.abstract_params(), shardings=p_sh_b)
        assert step == 5
        a = np.asarray(jax.device_get(params["embed"]))
        b = np.asarray(jax.device_get(back["embed"]))
        np.testing.assert_array_equal(a, b)
    print("CHECK_OK elastic_restore")


def check_spec_divisibility_drop():
    mesh = make_local_mesh(tp=4)
    rules = rules_for(get_config("mixtral-8x22b"))
    # experts=3 does not divide 4 -> dropped; mlp picks up "model"
    spec = spec_for((3, 64, 256), ("experts", "embed", "mlp"), rules, mesh)
    assert spec[0] is None and spec[2] == "model", spec
    # experts=8 divides 4 -> kept; mlp then blocked (axis used)
    spec = spec_for((8, 64, 256), ("experts", "embed", "mlp"), rules, mesh)
    assert spec[0] == "model" and spec[2] is None, spec
    print("CHECK_OK spec_divisibility_drop")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    check_spec_divisibility_drop()
    check_tp_matmul()
    check_compressed_psum()
    check_elastic_restore()
    check_sharded_train_step()
    print("ALL_DISTRIBUTED_OK")
