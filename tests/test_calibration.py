"""Calibration & model-fidelity subsystem (DESIGN.md §8).

Covers the whole probe -> fit -> oracle pipeline against the
simulator-backed virtual device: planted-constant recovery (exact without
noise; documented tolerances under 2% measurement jitter), measured-value
validation in ``calibrate()``, the calibrated-topology JSON artifact
(schema, provenance, tamper detection), the end-to-end fingerprint
interplay with the persistent selection cache (a calib-fitted topology
saved under an existing preset name must invalidate warm starts), the
selection observability hooks, and the oracle fidelity harness at smoke
scale.
"""
import dataclasses
import json
import math

import pytest

import repro.core.selector as selmod
from repro.calib import (VirtualDevice, fidelity_report, fit_topology,
                         level_windows, run_probes, theil_sen)
from repro.core import (GPU_MI300X_LIKE, TPU_V5E, GemmProblem, TileConfig,
                        add_selection_hook, calibrate,
                        clear_selection_cache, load_calibrated_topology,
                        load_selection_cache, remove_selection_hook,
                        select_gemm_config, simulate_gemm, simulate_stream,
                        topology_fingerprint, unload_selection_cache)

# Documented fit tolerances under 2% multiplicative measurement noise.
# Slopes (bandwidths, peak rates) are robust; intercept-derived overheads
# are extracted by subtraction from measurements that dwarf them, so their
# relative recovery is inherently looser (the artifact's residuals record
# the uncertainty).
TOL_RATE = 0.05          # per-level bandwidth, per-dtype peak, dma_fixed
# Intercept recovery error scales like noise x wave-slope x x-range /
# launch (~16% at 2% noise for a 2 us launch), so the launch tolerance is
# structurally looser than the slope tolerances.
TOL_LAUNCH = 0.20        # kernel_launch (wave-staircase intercept)
# The backing first-byte latency comes out of a double subtraction whose
# error scale is the launch + latency the intercept measures — a latency
# dwarfed by the launch can carry a huge *relative* error while the fit is
# fine on the scale it operates on, so it is judged against that scale.
TOL_LATENCY = 0.15       # abs err / (true latency + true launch)


def _perturbed(base):
    """A planted ground truth: every measurable constant moved off preset."""
    return base.with_calibration(
        levels=tuple(dataclasses.replace(l, bandwidth=l.bandwidth * 1.3,
                                         latency=l.latency * 0.7)
                     for l in base.levels),
        peak_flops={k: v * 0.85 for k, v in base.peak_flops.items()},
        kernel_launch=base.kernel_launch * 1.5,
        dma_fixed=base.dma_fixed * 2.0)


def _tolerance(field: str, noise: float) -> float:
    if noise == 0.0:
        return 1e-6
    if field == "hbm_latency":
        return TOL_LATENCY
    if field == "kernel_launch":
        return TOL_LAUNCH
    return TOL_RATE


# ---------------------------------------------------------------------------
# Probes against the virtual device.
# ---------------------------------------------------------------------------

def test_level_windows_target_each_level():
    """Each window must fit its target level's budget while exceeding every
    inner level's — so the stream probe isolates exactly one serving level
    (checked against the simulator's own serving rule)."""
    for base in (TPU_V5E, GPU_MI300X_LIKE):
        wins = level_windows(base)
        assert [n for _, n, _ in wins] == \
            [l.name for l in reversed(base.levels[1:])] + [base.levels[0].name]
        for idx, name, window in wins:
            inner = max((l.budget() for l in base.levels[idx + 1:]),
                        default=0)
            assert window > inner
            if idx > 0:
                assert window <= base.levels[idx].budget()
        # the virtual device serves a window-sized stream from that level:
        # time per byte beyond the first pass == 1 / level bandwidth
        for idx, name, window in wins:
            t1 = simulate_stream(base, 8.0 * window, window, 1)
            t2 = simulate_stream(base, 16.0 * window, window, 1)
            bw = 8.0 * window / (t2 - t1)
            assert math.isclose(bw, base.levels[idx].bandwidth,
                                rel_tol=1e-9), (base.name, name)


def test_probe_sweeps_are_deterministic_and_serializable():
    dev = VirtualDevice(TPU_V5E, noise=0.02, seed=7)
    s1 = run_probes(dev, TPU_V5E, dtypes=("bfloat16",))
    s2 = run_probes(dev, TPU_V5E, dtypes=("bfloat16",))
    assert s1.keys() == s2.keys()
    for k in s1:
        assert s1[k].samples == s2[k].samples, k       # same jitter
        json.dumps(s1[k].to_dict())                    # JSON-able raw data


def test_theil_sen_exact_on_collinear_and_robust_to_outlier():
    xs = [1.0, 2.0, 3.0, 4.0, 5.0]
    ys = [3.0 + 2.0 * x for x in xs]
    slope, icpt = theil_sen(xs, ys)
    assert math.isclose(slope, 2.0) and math.isclose(icpt, 3.0)
    ys[2] *= 10.0                                      # one wild outlier
    slope, icpt = theil_sen(xs, ys)
    assert abs(slope - 2.0) / 2.0 < 0.35               # not dragged away


def test_theil_sen_degenerate_sweep_is_a_clean_valueerror():
    """Regression: a sweep whose surviving samples all share one x (the
    watchdog/NaN filters can reduce a sweep to a single repeated point)
    used to die in ``_median([])`` with a bare IndexError.  It must raise
    the ValueError the fail-soft fit path classifies."""
    with pytest.raises(ValueError, match="degenerate sweep"):
        theil_sen([2.0, 2.0, 2.0], [1.0, 1.1, 0.9])
    with pytest.raises(ValueError, match="degenerate sweep"):
        theil_sen([7.0, 7.0], [1.0, 1.2])              # two repeated points
    with pytest.raises(ValueError, match=">= 2 samples"):
        theil_sen([7.0], [1.0])        # too-few guard stays its own error


def test_fit_topology_degrades_on_degenerate_sweep():
    """The fit-level contract for the same bug: under
    ``allow_degraded=True`` a degenerate probe sweep keeps the preset
    constant and records the reason; without it, calibration aborts with
    the classified error instead of an IndexError."""
    import dataclasses as _dc

    from repro.calib import ProbeSweep
    dev = VirtualDevice(TPU_V5E)
    probes = dict(run_probes(dev, TPU_V5E, dtypes=("bfloat16",)))
    (key, sweep), = [(k, s) for k, s in probes.items()
                     if s.kind == "compute"]
    probes[key] = _dc.replace(
        sweep, samples=((8.0, 1e-3), (8.0, 1.1e-3), (8.0, 0.9e-3)))
    res = fit_topology(TPU_V5E, dev, probes=probes, allow_degraded=True)
    assert "degenerate sweep" in res.degraded["peak_flops.bfloat16"]
    assert res.topology.peak_flops["bfloat16"] == \
        TPU_V5E.peak_flops["bfloat16"]                 # preset kept
    with pytest.raises(ValueError, match="degenerate sweep"):
        fit_topology(TPU_V5E, dev, probes=probes)


# ---------------------------------------------------------------------------
# Fit: planted-constant recovery (the tentpole acceptance).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("base", [TPU_V5E, GPU_MI300X_LIKE],
                         ids=lambda b: b.name)
@pytest.mark.parametrize("noise", [0.0, 0.02])
def test_fit_recovers_planted_constants(base, noise):
    truth = _perturbed(base)
    res = fit_topology(base, VirtualDevice(truth, noise=noise),
                       dtypes=("bfloat16", "float32"))
    for field, err in res.compare_to(truth).items():
        if field == "hbm_latency" and noise:
            err = abs(res.fitted[field] - truth.backing.latency) \
                / (truth.backing.latency + truth.kernel_launch)
        assert err <= _tolerance(field, noise), (field, err, noise)
    # the wave probe confirms the occupancy stage's static share
    assert abs(res.static_share - 1.0) < (0.05 if noise else 1e-6)
    # structure untouched: same chain, same menus, same name
    assert res.topology.name == base.name
    assert [l.name for l in res.topology.levels] == \
        [l.name for l in base.levels]
    assert res.topology.bm_menu == base.bm_menu


def test_fit_residuals_reflect_noise():
    truth = _perturbed(TPU_V5E)
    clean = fit_topology(TPU_V5E, VirtualDevice(truth))
    noisy = fit_topology(TPU_V5E, VirtualDevice(truth, noise=0.02))
    assert max(clean.residuals.values()) < 1e-9
    assert max(noisy.residuals.values()) > 1e-3


# ---------------------------------------------------------------------------
# calibrate() measured-value validation (satellite).
# ---------------------------------------------------------------------------

def test_calibrate_rejects_nonpositive_and_nan_named():
    for bad, field in ((float("nan"), "hbm_bandwidth"),
                       (0.0, "hbm_bandwidth"),
                       (-5.0, "vmem_bytes"),
                       (0.0, "vmem_budget_fraction")):
        with pytest.raises(ValueError, match=field):
            calibrate(TPU_V5E, {field: lambda b=bad: b})
    # negative overheads rejected too; zero overhead is a legal measurement
    with pytest.raises(ValueError, match="dma_fixed"):
        calibrate(TPU_V5E, {"dma_fixed": lambda: -1e-9})
    assert calibrate(TPU_V5E, {"dma_fixed": lambda: 0.0}).dma_fixed == 0.0
    # per-dtype peak_flops entries validated individually, named in full
    with pytest.raises(ValueError, match=r"peak_flops\.bfloat16"):
        calibrate(TPU_V5E, {"peak_flops": lambda: {"bfloat16": -1.0}})
    # unknown fields still raise KeyError (pre-existing contract)
    with pytest.raises(KeyError, match="warp_speed"):
        calibrate(TPU_V5E, {"warp_speed": lambda: 1.0})


def test_calibrate_device_delegates_to_fit_pipeline():
    truth = _perturbed(TPU_V5E)
    topo = calibrate(TPU_V5E, device=VirtualDevice(truth),
                     dtypes=("bfloat16",))
    assert math.isclose(topo.hbm_bandwidth, truth.hbm_bandwidth,
                        rel_tol=1e-6)
    with pytest.raises(ValueError, match="not both"):
        calibrate(TPU_V5E, {"hbm_bandwidth": lambda: 1e9},
                  device=VirtualDevice(truth))
    # neither mode given: refuse rather than silently return the preset
    with pytest.raises(ValueError, match="either"):
        calibrate(TPU_V5E)


# ---------------------------------------------------------------------------
# Calibrated-topology artifact (provenance + JSON schema).
# ---------------------------------------------------------------------------

def test_artifact_round_trip_and_tamper_detection(tmp_path):
    truth = _perturbed(GPU_MI300X_LIKE)
    res = fit_topology(GPU_MI300X_LIKE, VirtualDevice(truth, noise=0.01))
    path = tmp_path / "mi300x.topo.json"
    res.save(str(path))

    topo, prov = load_calibrated_topology(path.read_text())
    assert topo == res.topology
    assert prov["fingerprint"] == topology_fingerprint(res.topology)
    assert prov["base_preset"] == GPU_MI300X_LIKE.name
    assert prov["device"].startswith("virtual:")
    assert set(prov["residuals"]) == set(prov["fitted_fields"])
    assert prov["probes"]                              # raw sweeps included

    # tampering with constants after the fit is rejected
    doc = json.loads(path.read_text())
    doc["topology"]["levels"][0]["bandwidth"] *= 2
    with pytest.raises(ValueError, match="fingerprint"):
        load_calibrated_topology(json.dumps(doc))
    # wrong schema tag is rejected
    doc2 = json.loads(path.read_text())
    doc2["schema"] = "repro/other/v1"
    with pytest.raises(ValueError, match="schema"):
        load_calibrated_topology(json.dumps(doc2))


# ---------------------------------------------------------------------------
# Fingerprint interplay with the persistent selection cache (satellite):
# probe -> fit -> serve, end-to-end.
# ---------------------------------------------------------------------------

@pytest.fixture
def cache_path(tmp_path, monkeypatch):
    path = str(tmp_path / "selections.json")
    monkeypatch.setenv("REPRO_SELECTION_CACHE", path)
    load_selection_cache(path)
    clear_selection_cache()
    yield path
    monkeypatch.delenv("REPRO_SELECTION_CACHE")
    unload_selection_cache()
    clear_selection_cache()


def test_calibrated_topology_invalidates_warm_cache_end_to_end(
        cache_path, tmp_path):
    """A topology fitted from probes and saved under an existing preset
    name must cold-rescore shapes the stock preset already persisted —
    the artifact's fingerprint, not its name, gates warm starts."""
    events = []
    hook = lambda sel, src: events.append((sel.hardware, src))  # noqa: E731
    add_selection_hook(hook)
    try:
        s_stock = select_gemm_config(1536, 1536, 1536, hw=TPU_V5E)
        assert events[-1] == ("tpu_v5e", "cold")

        # probe a faster machine, fit, save, reload — same preset name
        truth = TPU_V5E.with_calibration(hbm_bandwidth=2.0 * 819e9)
        res = fit_topology(TPU_V5E, VirtualDevice(truth))
        art = tmp_path / "tpu_v5e.topo.json"
        res.save(str(art))
        served, _ = load_calibrated_topology(art.read_text())
        assert served.name == "tpu_v5e"
        assert topology_fingerprint(served) != topology_fingerprint(TPU_V5E)

        # "new process": memo cleared, disk table reloaded
        clear_selection_cache()
        assert load_selection_cache(cache_path) >= 1
        s_cal = select_gemm_config(1536, 1536, 1536, hw=served)
        assert events[-1] == ("tpu_v5e", "cold")       # NOT warm-started
        assert s_cal.predicted.total < s_stock.predicted.total  # faster HBM
        # the re-recorded entry (same key: same preset name) now carries
        # the CALIBRATED fingerprint
        fps = {e["topo"] for e in json.load(open(cache_path)).values()}
        assert topology_fingerprint(served) in fps
        assert topology_fingerprint(TPU_V5E) not in fps

        # ... which in turn forces the stock preset back to cold scoring
        clear_selection_cache()
        load_selection_cache(cache_path)
        select_gemm_config(1536, 1536, 1536, hw=TPU_V5E)
        assert events[-1] == ("tpu_v5e", "cold")
    finally:
        remove_selection_hook(hook)


def test_same_process_calibrated_topology_bypasses_memo():
    """The in-process memo must ALSO key on the content fingerprint: a
    calibrated topology served under its preset name in the same process
    cold-rescores instead of returning the stock preset's memo entry."""
    events = []
    hook = lambda sel, src: events.append(src)         # noqa: E731
    add_selection_hook(hook)
    try:
        clear_selection_cache()
        select_gemm_config(768, 768, 768, hw=TPU_V5E)
        select_gemm_config(768, 768, 768, hw=TPU_V5E)
        assert events == ["cold", "memo"]
        served = TPU_V5E.with_calibration(hbm_bandwidth=2.0 * 819e9)
        assert served.name == TPU_V5E.name
        s_cal = select_gemm_config(768, 768, 768, hw=served)
        assert events[-1] == "cold"                    # memo NOT reused
        # and each topology keeps its own memo entry afterwards
        select_gemm_config(768, 768, 768, hw=TPU_V5E)
        select_gemm_config(768, 768, 768, hw=served)
        assert events[-2:] == ["memo", "memo"]
        assert s_cal.predicted.total < \
            select_gemm_config(768, 768, 768, hw=TPU_V5E).predicted.total
    finally:
        remove_selection_hook(hook)
        clear_selection_cache()


def test_fit_pipeline_without_bfloat16_dtype():
    """Topologies with no bfloat16 entry probe/fit via the shared
    reference-dtype rule instead of crashing in the wave probe."""
    base = TPU_V5E.with_calibration(peak_flops={"float32": 49e12})
    res = fit_topology(base, VirtualDevice(base), dtypes=("float32",))
    assert math.isclose(res.topology.peak_flops["float32"], 49e12,
                        rel_tol=1e-6)
    assert abs(res.static_share - 1.0) < 1e-6


def test_selection_hooks_report_memo_and_sources():
    events = []
    hook = lambda sel, src: events.append(src)         # noqa: E731
    add_selection_hook(hook)
    try:
        clear_selection_cache()
        select_gemm_config(640, 640, 640)
        select_gemm_config(640, 640, 640)
        assert events == ["cold", "memo"]
    finally:
        remove_selection_hook(hook)


# ---------------------------------------------------------------------------
# Oracle fidelity harness (smoke scale).
# ---------------------------------------------------------------------------

def test_fidelity_report_smoke(tmp_path):
    """Probe the whole oracle path at tiny scale: rows complete, fidelity
    in (0, 1], artifacts written; the analytical selection must stay close
    to the exhaustive optimum even on the scaled shapes."""
    rep = fidelity_report(presets=("tpu_v5e", "gpu_mi300x_like"),
                          sizes=("8b",), tokens=(1024,), scale=8,
                          out_dir=str(tmp_path), verbose=False)
    assert set(rep["presets"]) == {"tpu_v5e", "gpu_mi300x_like"}
    for preset, s in rep["presets"].items():
        assert s["n"] == 5
        assert 0.0 < s["worst_fidelity"] <= 1.0 + 1e-12
        assert s["mean_fidelity"] >= 0.90, (preset, s)
    for row in rep["rows"]:
        assert 0.0 < float(row[10]) <= 1.0 + 1e-12
        assert int(row[11]) >= 1                       # oracle model rank
    for suffix in ("json", "csv", "md"):
        assert (tmp_path / f"fidelity_report.{suffix}").exists()


@pytest.mark.slow
def test_fidelity_above_95pct_all_presets_llama3():
    """The paper's headline number (acceptance): analytical selection
    reaches >= 95% of the exhaustive-oracle optimum on the llama3 8B sweep
    for every preset, with the simulator as the pricing device."""
    rep = fidelity_report(sizes=("8b",), tokens=(1024,), scale=1,
                          verbose=False)
    for preset, s in rep["presets"].items():
        assert s["mean_fidelity"] >= 0.95, (preset, s)


# ---------------------------------------------------------------------------
# GEMM pricing device consistency.
# ---------------------------------------------------------------------------

def test_virtual_device_gemm_time_is_the_simulator():
    p = GemmProblem(M=256, N=512, K=512)
    t = TileConfig(bm=128, bn=128, bk=128)
    dev = VirtualDevice(TPU_V5E)
    assert dev.gemm_time(p, t) == simulate_gemm(p, t, TPU_V5E).time


def test_jax_device_primitives_execute():
    """The real-execution device's four primitives compile and run at tiny
    sizes (CPU wall clocks are meaningless; the code path — chunked
    non-hoistable stream reads, parallel compute lanes, the wave grid, a
    configured GEMM — is the contract)."""
    from repro.calib import JaxDevice
    dev = JaxDevice(repeat=1)
    for t in (dev.stream_time(16384.0, 8192, 4),
              dev.compute_time("bfloat16", 32, 4),
              dev.compute_time("int8", 32, 1),
              dev.wave_time(4, 8, "bfloat16"),
              dev.gemm_time(GemmProblem(M=128, N=128, K=128),
                            TileConfig(bm=128, bn=128, bk=128))):
        assert t > 0.0 and math.isfinite(t)


# ---------------------------------------------------------------------------
# Per-level roofline columns from dry-run artifacts (satellite).
# ---------------------------------------------------------------------------

def test_roofline_table_emits_per_level_columns(tmp_path, monkeypatch):
    """roofline_table must read the serving topology recorded in dry-run
    artifacts and emit one port column per memory level (plus a blank for
    artifacts predating the record)."""
    from benchmarks import common, roofline_table
    monkeypatch.setattr(common, "OUT_DIR", str(tmp_path / "bench"))
    hw = GPU_MI300X_LIKE
    rec = {
        "arch": "phi4-mini-3.8b", "shape": "train_4k", "mesh": "pod16x16",
        "chips": 256,
        "topology": {
            "name": hw.name,
            "fingerprint": topology_fingerprint(hw),
            "levels": [{"name": l.name, "bandwidth": l.bandwidth,
                        "capacity": l.capacity, "scope": l.scope}
                       for l in hw.levels],
        },
        "hbm_bytes_analytic": {"total": 1.06e12},
        "roofline": {"compute_s": 1e-3, "memory_s": 2e-4,
                     "collective_s": 1e-5, "bottleneck": "compute",
                     "useful_flop_ratio": 0.9},
        "memory_analytic_gib": {"total_gib": 3.0, "fits_16gib_hbm": True},
    }
    legacy = {k: v for k, v in rec.items() if k != "topology"}
    legacy["shape"] = "serve_128"
    (tmp_path / "a.json").write_text(json.dumps(rec))
    (tmp_path / "b.json").write_text(json.dumps(legacy))

    rows = roofline_table.run(verbose=False, path=str(tmp_path))
    csv_path = tmp_path / "bench" / "roofline_table.csv"
    header = csv_path.read_text().splitlines()[0].split(",")
    # one column per non-staging level of the recorded topology
    for lvl in hw.levels[:-1]:
        assert f"level_s:{lvl.name}" in header
    assert "serving_topology" in header
    by_shape = {r[1]: r for r in rows}
    new_row = by_shape["train_4k"]
    hbm_col = header.index("level_s:hbm")
    assert math.isclose(float(new_row[hbm_col]),
                        1.06e12 / hw.backing.bandwidth, rel_tol=1e-6)
    mall_col = header.index("level_s:mall")
    assert float(new_row[mall_col]) > 0.0
    # legacy artifact: topology unknown, level cells blank
    old_row = by_shape["serve_128"]
    assert old_row[header.index("serving_topology")] == "?"
    assert old_row[hbm_col] == ""
