"""Hardened fault-tolerance primitives (ISSUE 6 satellites).

Covers: ``retry`` full-jitter backoff with a ``max_delay`` cap and per-call
transient markers (a JAX ``UNAVAILABLE``-style error retries, a
``ValueError`` re-raises immediately); atomic ``Heartbeat`` writes under a
concurrent reader; ``PreemptionGuard`` signal-handler restore via
``uninstall()`` / context manager; and guarded selection hooks — a raising
observability hook must never abort selection, on cold or warm paths.
"""
import os
import signal
import threading
import time

import pytest

from repro.core.selector import (add_selection_hook, clear_selection_cache,
                                 remove_selection_hook, select_gemm_config)
from repro.runtime.fault_tolerance import (Heartbeat, PreemptionGuard,
                                           is_transient, retry)


# ---------------------------------------------------------------------------
# retry: full jitter, max_delay cap, marker extensibility
# ---------------------------------------------------------------------------


def test_retry_unavailable_retries_then_succeeds():
    calls = []

    def step():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("UNAVAILABLE: device preempted mid-step")
        return "ok"

    assert retry(step, retries=3, base_delay=0.0) == "ok"
    assert len(calls) == 3


def test_retry_valueerror_reraises_immediately():
    calls = []

    def step():
        calls.append(1)
        raise ValueError("deterministic: bad dims")

    with pytest.raises(ValueError):
        retry(step, retries=5, base_delay=0.0)
    assert len(calls) == 1          # no retry on a deterministic error


def test_retry_exhaustion_raises_the_transient():
    def step():
        raise RuntimeError("transient: never recovers")

    with pytest.raises(RuntimeError, match="never recovers"):
        retry(step, retries=2, base_delay=0.0)


def test_retry_full_jitter_bounds_and_max_delay_cap():
    """The sleep is drawn uniformly from [0, min(base * 2^attempt,
    max_delay)] — the seed's unbounded ladder slept minutes by attempt 8."""
    class RecordingRng:
        def __init__(self):
            self.bounds = []

        def uniform(self, lo, hi):
            self.bounds.append((lo, hi))
            return 0.0                      # sleep nothing, record bounds

    rng = RecordingRng()
    n = [0]

    def step():
        n[0] += 1
        if n[0] <= 4:
            raise RuntimeError("transient: flaky")
        return 1

    assert retry(step, retries=4, base_delay=1.0, max_delay=3.0,
                 rng=rng) == 1
    assert [hi for _, hi in rng.bounds] == [1.0, 2.0, 3.0, 3.0]  # capped
    assert all(lo == 0.0 for lo, _ in rng.bounds)                # full jitter


def test_retry_transient_markers_extensible_per_call_site():
    def flaky_once():
        calls = []

        def step():
            calls.append(1)
            if len(calls) == 1:
                raise RuntimeError("MY_COLLECTIVE_HICCUP rank 3")
            return "ok"
        return step

    # Not a built-in marker: re-raises immediately...
    with pytest.raises(RuntimeError):
        retry(flaky_once(), retries=3, base_delay=0.0)
    # ...but the call site can declare it transient.
    assert retry(flaky_once(), retries=3, base_delay=0.0,
                 transient_markers=("MY_COLLECTIVE_HICCUP",)) == "ok"
    assert is_transient(RuntimeError("MY_COLLECTIVE_HICCUP"),
                        ("MY_COLLECTIVE_HICCUP",))
    assert not is_transient(RuntimeError("MY_COLLECTIVE_HICCUP"))


def test_retry_on_retry_callback_sees_each_attempt():
    seen = []
    n = [0]

    def step():
        n[0] += 1
        if n[0] <= 2:
            raise RuntimeError("transient: x")
        return 1

    retry(step, retries=3, base_delay=0.0,
          on_retry=lambda attempt, err: seen.append(attempt))
    assert seen == [0, 1]


# ---------------------------------------------------------------------------
# Heartbeat: atomic writes
# ---------------------------------------------------------------------------


def test_heartbeat_reader_never_observes_partial_file(tmp_path):
    """A reader polling the liveness file while beat() hammers it must
    always see a complete, parseable timestamp — the non-atomic
    truncate-then-write version fails this within a few hundred reads."""
    path = str(tmp_path / "alive")
    hb = Heartbeat(path, interval=3600.0)       # no background cadence
    hb.beat()
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            hb.beat()

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(400):
            with open(path) as f:
                txt = f.read()
            assert txt.strip(), "reader observed an empty heartbeat file"
            float(txt)                          # and a parseable one
    finally:
        stop.set()
        t.join()
        hb.close()
    # os.replace consumed every temp file — no litter next to the target.
    leftovers = [p for p in os.listdir(tmp_path) if p.endswith(".hb.tmp")]
    assert leftovers == []


def test_heartbeat_value_is_monotonic(tmp_path):
    path = str(tmp_path / "alive")
    hb = Heartbeat(path, interval=3600.0)
    hb.beat()
    first = float(open(path).read())
    time.sleep(0.01)
    hb.beat()
    second = float(open(path).read())
    hb.close()
    assert second >= first


# ---------------------------------------------------------------------------
# PreemptionGuard: handler restore
# ---------------------------------------------------------------------------


def test_preemption_guard_restores_previous_handlers():
    prev_term = signal.getsignal(signal.SIGTERM)
    prev_int = signal.getsignal(signal.SIGINT)
    with PreemptionGuard() as g:
        assert signal.getsignal(signal.SIGTERM) == g._handler
        assert signal.getsignal(signal.SIGINT) == g._handler
        assert not g.should_stop
        g.request_stop()
        assert g.should_stop
    assert signal.getsignal(signal.SIGTERM) == prev_term
    assert signal.getsignal(signal.SIGINT) == prev_int


def test_preemption_guard_uninstall_is_idempotent():
    prev_term = signal.getsignal(signal.SIGTERM)
    g = PreemptionGuard()
    g.uninstall()
    g.uninstall()                               # second call: no-op
    assert signal.getsignal(signal.SIGTERM) == prev_term


def test_preemption_guard_flags_real_sigterm():
    with PreemptionGuard() as g:
        os.kill(os.getpid(), signal.SIGTERM)
        deadline = time.time() + 5.0
        while not g.should_stop and time.time() < deadline:
            time.sleep(0.01)
        assert g.should_stop
    # ...and after exit the (default) handler is back in place; sending
    # another SIGTERM here would kill the test runner, which is the point.


# ---------------------------------------------------------------------------
# Selection hooks: log-and-continue on a raising observer
# ---------------------------------------------------------------------------


def test_raising_selection_hook_does_not_abort_cold_or_warm():
    seen = []

    def bad_hook(sel, source):
        raise RuntimeError("observer crashed")

    def good_hook(sel, source):
        seen.append(source)

    clear_selection_cache()
    add_selection_hook(bad_hook)
    add_selection_hook(good_hook)               # registered after: must run
    try:
        with pytest.warns(RuntimeWarning, match="hook skipped"):
            sel_cold = select_gemm_config(512, 512, 512)
        assert sel_cold.config.bm >= 1          # selection completed
        assert seen[-1] == "cold"
        with pytest.warns(RuntimeWarning, match="hook skipped"):
            sel_warm = select_gemm_config(512, 512, 512)
        assert sel_warm is sel_cold             # memo hit, still delivered
        assert seen[-1] == "memo"
    finally:
        remove_selection_hook(bad_hook)
        remove_selection_hook(good_hook)
        clear_selection_cache()
