"""Persistent selection-table failure paths (satellite: the PR 2 cache had
zero coverage for concurrency, corruption, or invalidation).

Covers: merge-on-write under two interleaved writers (in-process, pinning
the clobber window deterministically) and across two REAL processes,
truncated/corrupt JSON recovery, topology-fingerprint invalidation after
same-name recalibration, and schedule-field round-tripping (stream-K
selections must rehydrate as stream-K).
"""
import json
import os
import subprocess
import sys

import pytest

import repro.core.selector as selmod
from repro.core import (GPU_MI300X_LIKE, TPU_V5E, clear_selection_cache,
                        select_gemm_config)
from repro.core.selector import (load_selection_cache, save_selection_cache,
                                 unload_selection_cache)


@pytest.fixture
def cache_path(tmp_path, monkeypatch):
    """Activate persistence at a temp path; deactivate afterwards."""
    path = str(tmp_path / "selections.json")
    monkeypatch.setenv("REPRO_SELECTION_CACHE", path)
    load_selection_cache(path)
    clear_selection_cache()
    yield path
    monkeypatch.delenv("REPRO_SELECTION_CACHE")
    unload_selection_cache()
    clear_selection_cache()


def test_merge_on_write_two_interleaved_writers(cache_path):
    """Writer B loaded the (empty) table before writer A flushed; B's save
    must MERGE with A's on-disk entries, not clobber them."""
    select_gemm_config(1536, 1536, 1536)              # writer A, flushed
    a_table = json.load(open(cache_path))
    assert len(a_table) == 1

    # Writer B: in-memory table snapshot from BEFORE A's flush (empty).
    selmod._disk_table = {}
    clear_selection_cache()
    select_gemm_config(2560, 2560, 2560)              # writer B, flushed
    merged = json.load(open(cache_path))
    assert set(a_table) < set(merged)                 # A's entry survived
    assert len(merged) == 2


_WRITER = """
import os, sys
sys.path.insert(0, "src")
from repro.core import select_gemm_config
for m in {shapes}:
    select_gemm_config(m, m, m)
"""


def test_merge_on_write_two_real_processes(cache_path, tmp_path):
    """Two real processes share one cache path; every entry survives.

    Each save re-reads the file and merges before the atomic replace.  The
    processes run back-to-back: the read-merge-replace has no file lock,
    so truly simultaneous final flushes can lose a racing writer's entry
    (the TOCTOU window the interleaved-writers test above pins
    deterministically in-process) — sequencing keeps THIS test about the
    cross-process read-back path without CI flakes."""
    env = dict(os.environ, REPRO_SELECTION_CACHE=cache_path)
    shapes_a = [128, 256, 384, 512]
    shapes_b = [640, 768, 896, 1024]
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    pa = subprocess.Popen([sys.executable, "-c",
                           _WRITER.format(shapes=shapes_a)],
                          env=env, cwd=repo_root)
    assert pa.wait(timeout=120) == 0
    pb = subprocess.Popen([sys.executable, "-c",
                           _WRITER.format(shapes=shapes_b)],
                          env=env, cwd=repo_root)
    assert pb.wait(timeout=120) == 0
    table = json.load(open(cache_path))
    assert len(table) == len(shapes_a) + len(shapes_b)
    for m in shapes_a + shapes_b:
        assert any(f"({m}, {m}, {m}," in k for k in table), m


@pytest.mark.parametrize("corruption", ["truncated", "garbage", "empty"])
def test_corrupt_table_recovery(cache_path, corruption):
    """A truncated/garbled file must load as empty (no crash), selection
    must fall through to cold scoring, and the next flush must restore a
    valid JSON table."""
    s1 = select_gemm_config(1536, 1536, 1536)
    text = open(cache_path).read()
    with open(cache_path, "w") as f:
        f.write({"truncated": text[: len(text) // 2],
                 "garbage": "{not json at all",
                 "empty": ""}[corruption])
    clear_selection_cache()
    assert load_selection_cache(cache_path) == 0       # recovered as empty
    s2 = select_gemm_config(1536, 1536, 1536)          # cold path, no crash
    assert s2.config == s1.config
    table = json.load(open(cache_path))                # flush restored JSON
    assert len(table) == 1


def test_fingerprint_invalidation_on_recalibration(cache_path, monkeypatch):
    """An entry recorded under the stock topology must NOT warm-start a
    same-name recalibrated topology (the fingerprint, not the name, gates
    rehydration) — and the stock topology must still warm-start."""
    real = selmod.select_fast
    s1 = select_gemm_config(1536, 1536, 1536, hw=TPU_V5E)
    fp_stock = json.load(open(cache_path)).popitem()[1]["topo"]

    # "New process" #1: the SAME topology warm-starts, zero cold scoring.
    clear_selection_cache()
    assert load_selection_cache(cache_path) == 1
    monkeypatch.setattr(selmod, "select_fast",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            AssertionError("cold path ran")))
    assert select_gemm_config(1536, 1536, 1536, hw=TPU_V5E).config \
        == s1.config

    # "New process" #2: a same-NAME recalibrated topology must cold-score
    # (the content fingerprint, not the name, gates rehydration).
    clear_selection_cache()
    load_selection_cache(cache_path)
    calls = []

    def spy(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(selmod, "select_fast", spy)
    recal = TPU_V5E.with_calibration(hbm_bandwidth=500e9)
    s2 = select_gemm_config(1536, 1536, 1536, hw=recal)
    assert len(calls) == 1                             # cold scored
    # ...the slower HBM changed the predicted latency, and the re-recorded
    # entry (same key: same name) carries the NEW fingerprint
    assert s2.predicted.total > s1.predicted.total
    fp_recal = json.load(open(cache_path)).popitem()[1]["topo"]
    assert fp_recal != fp_stock


def test_schedule_round_trips_through_disk(cache_path, monkeypatch):
    """A stream-K selection persisted by one process must rehydrate as
    stream-K in the next (the schedule field is part of the config
    payload), with zero cold-path scoring."""
    s1 = select_gemm_config(1024, 4096, 4096, hw=GPU_MI300X_LIKE)
    assert s1.config.schedule == "stream_k"            # tail-wave shape

    clear_selection_cache()
    assert load_selection_cache(cache_path) >= 1
    monkeypatch.setattr(selmod, "select_fast",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            AssertionError("cold path ran")))
    s2 = select_gemm_config(1024, 4096, 4096, hw=GPU_MI300X_LIKE)
    assert s2.config == s1.config
    assert s2.config.schedule == "stream_k"
    assert s2.predicted.total == s1.predicted.total


def test_legacy_entry_without_schedule_still_rehydrates(cache_path):
    """PR 2-era tables have no schedule key; they must rehydrate as
    data_parallel rather than crash or fall cold."""
    s1 = select_gemm_config(1536, 1536, 1536)
    table = json.load(open(cache_path))
    k = next(iter(table))
    del table[k]["config"]["schedule"]                 # age the entry
    json.dump(table, open(cache_path, "w"))
    clear_selection_cache()
    load_selection_cache(cache_path)
    s2 = select_gemm_config(1536, 1536, 1536)
    assert s2.config == s1.config
    assert s2.config.schedule == "data_parallel"


def test_bulk_flush_merges_with_concurrent_writer(cache_path):
    """The batched cold path's ONE bulk flush lands in the same TOCTOU
    window as a concurrent writer's: our table was loaded (empty) before
    the other writer flushed, so a plain write would clobber it.  The bulk
    merge-on-write must preserve the concurrent entries AND persist every
    batch entry."""
    from repro.core.selector import select_gemm_config_batch

    select_gemm_config(1536, 1536, 1536)              # writer A, flushed
    a_table = json.load(open(cache_path))
    assert len(a_table) == 1

    # Writer B: table snapshot from BEFORE A's flush (empty), then a whole
    # batch of cold selections -> one bulk flush.
    selmod._disk_table = {}
    clear_selection_cache()
    shapes = [(m, m, m) for m in (256, 512, 768, 1024, 1280)]
    select_gemm_config_batch(shapes)
    merged = json.load(open(cache_path))
    assert set(a_table) < set(merged)                 # A's entry survived
    assert len(merged) == 1 + len(shapes)


def test_reload_after_programmatic_load_keeps_path(tmp_path, monkeypatch):
    """Regression: with $REPRO_SELECTION_CACHE unset, a bare
    ``load_selection_cache()`` after a programmatic
    ``load_selection_cache(path)`` must RE-LOAD from the remembered path —
    it used to resolve only the env var and silently deactivate
    persistence, even though ``save_selection_cache`` still honored the
    remembered path (load and save now share one resolution order:
    explicit path, then remembered path, then env)."""
    monkeypatch.delenv("REPRO_SELECTION_CACHE", raising=False)
    path = str(tmp_path / "selections.json")
    try:
        load_selection_cache(path)                     # programmatic load
        clear_selection_cache()
        select_gemm_config(1536, 1536, 1536)
        assert len(json.load(open(path))) == 1         # save honored path
        clear_selection_cache()
        selmod._disk_table = None                      # drop table only
        assert load_selection_cache() == 1             # bare re-load works
        assert selmod._disk_path == path
        # the explicit off switch is unload: afterwards a bare load with no
        # env var is a no-op deactivation again.
        unload_selection_cache()
        assert load_selection_cache() == 0
        assert selmod._disk_path is None
    finally:
        unload_selection_cache()
        clear_selection_cache()
