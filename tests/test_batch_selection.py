"""Batched selection (``select_gemm_config_batch``) is a COST optimization,
not a semantic one: every per-shape result must be bit-identical to the
scalar API — config, candidate count, and every float of the predicted
LatencyBreakdown down to the bit pattern (``float.hex``).  Covers all five
hardware presets x dtype pairs x epilogues, the memo/disk/cold source mix
(observed through the selection hooks), duplicate-shape sharing, the
single bulk disk flush, and the error paths.
"""
import dataclasses

import pytest

import repro.core.selector as selmod
from repro.core import (Epilogue, GemmProblem, clear_selection_cache,
                        get_hardware, select_gemm_config)
from repro.core.latency import TileConfig, gemm_latency_batch
from repro.core.selector import (add_selection_hook, load_selection_cache,
                                 remove_selection_hook,
                                 select_gemm_config_batch,
                                 unload_selection_cache)

PRESETS = ["tpu_v5e", "tpu_v5p", "tpu_v4", "gpu_mi300x_like",
           "gpu_h100_like"]

SHAPES = [(256, 256, 256), (512, 512, 512), (1024, 1024, 1024),
          (128, 4096, 4096), (4096, 128, 4096), (4096, 4096, 128),
          (1, 8192, 8192), (640, 1920, 2560), (48, 14336, 4096),
          (2048, 128256, 4096)]

VARIANTS = [
    dict(in_dtype="bfloat16", out_dtype="float32", epilogue=None, batch=1),
    dict(in_dtype="float32", out_dtype="float32",
         epilogue=Epilogue(bias=True, activation="gelu"), batch=1),
    dict(in_dtype="int8", out_dtype="bfloat16",
         epilogue=Epilogue(activation="swiglu_gate", residual=True),
         batch=4),
]


def _assert_breakdown_identical(a, b):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, float):
            assert va.hex() == vb.hex(), (f.name, va, vb)
        elif isinstance(va, dict):
            assert set(va) == set(vb), f.name
            for k in va:
                assert va[k].hex() == vb[k].hex(), (f.name, k)
        else:
            assert va == vb, (f.name, va, vb)


@pytest.mark.parametrize("hw_name", PRESETS)
def test_batch_bit_identical_to_scalar(hw_name):
    hw = get_hardware(hw_name)
    for kw in VARIANTS:
        clear_selection_cache()
        ref = [select_gemm_config(m, n, k, hw=hw, **kw)
               for m, n, k in SHAPES]
        clear_selection_cache()
        got = select_gemm_config_batch(SHAPES, hw=hw, **kw)
        for a, b in zip(ref, got):
            assert a.config == b.config
            assert a.n_candidates == b.n_candidates
            _assert_breakdown_identical(a.predicted, b.predicted)


def test_sources_memo_and_cold():
    """Pre-warmed shapes resolve from the memo, the rest cold — hook
    sources and results both match the scalar API's."""
    clear_selection_cache()
    hw = get_hardware("tpu_v5e")
    warm = SHAPES[:3]
    for m, n, k in warm:
        select_gemm_config(m, n, k, hw=hw)
    seen = []
    hook = lambda sel, src: seen.append((sel.problem.M, src))  # noqa: E731
    add_selection_hook(hook)
    try:
        sels = select_gemm_config_batch(SHAPES, hw=hw)
    finally:
        remove_selection_hook(hook)
    srcs = dict(s for s in seen)
    for i, (m, n, k) in enumerate(SHAPES):
        expect = "memo" if (m, n, k) in warm else "cold"
        assert srcs[m] == expect, (m, srcs[m])
        assert sels[i].config == select_gemm_config(m, n, k, hw=hw).config


def test_source_disk_roundtrip(tmp_path, monkeypatch):
    """A second 'process' (memo cleared, table reloaded) warm-starts the
    whole batch from disk with identical selections."""
    path = str(tmp_path / "selections.json")
    monkeypatch.setenv("REPRO_SELECTION_CACHE", path)
    load_selection_cache(path)
    clear_selection_cache()
    try:
        first = select_gemm_config_batch(SHAPES)
        clear_selection_cache()
        load_selection_cache(path)                   # fresh process state
        seen = []
        hook = lambda sel, src: seen.append(src)     # noqa: E731
        add_selection_hook(hook)
        try:
            second = select_gemm_config_batch(SHAPES)
        finally:
            remove_selection_hook(hook)
        assert seen == ["disk"] * len(SHAPES)
        for a, b in zip(first, second):
            assert a.config == b.config
            assert a.predicted.total.hex() == b.predicted.total.hex()
    finally:
        monkeypatch.delenv("REPRO_SELECTION_CACHE")
        unload_selection_cache()
        clear_selection_cache()


def test_bulk_flush_is_one_write(tmp_path, monkeypatch):
    """N cold shapes -> ONE merge-on-write save, not O(N) rewrites."""
    path = str(tmp_path / "selections.json")
    monkeypatch.setenv("REPRO_SELECTION_CACHE", path)
    load_selection_cache(path)
    clear_selection_cache()
    calls = []
    real = selmod.save_selection_cache
    monkeypatch.setattr(selmod, "save_selection_cache",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    try:
        select_gemm_config_batch(SHAPES)
        assert len(calls) == 1
        assert len(selmod._disk_table) == len(SHAPES)
    finally:
        monkeypatch.setattr(selmod, "save_selection_cache", real)
        monkeypatch.delenv("REPRO_SELECTION_CACHE")
        unload_selection_cache()
        clear_selection_cache()


def test_duplicate_cold_shapes_share_one_selection():
    clear_selection_cache()
    seen = []
    hook = lambda sel, src: seen.append(src)         # noqa: E731
    add_selection_hook(hook)
    try:
        sels = select_gemm_config_batch([(512, 512, 512)] * 4)
    finally:
        remove_selection_hook(hook)
    assert seen == ["cold"]                          # scored exactly once
    assert all(s is sels[0] for s in sels)


def test_four_tuple_shapes_set_per_shape_batch():
    clear_selection_cache()
    got = select_gemm_config_batch([(256, 512, 1024, 8)])
    ref = select_gemm_config(256, 512, 1024, batch=8)
    assert got[0].config == ref.config
    assert got[0].predicted.total.hex() == ref.predicted.total.hex()


def test_empty_batch_returns_empty():
    assert select_gemm_config_batch([]) == []


def test_gemm_latency_batch_rejects_nonuniform():
    a = GemmProblem(M=256, N=256, K=256, in_dtype="bfloat16")
    b = GemmProblem(M=256, N=256, K=256, in_dtype="float32")
    t = TileConfig(bm=128, bn=128, bk=128, split_k=1, group_m=1,
                   schedule="data_parallel")
    hw = get_hardware("tpu_v5e")
    with pytest.raises(ValueError):
        gemm_latency_batch([a, b], [t, t], hw)
