"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # CPU container: shim
    from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import TileConfig
from repro.kernels import flash_attention, matmul, select_attention_blocks
from repro.kernels import ref

RNG = np.random.default_rng(0)


def _mm_case(M, N, K, dt, **kw):
    a = jnp.asarray(RNG.standard_normal((M, K)), dtype=dt)
    b = jnp.asarray(RNG.standard_normal((K, N)), dtype=dt)
    want = np.asarray(ref.matmul_ref(a, b, out_dtype=jnp.float32))
    got = np.asarray(matmul(a, b, out_dtype=jnp.float32,
                            backend="pallas_interpret", **kw))
    rtol = 1e-5 if dt == jnp.float32 else 3e-2
    atol = (1e-4 if dt == jnp.float32 else 0.3) * np.sqrt(K)
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (128, 128, 128),       # single block
    (256, 512, 384),       # multi-block, ragged K
    (100, 300, 77),        # fully unaligned (padding path)
    (512, 256, 1024),      # k-major
    (1, 128, 128),         # degenerate M
    (640, 256, 256),       # non-pow2 M
])
def test_matmul_vs_ref(shape, dt):
    _mm_case(*shape, dt)


def test_matmul_selected_config_paths():
    """The analytically selected config must be numerically equivalent."""
    for (M, N, K) in [(384, 640, 512), (2048, 256, 128), (64, 2048, 2048)]:
        _mm_case(M, N, K, jnp.bfloat16)


def test_matmul_split_k():
    _mm_case(64, 128, 2048, jnp.bfloat16,
             config=TileConfig(bm=64, bn=128, bk=256, split_k=4))


def test_matmul_grouped_order():
    _mm_case(512, 256, 256, jnp.bfloat16,
             config=TileConfig(bm=128, bn=128, bk=256, group_m=4))


def test_matmul_batched_leading_dims():
    a = jnp.asarray(RNG.standard_normal((2, 3, 64, 128)), dtype=jnp.float32)
    b = jnp.asarray(RNG.standard_normal((128, 96)), dtype=jnp.float32)
    got = np.asarray(matmul(a, b, out_dtype=jnp.float32,
                            backend="pallas_interpret"))
    want = np.asarray(ref.matmul_ref(a.reshape(-1, 128), b)
                      ).reshape(2, 3, 64, 96)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    M=st.integers(1, 4).map(lambda k: k * 64 + 32),
    N=st.integers(1, 3).map(lambda k: k * 128),
    K=st.integers(1, 3).map(lambda k: k * 128 - 5),
)
def test_matmul_property_random_shapes(M, N, K):
    _mm_case(M, N, K, jnp.float32)


# ---------------------------------------------------------------------------
# Fused epilogue: interpret-mode kernel vs the pure-jnp oracle.
# ---------------------------------------------------------------------------

from repro.core import Epilogue                              # noqa: E402
from repro.kernels import expert_matmul                      # noqa: E402
from repro.kernels.ref import apply_epilogue_ref             # noqa: E402

EPILOGUES = [
    Epilogue(bias=True),
    Epilogue(activation="gelu"),
    Epilogue(activation="silu"),
    Epilogue(activation="swiglu_gate"),
    Epilogue(bias=True, activation="gelu"),
    Epilogue(residual=True),
    Epilogue(bias=True, activation="swiglu_gate", residual=True),
]


def _ep_operands(ep, M, N, dt):
    kw = {}
    if ep.bias:
        kw["bias"] = jnp.asarray(RNG.standard_normal(N), dtype=dt)
    if ep.activation == "swiglu_gate":
        kw["gate"] = jnp.asarray(RNG.standard_normal((M, N)), dtype=dt)
    if ep.residual:
        kw["residual"] = jnp.asarray(RNG.standard_normal((M, N)), dtype=dt)
    return kw


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (128, 128, 128),       # aligned
    (100, 300, 77),        # fully ragged (padding path)
    (8, 256, 512),         # skinny M
])
@pytest.mark.parametrize("ep", EPILOGUES, ids=str)
def test_matmul_epilogue_vs_ref(shape, dt, ep):
    M, N, K = shape
    a = jnp.asarray(RNG.standard_normal((M, K)), dtype=dt)
    b = jnp.asarray(RNG.standard_normal((K, N)), dtype=dt)
    kw = _ep_operands(ep, M, N, dt)
    acc = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    want = np.asarray(apply_epilogue_ref(acc, ep, **kw))
    got = np.asarray(matmul(a, b, out_dtype=jnp.float32, epilogue=ep,
                            backend="pallas_interpret", **kw))
    rtol = 1e-5 if dt == jnp.float32 else 3e-2
    atol = (1e-4 if dt == jnp.float32 else 0.3) * np.sqrt(K)
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


@pytest.mark.parametrize("ep", [Epilogue(), Epilogue(activation="gelu"),
                                Epilogue(activation="swiglu_gate")], ids=str)
def test_matmul_split_k_in_kernel(ep):
    """Split-K fuses into ONE pallas_call: no (sk, M, N) HBM partials, no
    combine reduction, epilogue still applied at the single flush."""
    M, N, K = 64, 128, 2048
    cfg = TileConfig(bm=64, bn=128, bk=256, split_k=4)
    a = jnp.asarray(RNG.standard_normal((M, K)), dtype=jnp.float32)
    b = jnp.asarray(RNG.standard_normal((K, N)), dtype=jnp.float32)
    kw = _ep_operands(ep, M, N, jnp.float32)

    fn = lambda a, b: matmul(a, b, out_dtype=jnp.float32, config=cfg,
                             epilogue=ep, backend="pallas_interpret", **kw)
    jaxpr = jax.make_jaxpr(fn)(a, b)
    calls = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "pallas_call"]
    assert len(calls) == 1
    sk_shape = (cfg.split_k, M, N)
    for eqn in jaxpr.jaxpr.eqns:
        for v in eqn.outvars:
            assert tuple(getattr(v.aval, "shape", ())) != sk_shape

    acc = jnp.matmul(a, b)
    want = np.asarray(apply_epilogue_ref(acc, ep, **kw))
    np.testing.assert_allclose(np.asarray(fn(a, b)), want,
                               rtol=1e-5, atol=1e-4 * np.sqrt(K))


@pytest.mark.parametrize("backend", ["reference", "pallas_interpret"])
def test_expert_matmul_grouped(backend):
    E, C, D, F = 4, 24, 64, 96
    x = jnp.asarray(RNG.standard_normal((E, C, D)), dtype=jnp.float32)
    wg = jnp.asarray(RNG.standard_normal((E, D, F)), dtype=jnp.float32)
    wu = jnp.asarray(RNG.standard_normal((E, D, F)), dtype=jnp.float32)
    u = expert_matmul(x, wu, backend=backend)
    got = np.asarray(expert_matmul(x, wg, epilogue="swiglu_gate", gate=u,
                                   backend=backend))
    g = jnp.einsum("ecd,edf->ecf", x, wg)
    want = np.asarray(jax.nn.silu(g) * jnp.einsum("ecd,edf->ecf", x, wu))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_matmul_out_dtype_selection_regression():
    """ops.matmul must hand the TRUE out_dtype to the selector: the seed
    inverted the conditional and priced every non-f32 output as f32
    (mis-modeling bf16 epilogue write bytes)."""
    from repro.core import clear_selection_cache
    from repro.core import selector as selector_mod
    clear_selection_cache()
    a = jnp.asarray(RNG.standard_normal((256, 256)), dtype=jnp.bfloat16)
    b = jnp.asarray(RNG.standard_normal((256, 256)), dtype=jnp.bfloat16)
    matmul(a, b, out_dtype=jnp.bfloat16, backend="pallas_interpret")
    out_dtypes = {s.problem.out_dtype for s in selector_mod._CACHE.values()}
    assert out_dtypes == {"bfloat16"}
    clear_selection_cache()
    matmul(a, b, out_dtype=jnp.float32, backend="pallas_interpret")
    out_dtypes = {s.problem.out_dtype for s in selector_mod._CACHE.values()}
    assert out_dtypes == {"float32"}


# ---------------------------------------------------------------------------
# Flash attention.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("cfg", [
    (1, 2, 2, 128, 128, 64),     # MHA
    (2, 4, 2, 256, 256, 64),     # GQA 2:1
    (1, 8, 2, 100, 300, 128),    # ragged seq (padding/mask path)
    (1, 2, 1, 384, 384, 128),    # GQA 2:1 deep
])
def test_flash_attention_vs_ref(cfg, causal):
    B, H, Hkv, Sq, Skv, d = cfg
    q = jnp.asarray(RNG.standard_normal((B, H, Sq, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, Skv, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, Skv, d)), dtype=jnp.float32)
    want = np.asarray(ref.attention_ref(q, k, v, causal=causal))
    got = np.asarray(flash_attention(q, k, v, causal=causal,
                                     backend="pallas_interpret",
                                     blocks=(128, 128)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-5)


def test_flash_attention_selected_blocks():
    bq, bkv = select_attention_blocks(4096, 4096, 128)
    assert bq >= 128 and bkv >= 128
    # selected blocks stay inside the VMEM budget by construction;
    # check determinism
    assert (bq, bkv) == select_attention_blocks(4096, 4096, 128)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 4, 256, 64)), dtype=jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), dtype=jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), dtype=jnp.bfloat16)
    want = np.asarray(ref.attention_ref(q, k, v, causal=True)
                      ).astype(np.float32)
    got = np.asarray(flash_attention(q, k, v, causal=True,
                                     backend="pallas_interpret",
                                     blocks=(128, 128))).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# jax-native chunked attention (the GSPMD/dry-run path) vs the same oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_attention_vs_ref(causal, window):
    from repro.nn.attention import chunked_attention
    if window and not causal:
        pytest.skip("sliding window implies causal")
    B, H, Hkv, S, d = 2, 4, 2, 200, 32
    q = jnp.asarray(RNG.standard_normal((B, H, S, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype=jnp.float32)
    got = np.asarray(chunked_attention(q, k, v, causal=causal,
                                       sliding_window=window,
                                       chunk_q=64, chunk_k=64))
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    kf = jnp.repeat(kf, 2, axis=1)
    vf = jnp.repeat(vf, 2, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * (d ** -0.5)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = jnp.tril(mask)
    if window:
        iq, ik = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
        mask = mask & (iq - ik < window)
    s = jnp.where(mask, s, -jnp.inf)
    want = np.asarray(jnp.einsum("bhqk,bhkd->bhqd",
                                 jax.nn.softmax(s, axis=-1), vf))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_decode_attention_gqa_packed_equivalence():
    """Packed grouped-query decode (no KV repeat — §Perf) must equal the
    repeat formulation bit-for-bit up to float tolerance."""
    from repro.nn.attention import decode_attention
    B, H, Hkv, S, d = 2, 6, 2, 64, 16
    q = jnp.asarray(RNG.standard_normal((B, H, 1, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype=jnp.float32)
    a = np.asarray(decode_attention(q, k, v, pos=jnp.int32(S - 1)))
    b = np.asarray(decode_attention(q, k, v, pos=jnp.int32(S - 1),
                                    gqa_packed=True))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_decode_attention_matches_prefix():
    from repro.nn.attention import chunked_attention, decode_attention
    B, H, Hkv, S, d = 1, 4, 2, 64, 32
    q = jnp.asarray(RNG.standard_normal((B, H, S, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype=jnp.float32)
    full = np.asarray(chunked_attention(q, k, v, causal=True,
                                        chunk_q=32, chunk_k=32))
    # decode for the last position must match the full causal row
    out = np.asarray(decode_attention(q[:, :, -1:, :], k, v,
                                      pos=jnp.int32(S - 1)))
    np.testing.assert_allclose(out[:, :, 0], full[:, :, -1],
                               rtol=1e-4, atol=1e-5)
