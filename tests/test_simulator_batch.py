"""Batched event simulator (``simulate_gemm_batch``) is a COST
optimization, not a semantic one: every per-candidate ``SimResult`` must be
bit-identical to a scalar ``simulate_gemm`` call — every float down to the
bit pattern (``float.hex``), every counter, every per-level byte split —
mirroring the batched-selection bit-identity methodology of
``tests/test_batch_selection.py``.  Covers all five presets x schedules
(``data_parallel``, ``stream_k``) x a ragged/skinny shape grid, plus the
full candidate menu (tier-1 on a small shape, ``-m slow`` on the llama3
sizes), and the simulator-primitive bugfixes that rode along: the
``simulate_compute`` reference-dtype fallback and the ``exhaustive_best``
empty-menu ValueError.
"""
import dataclasses
import math

import pytest

from repro.core import (PRESETS, TPU_V5E, GemmProblem, TileConfig,
                        candidate_tiles, exhaustive_best, get_hardware,
                        simulate_compute, simulate_gemm, simulate_gemm_batch,
                        simulate_wave)

# Ragged + skinny + square + batched: the regimes where padded-vs-real
# accounting historically diverged (shared with tests/test_wave_model.py).
SHAPES = [(1024, 4096, 4096), (1000, 1000, 1000), (100, 300, 77),
          (8, 8192, 512), (8192, 8, 512), (129, 257, 513)]

# Both schedules, grouping, and split-K — the event streams they generate
# (spans, partials, combines, fixups) all have to price identically.
CONFIGS = [TileConfig(128, 128, 64), TileConfig(64, 64, 32, group_m=4),
           TileConfig(128, 64, 64, split_k=4),
           TileConfig(128, 128, 64, schedule="stream_k"),
           TileConfig(64, 128, 32, group_m=8, schedule="stream_k"),
           TileConfig(256, 128, 32, split_k=2, group_m=4)]


def assert_result_identical(a, b, ctx=()):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, float):
            assert va.hex() == vb.hex(), ctx + (f.name, va, vb)
        elif isinstance(va, dict):
            assert set(va) == set(vb), ctx + (f.name,)
            for k in va:
                assert va[k].hex() == vb[k].hex(), ctx + (f.name, k)
        else:
            assert va == vb, ctx + (f.name, va, vb)


@pytest.mark.parametrize("hw_name", PRESETS)
def test_batch_bit_identical_to_scalar(hw_name):
    hw = get_hardware(hw_name)
    for (M, N, K) in SHAPES:
        p = GemmProblem(M=M, N=N, K=K)
        batch = simulate_gemm_batch(p, CONFIGS, hw)
        assert len(batch) == len(CONFIGS)
        for t, rb in zip(CONFIGS, batch):
            ra = simulate_gemm(p, t, hw)
            assert_result_identical(ra, rb, (hw_name, (M, N, K), t))


@pytest.mark.parametrize("hw_name", PRESETS)
def test_full_menu_bit_identical_small_shape(hw_name):
    """The oracle's actual call pattern: the FULL candidate menu of one
    shape in one batch."""
    hw = get_hardware(hw_name)
    p = GemmProblem(M=100, N=300, K=77)
    cands = candidate_tiles(p, hw)
    assert cands
    batch = simulate_gemm_batch(p, cands, hw)
    for t, rb in zip(cands, batch):
        assert_result_identical(simulate_gemm(p, t, hw), rb,
                                (hw_name, t))


@pytest.mark.slow
@pytest.mark.parametrize("hw_name", PRESETS)
def test_full_menu_bit_identical_llama3_shape(hw_name):
    hw = get_hardware(hw_name)
    p = GemmProblem(M=1024, N=4096, K=4096)
    cands = candidate_tiles(p, hw)
    batch = simulate_gemm_batch(p, cands, hw)
    for t, rb in zip(cands, batch):
        assert_result_identical(simulate_gemm(p, t, hw), rb,
                                (hw_name, t))


def test_batch_empty_candidates_returns_empty():
    assert simulate_gemm_batch(GemmProblem(M=128, N=128, K=128), [],
                               get_hardware("gpu_mi300x_like")) == []


def test_exhaustive_best_matches_scalar_argmin():
    """First-min tie-break preserved: the batch-priced argmin equals the
    scalar loop's."""
    hw = get_hardware("gpu_h100_like")
    p = GemmProblem(M=640, N=256, K=256)
    cands = candidate_tiles(p, hw)
    best_t, best_r = exhaustive_best(p, hw, cands)
    ref_t, ref_r = None, None
    for t in cands:
        r = simulate_gemm(p, t, hw)
        if ref_r is None or r.time < ref_r.time:
            ref_t, ref_r = t, r
    assert best_t == ref_t
    assert best_r.time.hex() == ref_r.time.hex()


def test_exhaustive_best_empty_candidates_raises():
    p = GemmProblem(M=384, N=512, K=640)
    with pytest.raises(ValueError, match=r"M=384 N=512 K=640"):
        exhaustive_best(p, get_hardware("tpu_v5e"), [])


def test_simulate_compute_reference_dtype_fallback():
    """bf16-less topologies fall back to the shared reference-dtype rule —
    the same default ``simulate_wave`` applies — instead of raising
    ``KeyError`` out of the calibration probes."""
    hw = TPU_V5E.with_calibration(peak_flops={"float32": 49e12})
    s = simulate_compute(hw, None, 128)
    assert math.isfinite(s) and s > hw.kernel_launch
    # Same rate the wave primitive's fallback resolves to: one wave of C
    # units on C cores at the static share == the same chip-rate atoms.
    mm, mn, mk = hw.mxu_shape
    assert math.isclose(s - hw.kernel_launch,
                        128 * (2.0 * mm * mn * mk) / 49e12, rel_tol=1e-12)
    assert math.isfinite(simulate_wave(hw, 8, 16))


def test_simulate_compute_explicit_dtype_still_exact():
    hw = get_hardware("tpu_v5e")
    mm, mn, mk = hw.mxu_shape
    s = simulate_compute(hw, "bfloat16", 64)
    assert math.isclose(s - hw.kernel_launch,
                        64 * (2.0 * mm * mn * mk) / hw.flops("bfloat16"),
                        rel_tol=1e-12)
