"""Telemetry subsystem (src/repro/obs/, DESIGN.md §11).

The load-bearing claims:

* OFF BY DEFAULT, FOR FREE — with no tracer installed and metrics
  disabled, the instrumented hot paths allocate zero Span objects, touch
  no files, and the serving engine's public stats are unchanged.
* DETERMINISTIC WHEN ON — an injected fixed clock yields a byte-identical
  trace; span ids sort in emission order.
* OBSERVES, NEVER PERTURBS — tracing an engine run changes no generated
  token and no non-timing stat; capturing simulator events changes no
  priced latency bit.
* ROUND-TRIPS — trace JSON parses back to identical spans; the Perfetto
  export is loadable Chrome-trace JSON; drift JSONL is parseable and its
  rolling fidelity gauge is 1.0 exactly when predicted == measured.
"""
import json
import os

import numpy as np
import pytest

import jax

from repro.calib.device import VirtualDevice
from repro.calib.faults import FaultPlan, FaultyDevice
from repro.configs.registry import get_config
from repro.core.bucketing import plan_buckets, step_gemms
from repro.core.hardware import PRESETS
from repro.core.selector import (add_selection_hook, remove_selection_hook,
                                 select_gemm_config)
from repro.core.simulator import simulate_gemm
from repro.kernels import ops
from repro.launch.engine import ServingEngine
from repro.nn.model import Model
from repro.obs import drift as obs_drift
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.drift import DriftMonitor, fidelity_of
from repro.obs.metrics import JsonlSink, MetricsRegistry
from repro.obs.perfetto import export_chrome_trace
from repro.runtime.metrics import MetricLogger


@pytest.fixture
def clean_obs():
    """Guarantee pristine disabled telemetry before AND after each test."""
    prev_tracer = obs_trace.set_tracer(None)
    prev_metrics = obs_metrics.enable_metrics(False)
    prev_monitor = obs_drift.set_drift_monitor(None)
    saved = obs_metrics.get_registry().snapshot()
    obs_metrics.get_registry().clear()
    yield
    obs_trace.set_tracer(prev_tracer)
    obs_metrics.enable_metrics(prev_metrics)
    obs_drift.set_drift_monitor(prev_monitor)
    obs_metrics.get_registry().clear()
    del saved


def fixed_clock(times):
    it = iter(times)
    last = [0.0]

    def clock():
        try:
            last[0] = next(it)
        except StopIteration:
            pass
        return last[0]
    return clock


# ---------------------------------------------------------------------------
# Tracing
# ---------------------------------------------------------------------------

def test_trace_roundtrip_identical_spans(clean_obs):
    tr = obs_trace.Tracer(clock=fixed_clock([0.0, 1.0, 2.0, 3.0, 4.0]))
    with tr.span("outer", cat="test", track="t0", args={"k": 1}):
        tr.event("instant", cat="test", track="t0", args={"x": [1, 2]})
    tr.counter("queue_depth", 3.0)
    tr.complete("sim", "simulator", "core0", 0.5, 0.75, {"wave": 0})
    text = tr.to_json()
    back = obs_trace.Tracer.from_json(text)
    assert back == tr.spans
    assert [s.kind for s in tr.spans] == ["span", "event", "counter", "span"]
    # sids are emission-ordered and sorted_spans is stable on start ties
    assert [s.sid for s in obs_trace.sorted_spans(tr.spans)] == [0, 3, 1, 2]


def test_trace_rejects_foreign_schema(clean_obs):
    with pytest.raises(ValueError, match="schema"):
        obs_trace.Tracer.from_json(json.dumps({"schema": "x", "spans": []}))


def test_trace_deterministic_under_fixed_clock(clean_obs):
    def emit():
        tr = obs_trace.Tracer(clock=fixed_clock([0.0, 0.5, 1.0, 1.5]))
        with tr.span("a", cat="c", track="t", args={"n": 7}):
            tr.event("b", cat="c", track="t")
        return tr.to_json()
    assert emit() == emit()
    spans = obs_trace.Tracer.from_json(emit())
    assert spans[0].start == 0.0 and spans[0].end == 1.0
    assert spans[1].start == spans[1].end == 0.5


def test_disabled_path_allocates_nothing(clean_obs, tmp_path):
    assert not obs_trace.tracing_enabled()
    before = obs_trace.Span.allocated
    for _ in range(100):
        with obs_trace.span("hot", cat="x", track="t") as s:
            assert s is None
        obs_trace.event("e", cat="x")
        obs_trace.counter("c", 1.0)
    assert obs_trace.Span.allocated == before          # zero Span objects
    assert obs_trace.span("again") is obs_trace.NULL_SPAN  # shared singleton
    # Disabled metrics helpers: global registry stays empty.
    obs_metrics.inc("nope")
    obs_metrics.set_gauge("nope_g", 1.0)
    obs_metrics.observe("nope_h", 0.5)
    assert obs_metrics.get_registry().snapshot() == {}
    assert list(tmp_path.iterdir()) == []              # and no files appear


# ---------------------------------------------------------------------------
# Metrics registry + exporters
# ---------------------------------------------------------------------------

def test_registry_instruments_and_snapshot(clean_obs):
    reg = MetricsRegistry()
    reg.counter("hits", labels={"source": "memo"}).inc(3)
    reg.counter("hits", labels={"source": "cold"}).inc()
    reg.gauge("depth").set(7.5)
    h = reg.histogram("lat", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap['hits{source="memo"}'] == 3
    assert snap['hits{source="cold"}'] == 1
    assert snap["depth"] == 7.5
    assert snap["lat"]["count"] == 3 and snap["lat"]["sum"] == 5.55
    assert snap["lat"]["buckets"] == {"0.1": 1, "1.0": 1, "+Inf": 1}
    # one name = one type, forever
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("hits")


def test_prometheus_textfile_format(clean_obs, tmp_path):
    reg = MetricsRegistry()
    reg.counter("sel_total", labels={"source": "cold"}).inc(2)
    reg.gauge("fidelity").set(0.97)
    h = reg.histogram("step_s", bounds=(0.5, 1.0))
    h.observe(0.2)
    h.observe(2.0)
    text = reg.to_prometheus()
    lines = text.splitlines()
    assert "# TYPE sel_total counter" in lines
    assert 'sel_total{source="cold"} 2' in lines
    assert "fidelity 0.97" in lines
    # histogram buckets are CUMULATIVE and end at +Inf == count
    assert 'step_s_bucket{le="0.5"} 1' in lines
    assert 'step_s_bucket{le="1.0"} 1' in lines
    assert 'step_s_bucket{le="+Inf"} 2' in lines
    assert "step_s_count 2" in lines
    path = tmp_path / "m.prom"
    reg.write_prometheus(str(path))
    assert path.read_text() == text
    assert not os.path.exists(str(path) + ".tmp")     # atomic replace


def test_registry_merge_semantics(clean_obs):
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n").inc(2)
    b.counter("n").inc(5)
    a.gauge("g").set(1.0)
    b.gauge("g").set(9.0)
    a.histogram("h", bounds=(1.0,)).observe(0.5)
    b.histogram("h", bounds=(1.0,)).observe(2.0)
    a.merge(b)
    snap = a.snapshot()
    assert snap["n"] == 7                    # counters add
    assert snap["g"] == 9.0                  # gauges take the newer value
    assert snap["h"]["count"] == 2           # histograms add bucket-wise
    assert snap["h"]["buckets"] == {"1.0": 1, "+Inf": 1}


def test_jsonl_sink_and_registry_jsonl(clean_obs, tmp_path):
    path = str(tmp_path / "sub" / "m.jsonl")
    with JsonlSink(path) as sink:             # creates parent dirs
        sink.write({"a": 1})
    reg = MetricsRegistry()
    reg.counter("k").inc()
    reg.write_jsonl(path, kind="test")        # appends
    recs = [json.loads(l) for l in open(path) if l.strip()]
    assert recs[0] == {"a": 1}
    assert recs[1]["kind"] == "test" and recs[1]["metrics"]["k"] == 1


# ---------------------------------------------------------------------------
# MetricLogger shim (runtime/metrics.py): byte-compatible legacy API
# ---------------------------------------------------------------------------

def test_metric_logger_shim_regression(clean_obs, tmp_path):
    path = str(tmp_path / "log" / "steps.jsonl")
    with MetricLogger(path, window=2) as log:     # now a context manager
        r0 = log.log(0, loss=1.5, step_time=0.5, note=object())
        r1 = log.log(1, loss=1.25, step_time=0.5)
        r2 = log.log(2, loss=1.0, step_time=0.5)
    # The original record schema, bit for bit: floats coerced, unfloatable
    # values stringified, steps_per_s over the rolling window.
    assert r0["step"] == 0 and r0["loss"] == 1.5
    assert isinstance(r0["note"], str)
    assert r0["steps_per_s"] == pytest.approx(1 / 0.5)
    assert r2["steps_per_s"] == pytest.approx(2 / 1.0)   # window=2
    recs = [json.loads(l) for l in open(path) if l.strip()]
    assert [r["step"] for r in recs] == [0, 1, 2]
    assert recs[1] == {k: v for k, v in r1.items()}
    log.close()                                           # idempotent
    # pathless logger still computes records, writes nothing
    nolog = MetricLogger()
    rec = nolog.log(5, x=2)
    assert rec["x"] == 2.0 and list(tmp_path.glob("*.jsonl")) == []


# ---------------------------------------------------------------------------
# Drift monitor
# ---------------------------------------------------------------------------

def test_fidelity_of_edge_cases(clean_obs):
    assert fidelity_of(1.0, 1.0) == 1.0
    assert fidelity_of(2.0, 1.0) == 0.5
    assert fidelity_of(1.0, 40.0) == pytest.approx(1 / 40)
    assert fidelity_of(0.0, 1.0) == 0.0
    assert fidelity_of(-1.0, 1.0) == 0.0
    assert fidelity_of(float("nan"), 1.0) == 0.0
    assert fidelity_of(1.0, float("inf")) == 0.0


def test_drift_monitor_rolling_gauge_and_jsonl(clean_obs, tmp_path):
    path = str(tmp_path / "drift.jsonl")
    reg = MetricsRegistry()
    with DriftMonitor(path=path, window=8, registry=reg) as mon:
        assert mon.fidelity() == 1.0                     # empty window
        assert mon.record(site="gemm", shape=(64, 64, 64),
                          predicted_s=1e-3, measured_s=1e-3) == 1.0
        assert reg.gauge("drift_fidelity").value == 1.0
        mon.record(site="gemm", shape=(64, 64, 64),
                   predicted_s=1e-3, measured_s=4e-2)    # 40x outlier
        assert reg.gauge("drift_fidelity").value == pytest.approx(
            (1.0 + 1 / 40) / 2)
        assert reg.counter("drift_records_total").value == 2
    recs = [json.loads(l) for l in open(path) if l.strip()]
    assert all(r["schema"] == "repro/drift/v1" for r in recs)
    assert [r["seq"] for r in recs] == [1, 2]
    assert recs[0]["fidelity"] == 1.0
    assert recs[1]["rolling_fidelity"] == pytest.approx((1.0 + 1 / 40) / 2)
    assert "time" not in recs[0]            # byte-deterministic by default


def test_drift_on_virtual_device(clean_obs, tmp_path):
    """predicted == simulated -> fidelity exactly 1.0; the analytical
    prediction itself stays >= 0.95 on a compute-bound shape; a
    FaultyDevice outlier measurement visibly dents the gauge."""
    hw = PRESETS["tpu_v5e"]
    dev = VirtualDevice(hw)
    sel = select_gemm_config(4096, 4096, 4096, hw=hw)
    sim_s = dev.gemm_time(sel.problem, sel.config)
    reg = MetricsRegistry()
    mon = DriftMonitor(path=str(tmp_path / "d.jsonl"), window=16,
                       registry=reg)
    # The simulator measured against its own pricing: exact agreement.
    f = mon.record(site="gemm", shape=(4096, 4096, 4096), topo=hw.name,
                   predicted_s=sim_s, measured_s=sim_s)
    assert f == 1.0 and mon.fidelity() == 1.0
    # The analytical model vs the event simulator (the paper's >=95% claim
    # on compute-bound shapes) — recorded through record_selection.
    f2 = mon.record_selection(sel, sim_s, topo=hw.name)
    assert f2 >= 0.95
    assert reg.gauge("drift_fidelity").value >= 0.95
    before = reg.gauge("drift_fidelity").value
    # FaultyDevice: probe_outlier=1.0 multiplies every measurement by 40x.
    faulty = FaultyDevice(VirtualDevice(hw), FaultPlan(probe_outlier=1.0))
    bad_s = faulty.gemm_time(sel.problem, sel.config)
    assert bad_s == pytest.approx(sim_s * 40.0)
    mon.record_selection(sel, bad_s, topo=hw.name)
    after = reg.gauge("drift_fidelity").value
    assert after < before and after < 0.95
    mon.close()
    recs = [json.loads(l) for l in open(tmp_path / "d.jsonl")]
    assert recs[-1]["config"]["bm"] == sel.config.bm
    assert recs[-1]["topo"] == hw.name


def test_record_selection_defaults_to_topology_fingerprint(clean_obs,
                                                           tmp_path):
    """Regression: the ``topo`` column used to default to the preset NAME
    (``sel.hardware``), which survives recalibration unchanged and cannot
    be validated — poisoning the residual corrector's training set.  It
    must default to the selection's topology fingerprint, and stay empty
    for legacy selection objects predating the field."""
    from repro.core import topology_fingerprint
    hw = PRESETS["tpu_v5e"]
    sel = select_gemm_config(256, 512, 512, hw=hw)
    assert sel.topo_fingerprint == topology_fingerprint(hw)
    path = str(tmp_path / "d.jsonl")
    with DriftMonitor(path=path, registry=obs_metrics.MetricsRegistry()) \
            as mon:
        mon.record_selection(sel, 1e-3)                # no explicit topo
        mon.record_selection(sel, 1e-3, topo="custom") # explicit still wins

        class _Legacy:                                 # pre-fingerprint sel
            problem, config, predicted = sel.problem, sel.config, \
                sel.predicted
        mon.record_selection(_Legacy(), 1e-3)
    recs = [json.loads(l) for l in open(path) if l.strip()]
    assert recs[0]["topo"] == topology_fingerprint(hw)
    assert recs[0]["topo"] != hw.name
    assert recs[1]["topo"] == "custom"
    assert recs[2]["topo"] == ""


def test_record_step_drift_noop_without_monitor(clean_obs):
    assert obs_drift.get_drift_monitor() is None
    obs_drift.record_step_drift(site="decode_step", shape=(4,),
                                predicted_s=1.0, measured_s=1.0)
    assert obs_metrics.get_registry().snapshot() == {}


# ---------------------------------------------------------------------------
# Instrumented call sites
# ---------------------------------------------------------------------------

def test_selection_emits_span_and_counter(clean_obs):
    tr = obs_trace.Tracer()
    obs_trace.set_tracer(tr)
    obs_metrics.enable_metrics(True)
    sel = select_gemm_config(384, 512, 640, hw=PRESETS["tpu_v5e"])
    evs = [s for s in tr.spans if s.name == "select_gemm_config"]
    assert len(evs) == 1
    args = evs[0].args
    assert args["shape"] == [384, 512, 640, 1]
    assert args["config"]["bm"] == sel.config.bm
    assert args["predicted_s"] == sel.predicted.total
    assert args["n_candidates"] == sel.n_candidates
    assert set(args["level_seconds"]) == set(args["level_bytes"])
    snap = obs_metrics.get_registry().snapshot()
    assert sum(v for k, v in snap.items()
               if k.startswith("selections_total")) >= 1


def test_raising_hook_bumps_error_counter_once_per_call(clean_obs):
    obs_metrics.enable_metrics(True)

    def bad_hook(sel, source):
        raise RuntimeError("boom")

    add_selection_hook(bad_hook)
    try:
        def n_errors():
            return obs_metrics.get_registry().counter(
                "selection_hook_errors", labels={"hook": "bad_hook"}).value
        with pytest.warns(RuntimeWarning, match="hook skipped") as w:
            select_gemm_config(96, 128, 160, hw=PRESETS["tpu_v5e"])
        assert n_errors() == 1                   # exactly once per call
        assert any("bad_hook" in str(x.message) for x in w)
        with pytest.warns(RuntimeWarning, match="hook skipped"):
            select_gemm_config(96, 128, 160, hw=PRESETS["tpu_v5e"])
        assert n_errors() == 2
    finally:
        remove_selection_hook(bad_hook)


def test_plan_buckets_span_and_gauges(clean_obs):
    tr = obs_trace.Tracer()
    obs_trace.set_tracer(tr)
    obs_metrics.enable_metrics(True)
    plan = plan_buckets([5, 9, 13, 7],
                        gemms=[(512, 512), (512, 2048)],
                        hw=PRESETS["tpu_v5e"], max_buckets=2)
    sp = [s for s in tr.spans if s.name == "plan_buckets"]
    assert len(sp) == 1 and sp[0].kind == "span"
    assert sp[0].args["edges"] == list(plan.edges)
    assert sp[0].args["pad_fraction"] == plan.pad_fraction
    snap = obs_metrics.get_registry().snapshot()
    assert snap["bucket_plan_pad_fraction"] == plan.pad_fraction


# ---------------------------------------------------------------------------
# Simulator event capture + Perfetto export
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", ["tpu_v5e", "gpu_h100_like"])
def test_simulator_events_do_not_change_pricing(clean_obs, preset):
    hw = PRESETS[preset]
    sel = select_gemm_config(384, 512, 768, hw=hw)
    base = simulate_gemm(sel.problem, sel.config, hw)
    events = []
    traced = simulate_gemm(sel.problem, sel.config, hw, events=events)
    assert traced.time == base.time                     # bit-identical
    assert traced.hbm_bytes == base.hbm_bytes
    assert len(events) > 0
    for track, name, t0, t1, args in events:
        assert isinstance(track, str) and isinstance(name, str)
        assert 0.0 <= t0 <= t1 <= base.time + 1e-12
        assert args is None or isinstance(args, dict)


def test_perfetto_export_loadable(clean_obs, tmp_path):
    tr = obs_trace.Tracer(clock=fixed_clock([0.0, 1e-3, 2e-3]))
    with tr.span("prefill", cat="engine", track="engine"):
        tr.event("select_gemm_config", cat="selection", track="selection")
    hw = PRESETS["tpu_v5e"]
    sel = select_gemm_config(256, 256, 256, hw=hw)
    ev = []
    simulate_gemm(sel.problem, sel.config, hw, events=ev)
    path = str(tmp_path / "trace.json")
    doc = export_chrome_trace(path, spans=tr.spans,
                              sim_timelines=[("gemm", ev)])
    on_disk = json.load(open(path))
    assert on_disk == doc
    evs = doc["traceEvents"]
    # Chrome-trace invariants: metadata names, pids 1 (measured) and
    # 2 (modeled), X events carry ts+dur in microseconds.
    pids = {e["pid"] for e in evs}
    assert pids == {1, 2}
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name"} <= {e["name"] for e in meta}
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all("ts" in e and "dur" in e for e in xs)
    assert any(e["name"].startswith("gemm:") for e in xs if e["pid"] == 2)
    assert [e for e in evs if e["ph"] == "i"]           # the instant


# ---------------------------------------------------------------------------
# Engine: tracing observes, never perturbs
# ---------------------------------------------------------------------------

def test_engine_tracing_identical_output(clean_obs):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens = [5, 9, 7]
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in lens]
    plan = plan_buckets(
        lens, gemms=step_gemms(cfg.d_model, cfg.d_ff,
                               kv_dim=cfg.num_kv_heads * cfg.head_dim,
                               vocab=cfg.vocab_size,
                               swiglu=cfg.activation == "swiglu"),
        hw=ops.get_default_hardware(), max_buckets=2)

    def run_once():
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            plan=plan, temperature=0.0, seed=0,
                            sync_every=4, quiet=True)
        for p in prompts:
            eng.submit(p, max_new_tokens=3)
        eng.warm_start()
        return eng.run()

    off = run_once()
    tr = obs_trace.Tracer()
    obs_trace.set_tracer(tr)
    obs_metrics.enable_metrics(True)
    on = run_once()
    obs_trace.set_tracer(None)
    # Identical tokens and identical non-timing stats.
    for i in off["results"]:
        assert np.array_equal(off["results"][i].tokens,
                              on["results"][i].tokens)
    for key in ("steps", "drained", "retries", "bucket_hits",
                "pad_fraction", "tokens_emitted", "queued_left"):
        assert off[key] == on[key], key
    # The traced run produced the span taxonomy DESIGN.md §11 documents.
    names = {s.name for s in tr.spans}
    assert {"warm_start", "prefill", "decode_step"} <= names
    prefills = [s for s in tr.spans if s.name == "prefill"]
    assert len(prefills) == len(prompts)
    assert all(s.kind == "span" for s in prefills)
    decodes = [s for s in tr.spans if s.name == "decode_step"]
    assert len(decodes) == on["steps"]
    # Engine counters were merge-published into the global registry.
    snap = obs_metrics.get_registry().snapshot()
    assert snap["engine_steps"] == on["steps"]
    assert snap["engine_tokens_emitted"] == on["tokens_emitted"]


def test_obs_report_skips_truncated_jsonl_tail(clean_obs, tmp_path):
    """Regression: a serving process killed mid-append leaves a truncated
    trailing JSONL line; ``tools/obs_report.py`` used to die on it with a
    JSONDecodeError.  It must summarize the records that DID land and note
    how many lines it skipped."""
    from tools.obs_report import build_report, summarize_drift
    obs = tmp_path / "obs"
    obs.mkdir()
    drift = obs / "drift.jsonl"
    with DriftMonitor(path=str(drift),
                      registry=obs_metrics.MetricsRegistry()) as mon:
        mon.record(site="gemm", shape=(64, 64, 64),
                   predicted_s=1e-3, measured_s=1e-3)
        mon.record(site="gemm", shape=(64, 64, 64),
                   predicted_s=1e-3, measured_s=2e-3)
    with open(drift, "a") as f:
        f.write('{"schema": "repro/drift/v1", "seq": 3, "site": "ge')
    reg = MetricsRegistry()
    reg.counter("engine_steps").inc(4)
    reg.write_jsonl(str(obs / "metrics.jsonl"), kind="final")
    with open(obs / "metrics.jsonl", "a") as f:
        f.write('{"kind": "final", "metr')
    report = build_report(str(obs))
    assert "## Drift — 2 records" in report
    assert "## Metrics" in report
    assert report.count("skipped 1 malformed line (truncated writer tail)") \
        == 2
    # a file reduced to ONLY a truncated line: note, no crash, no table
    lone = obs / "lone.jsonl"
    lone.write_text('{"schema": "repro/drift/v1"')
    lines = summarize_drift(str(lone))
    assert lines == ["_skipped 1 malformed line (truncated writer tail)_"]


def test_engine_quiet_suppresses_stdout(clean_obs, capsys):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=1, max_len=32,
                        temperature=0.0, seed=0, quiet=True)
    eng.submit(np.arange(4, dtype=np.int32) % cfg.vocab_size,
               max_new_tokens=2)
    eng.run()
    assert capsys.readouterr().out == ""
