"""Fallback property-testing shim for containers without ``hypothesis``.

The real library is used when importable (CI installs it); otherwise this
module provides just enough of the ``given``/``settings``/``strategies``
surface for our tests: each ``@given`` test runs against a deterministic
seeded sample of the strategy space instead of a shrinking search.

Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import functools
import inspect
import random

_N_EXAMPLES = 12


class _Strategy:
    def __init__(self, sample):
        self.sample = sample            # rnd -> value

    def map(self, fn):
        return _Strategy(lambda rnd: fn(self.sample(rnd)))


def integers(min_value, max_value):
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value, max_value, allow_nan=False, allow_infinity=False):
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def lists(elements, min_size=0, max_size=16):
    def sample(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.sample(rnd) for _ in range(n)]
    return _Strategy(sample)


class _St:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    lists = staticmethod(lists)


st = _St()
strategies = st


def settings(*args, **kwargs):
    return lambda fn: fn


def given(*arg_strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rnd = random.Random(0)
            for _ in range(_N_EXAMPLES):
                drawn_args = [s.sample(rnd) for s in arg_strats]
                drawn_kw = {k: s.sample(rnd) for k, s in kw_strats.items()}
                fn(*drawn_args, *args, **drawn_kw, **kwargs)
        # Hide the strategy-supplied params from pytest's fixture resolution.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[len(arg_strats):]
        params = [p for p in params if p.name not in kw_strats]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco
