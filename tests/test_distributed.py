"""Distribution-layer tests, run in a subprocess with 8 fake CPU devices
(XLA device count locks at first jax init, so the main pytest process
must stay at 1 device)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(600)
def test_distributed_suite_on_8_fake_devices():
    worker = os.path.join(os.path.dirname(__file__), "distributed_worker.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # the worker sets its own
    proc = subprocess.run(
        [sys.executable, worker], env=env, capture_output=True, text=True,
        timeout=560)
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-4000:]
    for marker in ("spec_divisibility_drop", "tp_matmul", "compressed_psum",
                   "elastic_restore", "sharded_train_step"):
        assert f"CHECK_OK {marker}" in out, out[-4000:]
    assert "ALL_DISTRIBUTED_OK" in out
