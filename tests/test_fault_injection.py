"""The ISSUE 6 fault matrix: every injected fault class must leave the
pipeline producing numerically correct output via the documented fallback
ladder (DESIGN.md §9), with every downgrade observable through selection
hooks and the same seed reproducing the same fault sequence.

Fault classes covered: probe timeout/hang (watchdog), NaN / sign-flipped /
outlier measurements (probe guards + robust fit + oracle guards), tampered
and truncated calibrated-topology artifacts (quarantine), corrupt selection
cache (mid-write truncation and parseable-but-illegal entries), kernel
compile/placement failures (fallback ladder), and mid-decode transients +
preemption drain (degraded serving).

The CI ``chaos`` job runs this file across all five presets with
``REPRO_CHAOS_SEEDS`` widening the seeded sweep.
"""
import json
import math
import os
import warnings
from dataclasses import replace as _dc_replace

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core.selector as selmod
from repro.calib import (FaultPlan, FaultyDevice, InjectedCompileError,
                         InjectedTransientError, VirtualDevice,
                         corrupt_cache_entry, decode_injector, fit_topology,
                         launch_injector, oracle_best, run_probes,
                         scripted_injector, tamper_artifact_fingerprint,
                         truncate_file)
from repro.calib.probes import probe_latency
from repro.core import (PRESETS, TPU_V5E, DegradedModeWarning, GemmProblem,
                        TileConfig, add_selection_hook, calibrated_topology_json,
                        candidate_tiles, clear_selection_cache, fits_placement,
                        get_hardware, load_calibrated_topology_guarded,
                        load_selection_cache, remove_selection_hook,
                        safe_config, select_gemm_config,
                        unload_selection_cache, validate_selection)
from repro.core.selector import fallback_ladder, rank_candidates
from repro.kernels import ops

CHAOS_SEEDS = range(int(os.environ.get("REPRO_CHAOS_SEEDS", "2")))


@pytest.fixture
def hooked():
    """Record every selection-hook emission for the duration of a test."""
    events = []

    def hook(sel, source):
        events.append((source, sel.config))

    add_selection_hook(hook)
    yield events
    remove_selection_hook(hook)


@pytest.fixture
def injector():
    """Install a launch fault injector; always restore the previous one."""
    installed = []

    def install(fn):
        installed.append(ops.set_launch_fault_injector(fn))
        return fn

    yield install
    while installed:
        ops.set_launch_fault_injector(installed.pop())


def _matmul_vs_reference(hw, *, seed=0, M=128, N=128, K=256):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((M, K)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype=jnp.float32)
    got = np.asarray(ops.matmul(a, b, out_dtype=jnp.float32, hw=hw,
                                backend="pallas_interpret"))
    want = np.asarray(ops.matmul(a, b, out_dtype=jnp.float32, hw=hw,
                                 backend="reference"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * np.sqrt(K))
    return got


# ---------------------------------------------------------------------------
# FaultPlan: seeded determinism
# ---------------------------------------------------------------------------


def _probe_workload(plan):
    dev = FaultyDevice(VirtualDevice(TPU_V5E), plan)
    for i in range(12):
        dev.stream_time(float(1 << (20 + i % 3)), 1 << 20, 16)
        dev.compute_time("bfloat16", 256 + i)
        dev.wave_time(8 + i, 64, "bfloat16")
    return list(plan.log)


def test_fault_plan_same_seed_same_fault_sequence():
    mk = lambda s: FaultPlan(seed=s, probe_nan=0.25, probe_outlier=0.2,
                             probe_signflip=0.15)
    plan = mk(7)
    log1 = _probe_workload(plan)
    assert log1, "rates this high must fire at least once in 36 calls"
    plan.reset()
    assert plan.log == [] and _probe_workload(plan) == log1
    assert _probe_workload(mk(7)) == log1       # fresh plan, same seed
    assert _probe_workload(mk(8)) != log1       # different seed


def test_faulty_device_fault_shapes():
    """Each kind corrupts the honest value the documented way."""
    honest = VirtualDevice(TPU_V5E)
    truth = honest.stream_time(1 << 20, 1 << 20, 16)

    def one(kind):
        plan = FaultPlan(seed=0, outlier_factor=40.0, **{kind: 1.0})
        return FaultyDevice(VirtualDevice(TPU_V5E), plan) \
            .stream_time(1 << 20, 1 << 20, 16)

    assert math.isnan(one("probe_nan"))
    assert one("probe_signflip") == pytest.approx(-truth)
    assert one("probe_outlier") == pytest.approx(40.0 * truth)


# ---------------------------------------------------------------------------
# Probe watchdog + degraded-mode fit
# ---------------------------------------------------------------------------


def test_watchdog_drops_hanging_probe_samples():
    plan = FaultPlan(seed=0, probe_timeout=1.0, hang_s=0.25)
    dev = FaultyDevice(VirtualDevice(TPU_V5E), plan)
    sweep = probe_latency(dev, TPU_V5E, deadline_s=0.02)
    assert sweep.samples == ()                  # every sample hung -> dropped
    assert sweep.params["n_dropped"] == 6
    # Without a deadline the hang is simply waited out (no watchdog).
    plan.reset()
    sweep2 = probe_latency(dev, TPU_V5E, targets=(1e-6,), deadline_s=None)
    assert len(sweep2.samples) == 1


def test_degraded_fit_keeps_preset_constants_under_total_probe_loss():
    """All probes hang: allow_degraded keeps every preset constant and
    records why, instead of aborting calibration."""
    plan = FaultPlan(seed=1, probe_timeout=1.0, hang_s=0.1)
    dev = FaultyDevice(VirtualDevice(TPU_V5E), plan)
    with pytest.raises((ValueError, IndexError, KeyError)):
        fit_topology(TPU_V5E, dev, dtypes=("bfloat16",), deadline_s=0.02)
    plan.reset()
    res = fit_topology(TPU_V5E, dev, dtypes=("bfloat16",), deadline_s=0.02,
                       allow_degraded=True)
    assert res.fitted == {}                     # nothing could be fitted
    assert "kernel_launch" in res.degraded
    assert res.topology.kernel_launch == TPU_V5E.kernel_launch
    assert res.topology.peak_flops == TPU_V5E.peak_flops
    assert "degraded" in res.provenance()


def test_fit_accurate_under_nan_and_signflip_poison():
    """NaN and sign-flipped measurements are dropped at the probe layer,
    so the fit sees only honest samples and must land on the planted
    truth."""
    plan = FaultPlan(seed=5, probe_nan=0.15, probe_signflip=0.1)
    dev = FaultyDevice(VirtualDevice(TPU_V5E), plan)
    res = fit_topology(TPU_V5E, dev, dtypes=("bfloat16",),
                       allow_degraded=True)
    errs = res.compare_to(TPU_V5E)
    fitted_errs = {k: errs[k] for k in res.fitted}
    assert fitted_errs, "a fault rate this low must leave fittable sweeps"
    for k, e in fitted_errs.items():
        assert e < 0.1, f"{k} off by {e:.3f} after dropping poison"


def test_fit_completes_under_outliers():
    """Outliers pass the probe guards (plausible values are the robust
    fit's problem): calibration must complete in degraded mode with every
    constant valid — a 40x outlier in a 4-point sweep is past Theil-Sen's
    breakdown, so accuracy there is not promised, only sanity."""
    plan = FaultPlan(seed=6, probe_outlier=0.15, outlier_factor=40.0)
    dev = FaultyDevice(VirtualDevice(TPU_V5E), plan)
    res = fit_topology(TPU_V5E, dev, dtypes=("bfloat16",),
                       allow_degraded=True)
    t = res.topology
    assert t.kernel_launch >= 0.0 and t.dma_fixed >= 0.0
    for lvl in t.levels:
        assert math.isfinite(lvl.bandwidth) and lvl.bandwidth > 0.0
    for v in t.peak_flops.values():
        assert math.isfinite(v) and v > 0.0


def test_oracle_skips_poisoned_gemm_measurements():
    """A sign-flipped (negative) timing would WIN the argmin; the oracle
    must skip non-finite/non-positive measurements."""
    p = GemmProblem(M=256, N=256, K=256)
    cands = candidate_tiles(p, TPU_V5E)[:10]
    plan = FaultPlan(seed=2, probe_signflip=0.3, probe_nan=0.2)
    dev = FaultyDevice(VirtualDevice(TPU_V5E), plan)
    best_t, best_s, _ = oracle_best(p, TPU_V5E, dev, cands, prune=False)
    assert best_t is not None
    assert np.isfinite(best_s) and best_s > 0.0


# ---------------------------------------------------------------------------
# Calibrated-topology artifacts: quarantine + degraded serving constants
# ---------------------------------------------------------------------------


def _write_artifact(tmp_path, residuals=None):
    path = str(tmp_path / "topo.json")
    with open(path, "w") as f:
        f.write(calibrated_topology_json(
            get_hardware("tpu_v5p"),
            {"residuals": residuals or {"kernel_launch": 0.01}}))
    return path


def test_tampered_artifact_quarantines_and_falls_back(tmp_path):
    path = _write_artifact(tmp_path)
    tamper_artifact_fingerprint(path)
    with pytest.warns(DegradedModeWarning, match="quarantined"):
        topo, prov = load_calibrated_topology_guarded(path, TPU_V5E)
    assert topo is TPU_V5E
    assert "fingerprint" in prov["degraded"]
    assert prov["quarantined"] == path + ".quarantined"
    assert not os.path.exists(path)             # moved aside, not deleted
    assert os.path.exists(prov["quarantined"])  # evidence preserved


def test_truncated_artifact_quarantines_and_falls_back(tmp_path):
    path = _write_artifact(tmp_path)
    truncate_file(path, frac=0.5)               # mid-write crash remnant
    with pytest.warns(DegradedModeWarning):
        topo, prov = load_calibrated_topology_guarded(path, TPU_V5E)
    assert topo is TPU_V5E and prov["degraded"]
    assert os.path.exists(path + ".quarantined")


def test_out_of_tolerance_residuals_quarantine(tmp_path):
    path = _write_artifact(tmp_path, residuals={"dma_fixed": 0.9})
    with pytest.warns(DegradedModeWarning, match="residual"):
        topo, prov = load_calibrated_topology_guarded(
            path, TPU_V5E, max_residual=0.5)
    assert topo is TPU_V5E and "dma_fixed" in prov["degraded"]


def test_healthy_artifact_loads_clean(tmp_path):
    path = _write_artifact(tmp_path)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DegradedModeWarning)
        topo, prov = load_calibrated_topology_guarded(path, TPU_V5E)
    assert topo.name == "tpu_v5p" and "degraded" not in prov
    assert os.path.exists(path)                 # not quarantined


def test_missing_artifact_degrades_without_quarantine(tmp_path):
    with pytest.warns(DegradedModeWarning, match="unreadable"):
        topo, prov = load_calibrated_topology_guarded(
            str(tmp_path / "nope.json"), TPU_V5E)
    assert topo is TPU_V5E and prov["quarantined"] is None


# ---------------------------------------------------------------------------
# Selection cache corruption
# ---------------------------------------------------------------------------


@pytest.fixture
def cache_path(tmp_path, monkeypatch):
    path = str(tmp_path / "selections.json")
    monkeypatch.setenv("REPRO_SELECTION_CACHE", path)
    load_selection_cache(path)
    clear_selection_cache()
    yield path
    monkeypatch.delenv("REPRO_SELECTION_CACHE")
    unload_selection_cache()
    clear_selection_cache()


def test_midwrite_truncated_cache_recovers(cache_path):
    select_gemm_config(1024, 1024, 1024)
    truncate_file(cache_path, frac=0.3)
    assert load_selection_cache(cache_path) == 0      # unreadable -> empty
    clear_selection_cache()
    sel = select_gemm_config(1024, 1024, 1024)        # re-selects cleanly
    assert fits_placement(sel.config, "bfloat16", TPU_V5E)


def test_tampered_cache_entry_falls_through_to_cold(cache_path, hooked):
    baseline = select_gemm_config(1024, 1024, 1024)
    assert corrupt_cache_entry(cache_path, bm=12288) == 1   # non-pow2, huge
    clear_selection_cache()
    load_selection_cache(cache_path)
    sel = select_gemm_config(1024, 1024, 1024)
    # The illegal rehydrated entry must NOT be served: cold re-scoring
    # reproduces the legal argmin instead.
    assert hooked[-1][0] == "cold"
    assert sel.config == baseline.config
    assert validate_selection(sel.problem, sel.config, TPU_V5E) is None


# ---------------------------------------------------------------------------
# Guarded launch: validation + fallback ladder
# ---------------------------------------------------------------------------


def test_validate_selection_catches_corrupt_configs():
    p = GemmProblem(M=512, N=512, K=512)
    ok = select_gemm_config(512, 512, 512).config
    assert validate_selection(p, ok, TPU_V5E) is None
    bad_pow2 = _dc_replace(ok, bm=12288)
    assert "power of two" in validate_selection(p, bad_pow2, TPU_V5E)
    bad_fit = _dc_replace(ok, bm=8192, bn=8192, bk=8192)
    assert "budget" in validate_selection(p, bad_fit, TPU_V5E)
    bad_align = _dc_replace(ok, bn=32)          # lane width is 128
    assert "misaligned" in validate_selection(p, bad_align, TPU_V5E)


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_safe_config_is_safe_everywhere(preset):
    hw = get_hardware(preset)
    p = GemmProblem(M=384, N=384, K=384)
    t = safe_config(p, hw)
    assert validate_selection(p, t, hw) is None


def test_fallback_ladder_is_deterministic_and_fresh():
    p = GemmProblem(M=512, N=512, K=512)
    primary = select_gemm_config(512, 512, 512).config
    rungs = list(fallback_ladder(p, TPU_V5E, primary))
    assert [r for _, r in rungs] in (["next", "safe"], ["next"])
    for sel, _ in rungs:
        assert sel.config != primary
    assert rungs == list(fallback_ladder(p, TPU_V5E, primary))
    # "next" really is the best-ranked non-primary candidate.
    ranked = [t for t, _ in rank_candidates(p, TPU_V5E)]
    assert rungs[0][0].config == next(t for t in ranked if t != primary)


def test_compile_failure_steps_to_next_ranked(hooked, injector):
    injector(scripted_injector([InjectedCompileError("lowering failed")]))
    with pytest.warns(DegradedModeWarning):
        _matmul_vs_reference(TPU_V5E, seed=10)
    falls = [s for s, _ in hooked if s.startswith("fallback")]
    assert falls == ["fallback:next"]


def test_two_compile_failures_step_to_safe(hooked, injector):
    injector(scripted_injector([InjectedCompileError("x"),
                                InjectedCompileError("y")]))
    with pytest.warns(DegradedModeWarning):
        _matmul_vs_reference(TPU_V5E, seed=11)
    falls = [s for s, _ in hooked if s.startswith("fallback")]
    assert falls == ["fallback:next", "fallback:safe"]


def test_total_launch_failure_serves_reference(hooked, injector):
    injector(scripted_injector([InjectedCompileError(f"rung {i}")
                                for i in range(8)]))
    with pytest.warns(DegradedModeWarning):
        _matmul_vs_reference(TPU_V5E, seed=12)
    falls = [s for s, _ in hooked if s.startswith("fallback")]
    assert falls[-1] == "fallback:reference"


def test_transient_launch_fault_retries_in_place(hooked, injector):
    injector(scripted_injector(
        [InjectedTransientError("transient: DMA hiccup")]))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DegradedModeWarning)
        _matmul_vs_reference(TPU_V5E, seed=13)
    assert not [s for s, _ in hooked if s.startswith("fallback")]


def test_explicit_config_never_silently_swapped(injector):
    """A user-passed config is a contract: transients retry, deterministic
    failures propagate — no ladder."""
    cfg = TileConfig(bm=128, bn=128, bk=128, split_k=1, group_m=1,
                     schedule="data_parallel")
    a = jnp.ones((128, 128), jnp.float32)
    injector(scripted_injector([InjectedCompileError("lowering failed")]))
    with pytest.raises(InjectedCompileError):
        ops.matmul(a, a, config=cfg, backend="pallas_interpret")
    ops.set_launch_fault_injector(
        scripted_injector([InjectedTransientError("transient: x")]))
    out = ops.matmul(a, a, config=cfg, backend="pallas_interpret")
    assert np.asarray(out).shape == (128, 128)


def test_poisoned_memo_is_revalidated_before_launch(hooked, injector):
    """A memo entry poisoned into a placement-busting config (a buggy hook,
    a cosmic-ray cache) is caught by pre-launch validation and the ladder
    serves a correct result."""
    clear_selection_cache()
    sel = select_gemm_config(128, 128, 256, in_dtype="float32",
                             out_dtype="float32")
    (key,) = selmod._CACHE
    poisoned = _dc_replace(sel, config=_dc_replace(
        sel.config, bm=8192, bn=8192, bk=8192))
    selmod._CACHE[key] = poisoned
    try:
        with pytest.warns(DegradedModeWarning, match="rejected"):
            _matmul_vs_reference(TPU_V5E, seed=14)
        assert [s for s, _ in hooked if s.startswith("fallback")]
    finally:
        clear_selection_cache()


# ---------------------------------------------------------------------------
# Chaos sweep: seeded fault plans x all presets (the CI chaos job widens
# CHAOS_SEEDS).  Whatever faults fire, the result must match the reference
# and the fault sequence must replay identically under the same seed.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preset", sorted(PRESETS))
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_matmul_correct_under_any_seed(preset, seed, injector):
    hw = get_hardware(preset)
    plan = FaultPlan(seed=seed, launch_compile=0.4, launch_transient=0.3)
    injector(launch_injector(plan))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DegradedModeWarning)
        out1 = _matmul_vs_reference(hw, seed=seed)
        log1 = list(plan.log)
        plan.reset()
        out2 = _matmul_vs_reference(hw, seed=seed)
    assert plan.log == log1                     # same seed, same faults
    np.testing.assert_array_equal(out1, out2)


# ---------------------------------------------------------------------------
# Degraded serving: transient decode fault + preemption drain + quarantined
# topology artifact, in one end-to-end pass against the clean run.
# ---------------------------------------------------------------------------


def _serve_args(**over):
    import argparse
    base = dict(arch="mamba2-370m", smoke=True, batch=2, prompt_len=16,
                gen=8, temperature=0.0, tp=1, seed=0, topology=None)
    base.update(over)
    return argparse.Namespace(**base)


def test_degraded_serving_matches_clean_prefix(tmp_path):
    from repro.launch.serve import run_serving

    clean = run_serving(_serve_args())
    assert clean["steps"] == 7 and not clean["drained"]

    art = _write_artifact(tmp_path)
    tamper_artifact_fingerprint(art)

    fired = []

    def fault(step, guard):
        if step == 2 and not fired:
            fired.append(step)
            raise InjectedTransientError("transient: injected decode fault")
        if step == 5:
            guard.request_stop()

    try:
        with pytest.warns(DegradedModeWarning):
            faulted = run_serving(_serve_args(topology=art),
                                  decode_fault=fault)
    finally:
        ops.set_default_hardware(None)

    assert faulted["degraded"]                  # artifact was quarantined
    assert faulted["retries"] == 1 and fired == [2]
    assert faulted["drained"] and faulted["steps"] == 6
    # Greedy decoding: the degraded run's tokens are a prefix of the clean
    # run's — transients and the drain changed nothing numerically.
    np.testing.assert_array_equal(
        faulted["tokens"], clean["tokens"][:, :faulted["steps"] + 1])


def test_serving_decode_injector_plan_is_deterministic():
    """decode_injector draws reproduce under reset — the serving chaos
    path inherits FaultPlan's determinism."""
    plan = FaultPlan(seed=9, decode_transient=0.5)
    inj = decode_injector(plan)
    seq1 = []
    for i in range(10):
        try:
            inj(i, None)
            seq1.append(False)
        except InjectedTransientError:
            seq1.append(True)
    plan.reset()
    seq2 = []
    for i in range(10):
        try:
            inj(i, None)
            seq2.append(False)
        except InjectedTransientError:
            seq2.append(True)
    assert seq1 == seq2 and any(seq1)
