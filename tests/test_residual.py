"""Learned residual corrector on the drift stream (DESIGN.md §12).

The load-bearing claims:

* OFF = NONEXISTENT — with no corrector installed, every selection is
  bit-identical to the goldens (hex totals), for every preset.
* TRAINING-SET HYGIENE — drift rows are keyed by topology fingerprint;
  name-shaped / stale / malformed / config-less rows are counted and
  refused, never silently fit.
* ARTIFACT SEMANTICS — ``repro/residual/v1`` round-trips; a tampered
  model block is rejected by digest; the guarded loader quarantines
  corrupt artifacts (evidence) but only warns on stale-fingerprint ones.
* THE FLYWHEEL CLOSES — a corrector fit on drift + sweep rows raises
  held-out %-of-oracle fidelity on shapes it never saw, for every
  preset, and never sinks the worst row.
"""
import functools
import json
import math
import os

import numpy as np
import pytest

from repro.calib import (VirtualDevice, fidelity_sweep, fit_residual,
                         load_residual, load_residual_guarded, residual_pick,
                         rows_from_drift, rows_from_sweep,
                         scaled_llama3_shapes)
from repro.calib.residual import (FEATURE_NAMES, MIN_FIT_ROWS,
                                  RESIDUAL_SCHEMA, ResidualRow)
from repro.core import (PRESETS, TPU_V5E, GemmProblem, add_selection_hook,
                        clear_selection_cache, remove_selection_hook,
                        select_gemm_config, select_gemm_config_batch,
                        select_topk, set_residual_corrector,
                        topology_fingerprint)
from repro.core.latency import gemm_latency
from repro.core.topology import DegradedModeWarning
from repro.obs.drift import DriftMonitor
from repro.obs.metrics import MetricsRegistry

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "llama3_selections.json")

# Smoke-scale sweeps: train on t=1024 shapes, hold out t=512 — the same
# split tools/fit_residual.py --check-against-oracle uses.
SCALE = 8
TRAIN_TOKENS = (1024,)
HELDOUT_TOKENS = (512,)


@pytest.fixture
def no_residual():
    """No corrector installed before the test; restore + cold memo after."""
    prev = set_residual_corrector(None)
    clear_selection_cache()
    yield
    set_residual_corrector(prev)
    clear_selection_cache()


@functools.lru_cache(maxsize=None)
def _fitted(preset: str):
    """A corrector fit from the virtual-device finalist sweep (cached —
    the fit is deterministic, so tests may share it)."""
    hw = PRESETS[preset]
    shapes = [(M, N, K) for (_, M, N, K) in
              scaled_llama3_shapes(tokens=TRAIN_TOKENS, scale=SCALE)]
    rows = rows_from_sweep(hw, VirtualDevice(hw), shapes)
    assert len(rows) >= MIN_FIT_ROWS
    return fit_residual(rows, hw, sources=["test-sweep"])


# ---------------------------------------------------------------------------
# Artifact: round-trip, tamper rejection, quarantine semantics.
# ---------------------------------------------------------------------------

def test_artifact_round_trip():
    corr = _fitted("tpu_v5e")
    back = load_residual(corr.to_json())
    assert back.feature_names == FEATURE_NAMES
    assert back.fingerprint == topology_fingerprint(TPU_V5E)
    assert back.content_fingerprint() == corr.content_fingerprint()
    assert back.provenance["n_rows"] == corr.provenance["n_rows"]
    assert back.provenance["sources"] == ["test-sweep"]
    # identical corrections, bit for bit
    p = GemmProblem(M=512, N=512, K=1024)
    configs, totals, _ = select_topk(p, TPU_V5E, 6)
    assert np.array_equal(back.correct(p, configs, totals, TPU_V5E),
                          corr.correct(p, configs, totals, TPU_V5E))


def test_load_rejects_tampered_wrong_schema_and_nameless():
    corr = _fitted("tpu_v5e")
    doc = corr.to_dict()
    assert doc["schema"] == RESIDUAL_SCHEMA
    doc["model"]["weights"][0] += 0.25          # edit weights after the fit
    with pytest.raises(ValueError, match="digest"):
        load_residual(json.dumps(doc))
    doc2 = corr.to_dict()
    doc2["schema"] = "repro/other/v1"
    with pytest.raises(ValueError, match="schema"):
        load_residual(json.dumps(doc2))
    # a preset NAME where the topology fingerprint belongs is refused —
    # the same hygiene rule the drift fitter applies
    doc3 = corr.to_dict()
    doc3["provenance"]["fingerprint"] = "tpu_v5e"
    with pytest.raises(ValueError, match="fingerprint"):
        load_residual(json.dumps(doc3))


def test_guarded_quarantines_corrupt_artifact(tmp_path):
    doc = _fitted("tpu_v5e").to_dict()
    doc["model"]["intercept"] += 1.0
    path = tmp_path / "tpu_v5e.residual.json"
    path.write_text(json.dumps(doc))
    with pytest.warns(DegradedModeWarning, match="quarantined"):
        corr, info = load_residual_guarded(str(path))
    assert corr is None
    assert info["quarantined"] == str(path) + ".quarantined"
    assert os.path.exists(info["quarantined"])  # evidence kept ...
    assert not path.exists()                    # ... moved, not copied


def test_guarded_stale_fingerprint_warns_without_quarantine(tmp_path):
    path = tmp_path / "r.json"
    path.write_text(_fitted("tpu_v5e").to_json())
    with pytest.warns(DegradedModeWarning, match="stale"):
        corr, info = load_residual_guarded(
            str(path), expect=PRESETS["gpu_h100_like"])
    assert corr is None
    assert info["quarantined"] is None
    assert path.exists()        # right artifact for another host: untouched
    # the same file loads fine against the topology it was fit for
    corr2, prov = load_residual_guarded(str(path), expect=TPU_V5E)
    assert corr2 is not None and prov["n_rows"] >= MIN_FIT_ROWS


def test_guarded_missing_file_degrades_without_sidecar(tmp_path):
    with pytest.warns(DegradedModeWarning, match="unreadable"):
        corr, info = load_residual_guarded(str(tmp_path / "absent.json"))
    assert corr is None and info["quarantined"] is None
    assert list(tmp_path.iterdir()) == []


# ---------------------------------------------------------------------------
# Training-set hygiene: the drift stream consumer.
# ---------------------------------------------------------------------------

def test_rows_from_drift_hygiene(tmp_path):
    hw = TPU_V5E
    fp = topology_fingerprint(hw)
    sel = select_gemm_config(512, 512, 1024, hw=hw)
    meas = VirtualDevice(hw).gemm_time(sel.problem, sel.config)
    path = str(tmp_path / "drift.jsonl")
    with DriftMonitor(path=path, registry=MetricsRegistry()) as mon:
        mon.record_selection(sel, meas)                      # kept
        mon.record_selection(sel, meas, topo="tpu_v5e")      # name-shaped
        mon.record_selection(sel, meas, topo="0" * 16)       # stale fp
        mon.record(site="decode_step", shape=(4,), topo=fp,
                   predicted_s=1e-3, measured_s=1e-3)        # config-less
        mon.record_selection(sel, -1.0)                      # bad measure
    with open(path, "a") as f:
        f.write('{"schema": "repro/drift/v1", "seq": 6')     # killed writer
    with pytest.warns(UserWarning, match="preset name"):
        rows, stats = rows_from_drift(path, fingerprint=fp)
    assert stats == {"total": 6, "kept": 1, "malformed": 1, "no_config": 1,
                     "bad_measurement": 1, "name_shaped_topo": 1,
                     "fingerprint_mismatch": 1}
    (row,) = rows
    assert (row.M, row.N, row.K) == (512, 512, 1024)
    assert row.config["bm"] == sel.config.bm
    assert math.isclose(row.log_ratio,
                        math.log(meas / sel.predicted.total))


def test_fit_refuses_too_few_rows():
    row = ResidualRow(M=256, N=256, K=256, batch=1,
                      config={"bm": 128, "bn": 128, "bk": 128},
                      predicted_s=1e-3, measured_s=1.1e-3)
    with pytest.raises(ValueError, match="too few rows"):
        fit_residual([row] * (MIN_FIT_ROWS - 1), TPU_V5E)


# ---------------------------------------------------------------------------
# Selector integration: OFF is bit-identical, ON is an opt-in re-ranking.
# ---------------------------------------------------------------------------

def test_corrector_off_selections_bit_identical_to_goldens(no_residual):
    """With no corrector installed the residual subsystem must be
    indistinguishable from not existing: every preset's llama3-8B
    selection reproduces the golden config AND the golden float64 latency
    bit for bit (hex)."""
    from benchmarks.llama3_shapes import llama3_gemms
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for hw_name in sorted(PRESETS):
        hw = PRESETS[hw_name]
        for (name, M, N, K) in llama3_gemms("8b"):
            s = select_gemm_config(M, N, K, hw=hw)
            want = golden[hw_name][name]
            got_cfg = {"bm": s.config.bm, "bn": s.config.bn,
                       "bk": s.config.bk, "split_k": s.config.split_k,
                       "group_m": s.config.group_m,
                       "schedule": s.config.schedule}
            assert got_cfg == want["config"], (hw_name, name)
            assert s.n_candidates == want["n_candidates"], (hw_name, name)
            assert s.predicted.total.hex() == want["total_hex"], \
                (hw_name, name)


def test_select_topk_head_is_the_selection(no_residual):
    for (_, M, N, K) in scaled_llama3_shapes(tokens=(512,), scale=4):
        p = GemmProblem(M=M, N=N, K=K)
        configs, totals, n = select_topk(p, TPU_V5E, 6)
        s = select_gemm_config(M, N, K, hw=TPU_V5E)
        assert configs[0] == s.config
        assert totals[0] == s.predicted.total      # same pricing, same bits
        assert n == s.n_candidates
        assert len(set(configs)) == len(configs)   # no duplicate finalists
        assert all(t >= totals[0] for t in totals[1:])


def test_residual_source_memo_and_analytical_pricing(no_residual):
    corr = _fitted("tpu_v5e")
    events = []
    hook = lambda sel, src: events.append(src)         # noqa: E731
    add_selection_hook(hook)
    try:
        set_residual_corrector(corr)
        s1 = select_gemm_config(384, 512, 640, hw=TPU_V5E)
        s2 = select_gemm_config(384, 512, 640, hw=TPU_V5E)
        assert events == ["residual", "memo"]
        assert s2 is s1
        assert s1.topo_fingerprint == topology_fingerprint(TPU_V5E)
        # the pick comes from the top-F analytical slate ...
        configs, _, n = select_topk(GemmProblem(M=384, N=512, K=640),
                                    TPU_V5E, corr.top_f)
        assert s1.config in configs and s1.n_candidates == n
        # ... and its attached price stays the ANALYTICAL breakdown, so
        # drift rows keep measuring the model, not the corrector
        assert s1.predicted.total == \
            gemm_latency(s1.problem, s1.config, TPU_V5E).total
    finally:
        remove_selection_hook(hook)


def test_fingerprint_mismatch_falls_back_to_analytical(no_residual):
    hw = PRESETS["gpu_h100_like"]
    base = select_gemm_config(768, 768, 768, hw=hw)
    clear_selection_cache()
    events = []
    hook = lambda sel, src: events.append(src)         # noqa: E731
    add_selection_hook(hook)
    try:
        set_residual_corrector(_fitted("tpu_v5e"))     # wrong topology
        s = select_gemm_config(768, 768, 768, hw=hw)
        assert events == ["cold"]                      # pure analytical
        assert s.config == base.config
        assert s.predicted.total.hex() == base.predicted.total.hex()
    finally:
        remove_selection_hook(hook)


def test_batch_selection_matches_scalar_under_corrector(no_residual):
    corr = _fitted("tpu_v5e")
    set_residual_corrector(corr)
    shapes = [(256, 512, 512), (384, 512, 640), (512, 1024, 512)]
    batch_sels = select_gemm_config_batch(shapes, hw=TPU_V5E)
    clear_selection_cache()
    for (M, N, K), bs in zip(shapes, batch_sels):
        ss = select_gemm_config(M, N, K, hw=TPU_V5E)
        assert bs.config == ss.config, (M, N, K)
        assert bs.predicted.total == ss.predicted.total


def test_residual_pick_matches_installed_selector(no_residual):
    """The oracle harness evaluates a corrector WITHOUT installing it —
    residual_pick must apply exactly the selector's choice rule."""
    corr = _fitted("tpu_v5e")
    shapes = scaled_llama3_shapes(tokens=HELDOUT_TOKENS, scale=SCALE)
    picks = [residual_pick(corr, GemmProblem(M=M, N=N, K=K), TPU_V5E)
             for (_, M, N, K) in shapes]
    set_residual_corrector(corr)
    clear_selection_cache()
    for (name, M, N, K), (cfg, n) in zip(shapes, picks):
        s = select_gemm_config(M, N, K, hw=TPU_V5E)
        assert s.config == cfg, name
        assert s.n_candidates == n, name


# ---------------------------------------------------------------------------
# The flywheel: drift stream -> fit -> better held-out fidelity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_corrected_fidelity_never_worse_on_heldout(preset, no_residual):
    """Acceptance: for every preset, the corrector's held-out llama3
    fidelity (%-of-oracle on token counts the fit never saw) is at least
    the analytical baseline's on average, and the worst row never
    degrades (beyond the CLI's anti-flake tolerance)."""
    hw = PRESETS[preset]
    corr = _fitted(preset)
    held = scaled_llama3_shapes(tokens=HELDOUT_TOKENS, scale=SCALE)
    rows = fidelity_sweep(hw, VirtualDevice(hw), held, prune=False,
                          residual=corr)
    assert len(rows) == len(held)
    for r in rows:
        assert 0.0 < r.corrected_fidelity <= 1.0 + 1e-12
        assert r.corrected != ""
    mean_a = sum(r.fidelity for r in rows) / len(rows)
    mean_c = sum(r.corrected_fidelity for r in rows) / len(rows)
    worst_a = min(r.fidelity for r in rows)
    worst_c = min(r.corrected_fidelity for r in rows)
    assert mean_c >= mean_a - 5e-3, (preset, mean_a, mean_c)
    assert worst_c >= worst_a - 5e-3, (preset, worst_a, worst_c)


def test_flywheel_from_drift_stream_end_to_end(tmp_path, no_residual):
    """The full loop the PR closes: selections measured on the virtual
    device -> drift JSONL (fingerprint-keyed by default) -> rows_from_drift
    -> fit -> corrected held-out fidelity beats the analytical baseline on
    tpu_v5e (the preset whose smoke numbers the CLI pins)."""
    hw = TPU_V5E
    fp = topology_fingerprint(hw)
    dev = VirtualDevice(hw)
    path = str(tmp_path / "drift.jsonl")
    train = scaled_llama3_shapes(tokens=TRAIN_TOKENS, scale=SCALE)
    with DriftMonitor(path=path, registry=MetricsRegistry()) as mon:
        for (_, M, N, K) in train:
            sel = select_gemm_config(M, N, K, hw=hw)
            mon.record_selection(sel, dev.gemm_time(sel.problem, sel.config),
                                 site="warm_gemm")
    rows, stats = rows_from_drift(path, fingerprint=fp)
    assert stats["kept"] == len(train) and stats["name_shaped_topo"] == 0
    # the serving drift stream alone only covers the model's own picks;
    # widen to the finalist slate exactly as tools/fit_residual.py does
    rows += rows_from_sweep(hw, dev,
                            [(M, N, K) for (_, M, N, K) in train])
    corr = fit_residual(rows, hw, sources=[path, "sweep"], stats=stats)
    assert corr.provenance["row_stats"]["kept"] == len(train)
    held = scaled_llama3_shapes(tokens=HELDOUT_TOKENS, scale=SCALE)
    orows = fidelity_sweep(hw, dev, held, prune=False, residual=corr)
    mean_a = sum(r.fidelity for r in orows) / len(orows)
    mean_c = sum(r.corrected_fidelity for r in orows) / len(orows)
    assert mean_c >= mean_a - 5e-3
    assert min(r.corrected_fidelity for r in orows) >= \
        min(r.fidelity for r in orows) - 5e-3
