"""End-to-end behaviour tests: the training and serving drivers as a user
would run them (CLI mains), plus dry-run cell machinery on tiny configs."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, *args], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.timeout(600)
def test_train_driver_end_to_end(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    log = str(tmp_path / "metrics.jsonl")
    p = _run(["-m", "repro.launch.train", "--arch", "phi4-mini-3.8b",
              "--smoke", "--steps", "30", "--batch", "4", "--seq", "64",
              "--lr", "1e-2", "--ckpt-dir", ckpt, "--ckpt-every", "10",
              "--log", log])
    assert p.returncode == 0, p.stdout[-3000:] + p.stderr[-3000:]
    recs = [json.loads(l) for l in open(log)]
    assert len(recs) == 30
    first, last = recs[0]["loss"], recs[-1]["loss"]
    assert last < first, (first, last)           # it learns
    assert os.path.isdir(os.path.join(ckpt, "step_000000030"))

    # restart from checkpoint: picks up at step 30, runs 10 more
    p2 = _run(["-m", "repro.launch.train", "--arch", "phi4-mini-3.8b",
               "--smoke", "--steps", "40", "--batch", "4", "--seq", "64",
               "--lr", "1e-2", "--ckpt-dir", ckpt, "--log", log])
    assert p2.returncode == 0, p2.stdout[-3000:] + p2.stderr[-3000:]
    assert "restored checkpoint at step 30" in p2.stdout
    recs = [json.loads(l) for l in open(log)]
    assert recs[-1]["step"] == 40


@pytest.mark.timeout(600)
def test_serve_driver_end_to_end():
    p = _run(["-m", "repro.launch.serve", "--arch", "mamba2-370m",
              "--smoke", "--batch", "2", "--prompt-len", "16",
              "--gen", "8"])
    assert p.returncode == 0, p.stdout[-3000:] + p.stderr[-3000:]
    assert "decoded 7 steps" in p.stdout


@pytest.mark.timeout(600)
def test_dryrun_cell_on_tiny_mesh(tmp_path):
    """The dry-run machinery itself (lower+compile+roofline) on 8 fake
    devices with a smoke config — exercises the exact code path of the
    512-device run without its compile cost."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import dataclasses, json
import jax
from repro.configs.registry import get_config, get_shape
from repro.launch import dryrun
from repro.launch.mesh import make_local_mesh
from repro.nn.model import Model
from repro.kernels import set_backend
from repro.core.roofline import cost_analysis_terms, parse_collective_bytes
set_backend("reference")
cfg = get_config("phi4-mini-3.8b", smoke=True)
cfg = dataclasses.replace(cfg, d_model=64, num_heads=4, num_kv_heads=4,
                          d_ff=256, vocab_size=512)
model = Model(cfg)
mesh = make_local_mesh(tp=4)
shape = dataclasses.replace(get_shape("train_4k"), seq_len=128,
                            global_batch=4)
jitted, args = dryrun._lower_cell(model, cfg, shape, mesh)
compiled = jitted.lower(*args).compile()
fl, by = cost_analysis_terms(compiled)
colls = parse_collective_bytes(compiled.as_text())
assert fl > 0 and by > 0, (fl, by)
assert colls["total"] > 0, colls      # sharded grads MUST produce collectives
ma = compiled.memory_analysis()
assert ma.temp_size_in_bytes > 0
print("DRYRUN_CELL_OK", fl, colls["total"])
"""
    p = _run(["-c", code])
    assert p.returncode == 0, p.stdout[-3000:] + p.stderr[-3000:]
    assert "DRYRUN_CELL_OK" in p.stdout


@pytest.mark.timeout(300)
def test_collective_parser_units():
    from repro.core.roofline import parse_collective_bytes
    hlo = """
  %all-reduce.1 = f32[256,1024]{1,0} all-reduce(%dot), channel_id=1
  %ag = bf16[64,32]{1,0} all-gather(%x), dimensions={0}
  %rs.2 = f32[16]{0} reduce-scatter(%y)
  %cp = (f32[8]{0}, f32[8]{0}) collective-permute-start(%z)
  %name-with-all-reduce-inside = f32[4]{0} add(%a, %b)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"] == 256 * 1024 * 4
    assert out["all-gather"] == 64 * 32 * 2
    assert out["reduce-scatter"] == 16 * 4
    assert out["collective-permute"] == 8 * 4 * 2
    assert out["total"] == sum(v for k, v in out.items() if k != "total")
