"""Fit the learned residual corrector from the drift stream (DESIGN.md §12).

    PYTHONPATH=src python tools/fit_residual.py --preset tpu_v5e \
        [--drift experiments/obs/drift.jsonl ...] [--oracle-sweep] \
        [--scale 8] [--smoke] [--out experiments/calib/<preset>.residual.json] \
        [--check-against-oracle]

Training rows come from either (or both) of:

* ``--drift PATH`` (repeatable) — ``repro/drift/v1`` JSONL streams a
  traced serving run emitted (PR 9's drift monitor).  Rows are validated
  against the live preset's topology fingerprint; name-shaped ``topo``
  columns and malformed lines are counted and refused.
* ``--oracle-sweep`` — measure the top-k analytically-ranked candidates of
  the scaled llama3 sweep on the simulator-backed virtual device, exactly
  the finalists the corrector re-prices at selection time.

The fit is written as a ``repro/residual/v1`` artifact (fingerprint +
model digest + provenance) loadable with ``load_residual_guarded``.
``--check-against-oracle`` then evaluates it on a HELD-OUT token sweep —
shapes the fit never saw — and fails when the corrected selection's
%-of-oracle fidelity falls below the analytical baseline; the held-out
report lands next to the artifact (``residual_report_<preset>.{json,md}``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.calib.device import VirtualDevice                 # noqa: E402
from repro.calib.oracle import (fidelity_sweep,              # noqa: E402
                                scaled_llama3_shapes)
from repro.calib.residual import (MIN_FIT_ROWS,              # noqa: E402
                                  fit_residual, rows_from_drift,
                                  rows_from_sweep)
from repro.core import PRESETS, get_hardware                 # noqa: E402
from repro.core.topology import topology_fingerprint         # noqa: E402

DEFAULT_OUT_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                               "experiments", "calib")

# Held-out evaluation uses a token count the training sweep never saw.
TRAIN_TOKENS = (1024,)
HELDOUT_TOKENS = (512,)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tpu_v5e", choices=sorted(PRESETS))
    ap.add_argument("--drift", action="append", default=[],
                    help="drift.jsonl path (repeatable)")
    ap.add_argument("--oracle-sweep", action="store_true",
                    help="supplement with top-k candidate measurements of "
                         "the scaled llama3 sweep on the virtual device")
    ap.add_argument("--scale", type=int, default=1,
                    help="divide llama3 sweep dims (smoke-size knob)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: --scale 8 and --oracle-sweep")
    ap.add_argument("--top-k", type=int, default=12,
                    help="candidates measured per sweep shape (wider than "
                         "the corrector's top_f=8 re-pricing slate so every "
                         "re-priced finalist is in-distribution)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default experiments/calib/"
                         "<preset>.residual.json)")
    ap.add_argument("--check-against-oracle", action="store_true",
                    help="held-out fidelity report; fail if the corrected "
                         "selection underperforms the analytical baseline")
    args = ap.parse_args()
    if args.smoke:
        args.scale = max(args.scale, 8)
        args.oracle_sweep = True

    hw = get_hardware(args.preset)
    fp = topology_fingerprint(hw)
    device = VirtualDevice(hw)

    rows, sources, stats = [], [], {}
    for path in args.drift:
        drows, dstats = rows_from_drift(path, fingerprint=fp)
        print(f"[residual] {path}: kept {dstats['kept']}/{dstats['total']} "
              f"rows ({dstats['malformed']} malformed, "
              f"{dstats['no_config']} config-less, "
              f"{dstats['name_shaped_topo']} name-shaped topo, "
              f"{dstats['fingerprint_mismatch']} stale fingerprint)")
        rows += drows
        sources.append(path)
        for k, v in dstats.items():
            stats[k] = stats.get(k, 0) + v
    if args.oracle_sweep:
        shapes = [(M, N, K) for (_, M, N, K) in
                  scaled_llama3_shapes(tokens=TRAIN_TOKENS,
                                       scale=args.scale)]
        srows = rows_from_sweep(hw, device, shapes, k=args.top_k)
        print(f"[residual] oracle sweep ({len(shapes)} shapes x top-"
              f"{args.top_k}): {len(srows)} rows")
        rows += srows
        sources.append(f"oracle-sweep:scale={args.scale}")

    if len(rows) < MIN_FIT_ROWS:
        print(f"[residual] FAIL: {len(rows)} training rows < "
              f"{MIN_FIT_ROWS} (pass --drift and/or --oracle-sweep)")
        return 2
    corr = fit_residual(rows, hw, sources=sources, stats=stats or None)
    prov = corr.provenance
    print(f"[residual] fit {len(rows)} rows for {hw.name} "
          f"(fingerprint {fp}): train RMSE {prov['train_rmse_log']:.4f} "
          f"log-s vs mean |log ratio| "
          f"{prov['train_mean_abs_log_ratio']:.4f}")

    out = args.out or os.path.join(DEFAULT_OUT_DIR,
                                   f"{hw.name}.residual.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    corr.save(out)
    print(f"[residual] artifact -> {out}")

    if not args.check_against_oracle:
        return 0
    held = scaled_llama3_shapes(tokens=HELDOUT_TOKENS, scale=args.scale)
    orows = fidelity_sweep(hw, device, held, prune=False, residual=corr)
    mean_a = sum(r.fidelity for r in orows) / len(orows)
    mean_c = sum(r.corrected_fidelity for r in orows) / len(orows)
    worst_a = min(r.fidelity for r in orows)
    worst_c = min(r.corrected_fidelity for r in orows)
    report = {
        "preset": hw.name, "fingerprint": fp, "n_shapes": len(orows),
        "heldout_tokens": list(HELDOUT_TOKENS), "scale": args.scale,
        "mean_fidelity": mean_a, "mean_corrected_fidelity": mean_c,
        "worst_fidelity": worst_a, "worst_corrected_fidelity": worst_c,
        "rows": [r.as_list() for r in orows],
    }
    base = os.path.join(os.path.dirname(os.path.abspath(out)),
                        f"residual_report_{hw.name}")
    with open(base + ".json", "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    md = ["| preset | shapes | analytical mean | corrected mean | "
          "analytical worst | corrected worst |",
          "|---|---|---|---|---|---|",
          f"| {hw.name} | {len(orows)} | {100*mean_a:.2f}% "
          f"| {100*mean_c:.2f}% | {100*worst_a:.2f}% "
          f"| {100*worst_c:.2f}% |"]
    with open(base + ".md", "w") as f:
        f.write("\n".join(md) + "\n")
    print(f"[residual] held-out ({len(orows)} shapes): analytical "
          f"{100*mean_a:.2f}% mean / {100*worst_a:.2f}% worst; corrected "
          f"{100*mean_c:.2f}% mean / {100*worst_c:.2f}% worst "
          f"-> {base}.{{json,md}}")
    # The corrector must help on average and never sink the worst row
    # (small tolerance: held-out noise must not flake CI).
    if mean_c < mean_a - 0.005 or worst_c < worst_a - 0.005:
        print("[residual] FAIL: corrected fidelity regressed vs the "
              "analytical baseline on held-out shapes")
        return 1
    print("[residual] corrected >= analytical on held-out shapes")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
