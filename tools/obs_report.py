#!/usr/bin/env python
"""Summarize a telemetry directory (trace.json / metrics.prom /
metrics.jsonl / drift.jsonl) into a human-readable markdown report.

Usage::

    PYTHONPATH=src python tools/obs_report.py experiments/obs
    PYTHONPATH=src python tools/obs_report.py experiments/obs -o report.md

The report contains one table per artifact that exists:

* **Trace** — span count per (track, cat) with total duration, plus the
  simulator timelines embedded in the Perfetto export.
* **Metrics** — every counter/gauge from the Prometheus textfile (or
  JSONL snapshot fallback), sorted by name.
* **Drift** — record count, rolling fidelity, min/mean fidelity and the
  worst offender per site.

Only the standard library is used, so the tool runs anywhere the repo
does (CI included).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e12:
        return str(int(v))
    return f"{v:.6g}"


def _table(headers: List[str], rows: List[List[str]]) -> List[str]:
    out = ["| " + " | ".join(headers) + " |",
           "| " + " | ".join("---" for _ in headers) + " |"]
    for row in rows:
        out.append("| " + " | ".join(row) + " |")
    return out


def _read_jsonl(path: str) -> Tuple[List[Dict[str, Any]], int]:
    """Parse a JSONL file, skipping malformed lines (a writer killed
    mid-append leaves a truncated trailing line — the crash-drain case;
    the report must summarize the records that DID land).  Returns
    (records, number of malformed lines skipped)."""
    recs: List[Dict[str, Any]] = []
    skipped = 0
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError:
                skipped += 1
    return recs, skipped


def _skipped_note(skipped: int) -> List[str]:
    if not skipped:
        return []
    s = "s" if skipped != 1 else ""
    return ["", f"_skipped {skipped} malformed line{s} "
                f"(truncated writer tail)_"]


def summarize_trace(path: str) -> List[str]:
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", [])
    pids: Dict[int, str] = {}
    tids: Dict[Tuple[int, int], str] = {}
    for ev in events:
        if ev.get("ph") == "M":
            if ev.get("name") == "process_name":
                pids[ev["pid"]] = ev["args"]["name"]
            elif ev.get("name") == "thread_name":
                tids[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    agg: Dict[Tuple[str, str, str], List[float]] = defaultdict(
        lambda: [0, 0.0])
    for ev in events:
        if ev.get("ph") not in ("X", "i", "C"):
            continue
        proc = pids.get(ev["pid"], str(ev["pid"]))
        track = tids.get((ev["pid"], ev["tid"]), str(ev.get("tid", "")))
        cat = ev.get("cat", "")
        cell = agg[(proc, track, cat)]
        cell[0] += 1
        cell[1] += float(ev.get("dur", 0.0))
    lines = [f"## Trace — {len(events)} events", ""]
    rows = [[proc, track, cat, str(int(n)), f"{dur / 1e3:.3f}"]
            for (proc, track, cat), (n, dur) in sorted(agg.items())]
    lines += _table(["process", "track", "cat", "events", "total ms"], rows)
    return lines


def _parse_prometheus(path: str) -> List[Tuple[str, float]]:
    out: List[Tuple[str, float]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            name, _, val = line.rpartition(" ")
            try:
                out.append((name, float(val)))
            except ValueError:
                continue
    return out


def summarize_metrics(prom_path: Optional[str],
                      jsonl_path: Optional[str]) -> List[str]:
    samples: List[Tuple[str, float]] = []
    src = ""
    skipped = 0
    if prom_path and os.path.exists(prom_path):
        samples = _parse_prometheus(prom_path)
        src = os.path.basename(prom_path)
    elif jsonl_path and os.path.exists(jsonl_path):
        src = os.path.basename(jsonl_path)
        recs, skipped = _read_jsonl(jsonl_path)
        last: Dict[str, Any] = recs[-1] if recs else {}
        metrics = last.get("metrics", {})
        if isinstance(metrics, dict):
            # MetricsRegistry.write_jsonl: {"name{labels}": value, ...},
            # histograms as {"count": ..., "sum": ..., "buckets": ...}.
            for name, value in metrics.items():
                if isinstance(value, dict):
                    for part in ("count", "sum"):
                        if part in value:
                            samples.append((f"{name}_{part}",
                                            float(value[part])))
                else:
                    samples.append((name, float(value)))
        else:
            # legacy list-of-samples form
            for m in metrics:
                labels = m.get("labels") or []
                suffix = ("{" + ",".join(f'{k}="{v}"' for k, v in labels)
                          + "}" if labels else "")
                samples.append((m["name"] + suffix, float(m["value"])))
    if not samples:
        return []
    lines = [f"## Metrics — {len(samples)} samples ({src})", ""]
    rows = [[name, _fmt(val)] for name, val in sorted(samples)
            if "_bucket{" not in name]
    lines += _table(["metric", "value"], rows)
    lines += _skipped_note(skipped)
    return lines


def summarize_drift(path: str) -> List[str]:
    recs, skipped = _read_jsonl(path)
    if not recs:
        return _skipped_note(skipped)[1:] if skipped else []
    by_site: Dict[str, List[Dict[str, Any]]] = defaultdict(list)
    for r in recs:
        by_site[str(r.get("site", "?"))].append(r)
    lines = [f"## Drift — {len(recs)} records, rolling fidelity "
             f"{recs[-1].get('rolling_fidelity', float('nan')):.4f}", ""]
    rows = []
    for site, rs in sorted(by_site.items()):
        fids = [float(r.get("fidelity", 0.0)) for r in rs]
        worst = min(rs, key=lambda r: float(r.get("fidelity", 0.0)))
        rows.append([site, str(len(rs)),
                     f"{sum(fids) / len(fids):.4f}", f"{min(fids):.4f}",
                     str(worst.get("shape", "?"))])
    lines += _table(["site", "records", "mean fidelity", "min fidelity",
                     "worst shape"], rows)
    lines += _skipped_note(skipped)
    return lines


def build_report(obs_dir: str) -> str:
    sections: List[str] = [f"# Telemetry report — `{obs_dir}`", ""]
    trace = os.path.join(obs_dir, "trace.json")
    if os.path.exists(trace):
        sections += summarize_trace(trace) + [""]
    metrics = summarize_metrics(os.path.join(obs_dir, "metrics.prom"),
                                os.path.join(obs_dir, "metrics.jsonl"))
    if metrics:
        sections += metrics + [""]
    drift = os.path.join(obs_dir, "drift.jsonl")
    if os.path.exists(drift):
        sections += summarize_drift(drift) + [""]
    if len(sections) <= 2:
        sections.append("_no telemetry artifacts found_")
    return "\n".join(sections).rstrip() + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("obs_dir", help="telemetry directory to summarize")
    ap.add_argument("-o", "--output", default=None,
                    help="write the markdown report here (default stdout)")
    args = ap.parse_args(argv)
    report = build_report(args.obs_dir)
    if args.output:
        with open(args.output, "w") as f:
            f.write(report)
    else:
        sys.stdout.write(report)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
