"""Regenerate the golden-selection regression file.

    PYTHONPATH=src python tools/regen_goldens.py [--check]

Writes ``tests/goldens/llama3_selections.json``: the full llama3-sweep
selection (config 6-tuple, candidate count, exact float64 predicted total
as hex) for every preset.  ``--check`` only diffs, exits non-zero on
mismatch (what ``tests/test_golden_selections.py`` does with a readable
table).

Regenerating is a DELIBERATE act: single-core (TPU) entries are the PR 1/2
bit-for-bit lineage and must never change; multi-level entries change only
when the model deliberately does.  Review the diff before committing.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

from benchmarks.llama3_shapes import llama3_gemms  # noqa: E402
from repro.core import PRESETS, select_gemm_config  # noqa: E402

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                           "tests", "goldens", "llama3_selections.json")


def compute_goldens() -> dict:
    out = {}
    for hw_name in sorted(PRESETS):
        hw = PRESETS[hw_name]
        entries = {}
        for size in ("8b", "70b"):
            for (name, M, N, K) in llama3_gemms(size):
                s = select_gemm_config(M, N, K, hw=hw)
                c = s.config
                entries[name] = {
                    "M": M, "N": N, "K": K,
                    "config": {"bm": c.bm, "bn": c.bn, "bk": c.bk,
                               "split_k": c.split_k, "group_m": c.group_m,
                               "schedule": c.schedule},
                    "n_candidates": s.n_candidates,
                    "total_hex": s.predicted.total.hex(),
                }
        out[hw_name] = entries
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="diff against the checked-in file, do not write")
    args = ap.parse_args()
    got = compute_goldens()
    path = os.path.normpath(GOLDEN_PATH)
    if args.check:
        with open(path) as f:
            want = json.load(f)
        if got != want:
            print("golden mismatch — run tests/test_golden_selections.py "
                  "for the readable diff table")
            return 1
        print(f"goldens match ({sum(len(v) for v in got.values())} entries)")
        return 0
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(got, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}: {sum(len(v) for v in got.values())} entries "
          f"across {len(got)} presets")
    return 0


if __name__ == "__main__":
    sys.exit(main())
