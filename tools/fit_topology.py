"""Calibrate a topology preset from microbenchmark probes (DESIGN.md §8).

    PYTHONPATH=src python tools/fit_topology.py --preset gpu_mi300x_like \
        [--device virtual | jax] [--noise 0.02] [--seed 0] \
        [--out experiments/calib/<preset>.topo.json] [--check-against-planted]

Runs the probe suite (per-level stream bandwidth, per-dtype compute issue
rate, wave-latency staircase, DMA-issue and first-byte-latency sweeps)
against the chosen device, fits the measured constants into the preset's
structure, prints a fitted-vs-preset table with residuals, and writes the
calibrated-topology JSON artifact (topology + provenance: raw probe
samples, residuals, fingerprint).

``--device virtual`` wraps the event simulator around the preset itself
(the CI self-consistency path — add ``--noise`` to exercise the robust
fits); ``--device jax`` times real executions on whatever jax backend is
present (meaningful on accelerators only).  Serving a saved artifact:

    from repro.core import load_calibrated_topology
    hw, prov = load_calibrated_topology(open(path).read())
    select_gemm_config(M, N, K, hw=hw)      # fingerprint-invalidated cache
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

from repro.calib.device import get_device          # noqa: E402
from repro.calib.fit import fit_topology           # noqa: E402
from repro.core import PRESETS, get_hardware       # noqa: E402

DEFAULT_OUT_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                               "experiments", "calib")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", required=True, choices=sorted(PRESETS))
    ap.add_argument("--device", default="virtual",
                    choices=("virtual", "jax"))
    ap.add_argument("--noise", type=float, default=0.0,
                    help="virtual device: deterministic relative jitter")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="artifact path (default "
                         "experiments/calib/<preset>.topo.json)")
    ap.add_argument("--check-against-planted", action="store_true",
                    help="virtual device: print per-field relative error "
                         "vs the planted constants and fail above the "
                         "documented tolerances (DESIGN.md §8: 5%% rates, "
                         "20%% kernel_launch, 15%% of the launch+latency "
                         "scale for the backing latency)")
    args = ap.parse_args()

    base = get_hardware(args.preset)
    device = get_device(args.device, base, noise=args.noise, seed=args.seed)
    print(f"[fit] probing {device.name} against preset {base.name} ...")
    res = fit_topology(base, device)

    print(f"[fit] static bandwidth-share coefficient: "
          f"{res.static_share:.4f} (occupancy stage assumes 1.0)")
    print(f"{'field':<34}{'preset':>14}{'fitted':>14}{'resid':>9}")
    for key in sorted(res.fitted):
        print(f"{key:<34}{_preset_value(base, key):>14.4e}"
              f"{res.fitted[key]:>14.4e}{res.residuals[key]:>9.1e}")

    out = args.out or os.path.join(DEFAULT_OUT_DIR,
                                   f"{base.name}.topo.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    res.save(out)
    print(f"[fit] calibrated-topology artifact -> {out}")

    if args.check_against_planted:
        if args.device != "virtual":
            print("[fit] --check-against-planted needs --device virtual")
            return 2
        # Same tolerances DESIGN.md §8 documents and tests enforce
        # (tests/test_calibration.py TOL_RATE / TOL_LAUNCH / TOL_LATENCY).
        # hbm_latency is judged against the launch + latency scale the
        # intercept subtraction operates on, not the latency alone.
        planted = device.planted
        errs = res.compare_to(planted)
        lat_scale = planted.backing.latency + planted.kernel_launch
        errs["hbm_latency"] = abs(
            res.fitted["hbm_latency"] - planted.backing.latency) / lat_scale
        bad = {k: e for k, e in errs.items()
               if e > (0.15 if k == "hbm_latency"
                       else 0.2 if k == "kernel_launch" else 0.05)}
        for k, e in sorted(errs.items()):
            print(f"  recovered {k}: rel err {e:.2%}")
        if bad:
            print(f"[fit] FAIL: outside tolerance: {bad}")
            return 1
        print("[fit] planted constants recovered within tolerance")
    return 0


def _preset_value(base, key: str) -> float:
    if key.startswith("levels."):
        name = key.split(".")[1]
        return next(l.bandwidth for l in base.levels if l.name == name)
    if key.startswith("peak_flops."):
        return base.peak_flops[key.split(".", 1)[1]]
    if key == "hbm_latency":
        return base.backing.latency
    return getattr(base, key)


if __name__ == "__main__":
    raise SystemExit(main())
