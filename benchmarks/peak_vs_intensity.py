"""Paper Fig. 4 (c)/(d): percent-of-peak as a function of arithmetic
intensity for selector-chosen kernels.

Per shape: the event simulator runs the selected config; percent-of-peak =
sim TFLOP/s / roofline(AI) where roofline(AI) = min(peak, AI * HBM_bw) —
the same normalization the paper uses (Ben Sander's max-achievable peak).
Binned means reproduce Fig. 4d.
"""
from __future__ import annotations

import argparse
from typing import List

import numpy as np

from benchmarks.common import random_shapes, write_csv
from repro.core import (GemmProblem, get_hardware, select_gemm_config,
                        simulate_gemm)


def run(n: int = 200, seed: int = 1, hw_name: str = "tpu_v5e",
        verbose: bool = True):
    hw = get_hardware(hw_name)
    peak = hw.flops("bfloat16")
    rows: List = []
    for (M, N, K) in random_shapes(n, seed=seed):
        p = GemmProblem(M=M, N=N, K=K)
        sel = select_gemm_config(M, N, K, hw=hw)
        r = simulate_gemm(p, sel.config, hw)
        ai = p.arithmetic_intensity
        roof = min(peak, ai * hw.hbm_bandwidth)
        achieved = p.flops / r.time
        rows.append([M, N, K, round(ai, 2), achieved / 1e12,
                     round(100 * achieved / roof, 2),
                     round(100 * achieved / peak, 2), str(sel.config)])
    write_csv(f"peak_vs_intensity_{hw_name}.csv",
              ["M", "N", "K", "arith_intensity", "achieved_tflops",
               "pct_of_roofline", "pct_of_peak", "config"], rows)
    # Fig 4d: binned means
    ais = np.array([r[3] for r in rows])
    pct = np.array([r[5] for r in rows])
    bins = np.array([0, 64, 128, 256, 512, 1024, 1e9])
    if verbose:
        print(f"[fig4:{hw_name}] percent-of-roofline by intensity bin:")
        for lo, hi in zip(bins[:-1], bins[1:]):
            m = (ais >= lo) & (ais < hi)
            if m.any():
                print(f"   AI [{lo:6.0f},{hi if hi < 1e8 else np.inf:6.0f}) "
                      f": {pct[m].mean():5.1f}%  (n={int(m.sum())})")
        print(f"   overall mean: {pct.mean():.1f}% of roofline")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--hw", default="tpu_v5e")
    args = ap.parse_args()
    run(n=args.n, hw_name=args.hw)


if __name__ == "__main__":
    main()
