"""Paper Fig. 3 / §V-A: selection efficiency of the analytical model vs
exhaustive search over the SAME candidate space.

Ground truth on this CPU container is the independent event-level grid
simulator (core/simulator.py) — see DESIGN.md §6.  Efficiency per problem =
sim_time(exhaustive argmin) / sim_time(selected config); the paper reports
94.7% mean over 150k shapes on MI300X; we sweep a seeded sample of the same
128-multiple distribution (``--n`` scales it up).
"""
from __future__ import annotations

import argparse
from typing import List

import numpy as np

from benchmarks.common import random_shapes, write_csv
from repro.core import (GemmProblem, candidate_tiles, exhaustive_best,
                        get_hardware, select_gemm_config, simulate_gemm)


def run(n: int = 150, seed: int = 0, hw_name: str = "tpu_v5e",
        max_mult: int = 32, verbose: bool = True) -> dict:
    hw = get_hardware(hw_name)
    rows: List = []
    effs = []
    for (M, N, K) in random_shapes(n, seed=seed, max_mult=max_mult):
        p = GemmProblem(M=M, N=N, K=K)
        cands = candidate_tiles(p, hw)
        best_t, best_r = exhaustive_best(p, hw, cands)
        sel = select_gemm_config(M, N, K, hw=hw)
        sel_r = simulate_gemm(p, sel.config, hw)
        eff = best_r.time / sel_r.time
        effs.append(eff)
        rows.append([M, N, K, round(p.arithmetic_intensity, 1),
                     str(sel.config), str(best_t), f"{eff:.4f}",
                     len(cands)])
    effs_np = np.array(effs)
    summary = {
        "n": n,
        "hw": hw_name,
        "mean_efficiency": float(effs_np.mean()),
        "median_efficiency": float(np.median(effs_np)),
        "p10": float(np.percentile(effs_np, 10)),
        "frac_ge_90": float((effs_np >= 0.90).mean()),
    }
    write_csv(f"selection_efficiency_{hw_name}.csv",
              ["M", "N", "K", "arith_intensity", "selected", "exhaustive",
               "efficiency", "n_candidates"], rows)
    if verbose:
        print(f"[fig3:{hw_name}] mean selection efficiency over {n} shapes: "
              f"{summary['mean_efficiency']*100:.1f}% "
              f"(median {summary['median_efficiency']*100:.1f}%, "
              f"p10 {summary['p10']*100:.1f}%, "
              f">=90%: {summary['frac_ge_90']*100:.0f}% of shapes) "
              f"[paper: 94.7%]")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hw", default="tpu_v5e")
    args = ap.parse_args()
    run(n=args.n, seed=args.seed, hw_name=args.hw)


if __name__ == "__main__":
    main()
