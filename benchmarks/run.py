"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows; detailed artifacts land in
experiments/bench/.  --full scales the sweeps up (paper-scale counts).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep sizes (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes only — the CI rot check: every "
                         "registered benchmark must still run")
    args = ap.parse_args()

    from benchmarks import (fused_epilogue, hierarchy_sweep, llama3_shapes,
                            model_fidelity, peak_vs_intensity,
                            roofline_table, selection_efficiency,
                            selection_overhead, serving_throughput,
                            wave_quantization)
    from repro.core import clear_selection_cache, select_gemm_config

    n_eff = 1000 if args.full else (8 if args.smoke else 120)
    n_ai = 500 if args.full else (8 if args.smoke else 120)

    print("name,us_per_call,derived")
    rows = []

    # Fig. 3 — selection efficiency (v5e) + Fig. 5 portability (v5p, v4).
    for hw in ("tpu_v5e", "tpu_v5p", "tpu_v4"):
        n = n_eff if hw == "tpu_v5e" else (n_eff if args.smoke
                                           else max(40, n_eff // 3))
        t0 = time.perf_counter()
        s = selection_efficiency.run(n=n, hw_name=hw, verbose=False)
        dt = (time.perf_counter() - t0) / max(n, 1) * 1e6
        label = "fig3_selection_efficiency" if hw == "tpu_v5e" \
            else f"fig5_portability_{hw}"
        print(f"{label},{dt:.1f},"
              f"mean_eff={s['mean_efficiency']*100:.2f}%")

    # Table II — selection overhead vs emulated autotune.
    t0 = time.perf_counter()
    tab = selection_overhead.run(verbose=False,
                                 autotune_upto=1024 if args.full
                                 else (256 if args.smoke else 512))
    dt = (time.perf_counter() - t0) * 1e6
    cold = tab[2][2]     # 1024^3 cold selection in us
    auto = tab[1][4]     # 512^3 autotune seconds
    print(f"tableII_selection_overhead,{cold:.1f},"
          f"autotune_512^3={auto:.1f}s_vs_select_{tab[1][2]:.0f}us")

    # Vectorized cold-path scoring vs the seed's Python loop.
    speedups = [row[7] for row in tab]
    print(f"selector_scoring_speedup,{tab[2][6]:.1f},"
          f"min={min(speedups):.1f}x_max={max(speedups):.1f}x")

    # §Batched selection — one vectorized cold pass for N shapes vs N
    # scalar calls (llama3 30-shape sweep, in-memory + disk-recording).
    bs = selection_overhead.measure_batch_selection(
        repeats=3 if args.smoke else 7, verbose=False)
    print(f"batch_selection,{bs['mem_batch_s']*1e6:.1f},"
          f"mem={bs['mem_speedup']:.1f}x_disk={bs['disk_speedup']:.1f}x_"
          f"n={bs['n_shapes']}")

    # §Oracle pricing — batched full-menu simulation vs P scalar event
    # loops (one unpruned exhaustive-oracle shape; smoke shrinks the
    # shape so the rot check stays seconds, not minutes).
    sb = selection_overhead.measure_simulator_batch(
        repeats=1 if args.smoke else 3, verbose=False,
        shape=(256, 1024, 1024) if args.smoke else (1024, 4096, 4096))
    print(f"simulator_batch,{sb['batch_s']*1e6:.1f},"
          f"speedup={sb['speedup']:.2f}x_P={sb['n_candidates']}")

    # §Serving — continuous batching over ragged requests: model-priced
    # buckets vs the pow2 baseline (same requests, same tokens).
    t0 = time.perf_counter()
    st = serving_throughput.run(smoke=not args.full, verbose=False)
    dt = (time.perf_counter() - t0) * 1e6
    mp, p2 = st["model_priced"], st["pow2"]
    print(f"serving_throughput,{dt:.1f},"
          f"modeled={p2['modeled_total_s']/mp['modeled_total_s']:.2f}x_"
          f"toks={mp['tokens_per_s']/p2['tokens_per_s']:.2f}x_"
          f"pad={mp['pad_fraction']*100:.0f}%_vs_{p2['pad_fraction']*100:.0f}%")

    # §Fused epilogue — fused vs unfused bytes/latency (roofline accounting).
    t0 = time.perf_counter()
    fe = fused_epilogue.run(verbose=False)
    dt = (time.perf_counter() - t0) / max(len(fe), 1) * 1e6
    byte_save = sum(r[8] for r in fe) / len(fe)
    lat_save = sum(r[11] for r in fe) / len(fe)
    print(f"fused_epilogue,{dt:.1f},"
          f"mean_byte_savings={byte_save:.1f}%_"
          f"mean_latency_savings={lat_save:.1f}%")

    # §Hierarchy — multi-level topology ablation (llama3 shapes).
    t0 = time.perf_counter()
    hs = hierarchy_sweep.run(sizes=("8b",) if args.smoke else ("8b", "70b"),
                             simulate=not args.smoke, verbose=False)
    n_hs = sum(s["n"] for s in hs.values())
    dt = (time.perf_counter() - t0) / max(n_hs, 1) * 1e6
    flips = sum(s["flips"] for s in hs.values())
    print(f"hierarchy_sweep,{dt:.1f},"
          f"flips={flips}/{n_hs}_presets={len(hs)}")

    # §Occupancy — tail-wave cliffs (Alg. 4 chip-wide) + schedule recovery.
    t0 = time.perf_counter()
    wq = wave_quantization.run(simulate=not args.smoke, smoke=args.smoke,
                               verbose=False)
    n_wq = sum(s["points"] for s in wq.values())
    dt = (time.perf_counter() - t0) / max(n_wq, 1) * 1e6
    dips = [s["model_dip"] for s in wq.values()]
    rec = sum(s["selection_recovered"] for s in wq.values())
    print(f"wave_quantization,{dt:.1f},"
          f"max_model_dip={100*max(dips):.0f}%_recovered={rec}/{n_wq}")

    # §Fidelity — %-of-exhaustive-oracle per preset (calib subsystem).
    # Exhaustive candidate pricing is minutes per GPU preset at full scale,
    # so the harness scales the shapes down outside --full; the full-scale
    # sweep is the calibration-smoke CI artifact / nightly assertion.
    t0 = time.perf_counter()
    mf = model_fidelity.run(smoke=not args.full, full=args.full,
                            verbose=False)
    n_mf = sum(s["n"] for s in mf["presets"].values())
    dt = (time.perf_counter() - t0) / max(n_mf, 1) * 1e6
    worst = min(s["worst_fidelity"] for s in mf["presets"].values())
    mean = (sum(s["mean_fidelity"] * s["n"]
                for s in mf["presets"].values()) / max(n_mf, 1))
    print(f"model_fidelity,{dt:.1f},"
          f"mean={100*mean:.1f}%_worst={100*worst:.1f}%_"
          f"presets={len(mf['presets'])}")

    # Fig. 4 — percent of peak vs arithmetic intensity.
    t0 = time.perf_counter()
    r4 = peak_vs_intensity.run(n=n_ai, verbose=False)
    dt = (time.perf_counter() - t0) / max(n_ai, 1) * 1e6
    mean_pct = sum(x[5] for x in r4) / len(r4)
    print(f"fig4_pct_of_roofline,{dt:.1f},mean={mean_pct:.1f}%")

    # Fig. 6 — Llama-3 key GEMMs.
    t0 = time.perf_counter()
    r6 = llama3_shapes.run(verbose=False,
                           sizes=("8b",) if args.smoke else ("8b", "70b"),
                           tokens=(1024,) if args.smoke else (1024, 4096,
                                                              8192))
    dt = (time.perf_counter() - t0) / max(len(r6), 1) * 1e6
    eff = [float(x[6]) for x in r6]
    print(f"fig6_llama3_shapes,{dt:.1f},"
          f"mean_eff={100*sum(eff)/len(eff):.2f}%_worst={100*min(eff):.2f}%")

    # §Roofline — aggregate dry-run artifacts (if present).
    try:
        t0 = time.perf_counter()
        rows = roofline_table.run(verbose=False)
        dt = (time.perf_counter() - t0) * 1e6
        if rows:
            bounds = {}
            for row in rows:
                bounds[row[7]] = bounds.get(row[7], 0) + 1
            print(f"roofline_table,{dt:.1f},cells={len(rows)}_"
                  f"bounds={bounds}")
        else:
            print("roofline_table,0,no_dryrun_artifacts_yet")
    except Exception as e:                                 # noqa: BLE001
        print(f"roofline_table,0,error={e!r}")

    # Selection micro-latency (cached path, paper §V-B "1s of us").
    clear_selection_cache()
    select_gemm_config(4096, 4096, 4096)
    t0 = time.perf_counter()
    for _ in range(1000):
        select_gemm_config(4096, 4096, 4096)
    dt = (time.perf_counter() - t0) / 1000 * 1e6
    print(f"selection_cached_lookup,{dt:.2f},paper_claims_order_1us")


if __name__ == "__main__":
    main()
