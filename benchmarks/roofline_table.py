"""§Roofline: aggregate the dry-run artifacts into the per-cell table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
CSV + a markdown table for EXPERIMENTS.md: three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio, analytic memory fit,
per (arch x shape x mesh).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List

from benchmarks.common import write_csv

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(path: str = DRYRUN_DIR) -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(verbose: bool = True, path: str = DRYRUN_DIR):
    rows = []
    md = ["| arch | shape | mesh | compute_s | memory_s | coll_s | bound | "
          "useful | mem GiB/dev | fits |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load_records(path):
        rf = r.get("roofline", {})
        mem_an = r.get("memory_analytic_gib", {})
        fits = mem_an.get("fits_16gib_hbm", "?")
        total_gib = mem_an.get("total_gib", 0)
        src = "probes" if "cost_reconstructed" in r else "module"
        rows.append([
            r["arch"], r["shape"], r["mesh"], r["chips"],
            f"{rf.get('compute_s', 0):.4e}", f"{rf.get('memory_s', 0):.4e}",
            f"{rf.get('collective_s', 0):.4e}", rf.get("bottleneck", "?"),
            f"{rf.get('useful_flop_ratio', 0):.3f}",
            round(total_gib, 2), fits,
            r.get("microbatches", 1),
            f"{r.get('cost_reconstructed', r.get('cost_module', {})).get('flops', 0):.4e}",
            f"{r.get('hbm_bytes_analytic', {}).get('total', 0):.4e}",
            f"{r.get('cost_module', {}).get('bytes', 0):.4e}",
            round(r.get("memory", {}).get("temp_bytes", 0) / 2**30, 2),
            src,
        ])
        md.append("| " + " | ".join(str(x) for x in [
            r["arch"], r["shape"], r["mesh"],
            f"{rf.get('compute_s', 0):.2e}", f"{rf.get('memory_s', 0):.2e}",
            f"{rf.get('collective_s', 0):.2e}", rf.get("bottleneck", "?"),
            f"{rf.get('useful_flop_ratio', 0):.2f}",
            round(total_gib, 2), fits]) + " |")
    path_csv = write_csv(
        "roofline_table.csv",
        ["arch", "shape", "mesh", "chips", "compute_s", "memory_s",
         "collective_s", "bottleneck", "useful_flop_ratio",
         "analytic_mem_gib", "fits_hbm", "microbatches", "flops_dev",
         "bytes_analytic_dev", "bytes_xla_cpu_dev", "xla_temp_gib",
         "source"], rows)
    md_path = path_csv.replace(".csv", ".md")
    with open(md_path, "w") as f:
        f.write("\n".join(md) + "\n")
    if verbose:
        print(f"[roofline] {len(rows)} cells -> {path_csv}")
        by_bound = {}
        for row in rows:
            by_bound[row[7]] = by_bound.get(row[7], 0) + 1
        print(f"[roofline] bottleneck distribution: {by_bound}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=DRYRUN_DIR)
    run(path=ap.parse_args().path)


if __name__ == "__main__":
    main()
