"""§Roofline: aggregate the dry-run artifacts into the per-cell table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
CSV + a markdown table for EXPERIMENTS.md: three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio, analytic memory fit,
per (arch x shape x mesh) — plus one per-level port column per memory
level of the artifact's recorded *serving topology* (the outermost entry
is the classic memory term; inner entries bound what a cache-resident
schedule could recover).  Artifacts predating the topology record fall
back to the roofline's own ``level_seconds`` when present, else blank.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from benchmarks.common import write_csv

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(path: str = DRYRUN_DIR) -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def level_seconds(r: dict) -> Dict[str, float]:
    """Per-level port seconds for one record: HLO bytes through each level
    of the recorded serving topology (inclusive hierarchy — every byte
    crosses every port outward of where it is served; with only aggregate
    HLO bytes available this is the all-traffic bound per port).  Prefers
    the artifact's own topology record; falls back to the roofline's
    precomputed ``level_seconds``."""
    topo = r.get("topology")
    if topo and "levels" in topo:
        hlo_bytes = float(r.get("hbm_bytes_analytic", {}).get("total", 0.0)
                          or r.get("cost_module", {}).get("bytes", 0.0))
        return {lvl["name"]: hlo_bytes / lvl["bandwidth"]
                for lvl in topo["levels"][:-1]}
    return dict(r.get("roofline", {}).get("level_seconds", {}))


def run(verbose: bool = True, path: str = DRYRUN_DIR):
    rows = []
    recs = load_records(path)
    # Union of level names across artifacts, outermost-first per record
    # order — one CSV/markdown column per level.
    level_names: List[str] = []
    for r in recs:
        for name in level_seconds(r):
            if name not in level_names:
                level_names.append(name)
    lvl_hdr = [f"level_s:{n}" for n in level_names]
    md = ["| arch | shape | mesh | compute_s | memory_s | coll_s | bound | "
          "useful | mem GiB/dev | fits | topo | "
          + " | ".join(lvl_hdr) + " |",
          "|---|---|---|---|---|---|---|---|---|---|---|"
          + "---|" * len(level_names)]
    for r in recs:
        rf = r.get("roofline", {})
        mem_an = r.get("memory_analytic_gib", {})
        fits = mem_an.get("fits_16gib_hbm", "?")
        total_gib = mem_an.get("total_gib", 0)
        src = "probes" if "cost_reconstructed" in r else "module"
        lvl_s = level_seconds(r)
        topo_name = r.get("topology", {}).get("name", "?")
        lvl_cells = [f"{lvl_s[n]:.4e}" if n in lvl_s else ""
                     for n in level_names]
        rows.append([
            r["arch"], r["shape"], r["mesh"], r["chips"],
            f"{rf.get('compute_s', 0):.4e}", f"{rf.get('memory_s', 0):.4e}",
            f"{rf.get('collective_s', 0):.4e}", rf.get("bottleneck", "?"),
            f"{rf.get('useful_flop_ratio', 0):.3f}",
            round(total_gib, 2), fits,
            r.get("microbatches", 1),
            f"{r.get('cost_reconstructed', r.get('cost_module', {})).get('flops', 0):.4e}",
            f"{r.get('hbm_bytes_analytic', {}).get('total', 0):.4e}",
            f"{r.get('cost_module', {}).get('bytes', 0):.4e}",
            round(r.get("memory", {}).get("temp_bytes", 0) / 2**30, 2),
            src, topo_name,
        ] + lvl_cells)
        md.append("| " + " | ".join(str(x) for x in [
            r["arch"], r["shape"], r["mesh"],
            f"{rf.get('compute_s', 0):.2e}", f"{rf.get('memory_s', 0):.2e}",
            f"{rf.get('collective_s', 0):.2e}", rf.get("bottleneck", "?"),
            f"{rf.get('useful_flop_ratio', 0):.2f}",
            round(total_gib, 2), fits, topo_name] + lvl_cells) + " |")
    path_csv = write_csv(
        "roofline_table.csv",
        ["arch", "shape", "mesh", "chips", "compute_s", "memory_s",
         "collective_s", "bottleneck", "useful_flop_ratio",
         "analytic_mem_gib", "fits_hbm", "microbatches", "flops_dev",
         "bytes_analytic_dev", "bytes_xla_cpu_dev", "xla_temp_gib",
         "source", "serving_topology"] + lvl_hdr, rows)
    md_path = path_csv.replace(".csv", ".md")
    with open(md_path, "w") as f:
        f.write("\n".join(md) + "\n")
    if verbose:
        print(f"[roofline] {len(rows)} cells -> {path_csv}")
        by_bound = {}
        for row in rows:
            by_bound[row[7]] = by_bound.get(row[7], 0) + 1
        print(f"[roofline] bottleneck distribution: {by_bound}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=DRYRUN_DIR)
    run(path=ap.parse_args().path)


if __name__ == "__main__":
    main()
