"""Hierarchy ablation sweep: what the memory-topology model buys.

For each multi-level preset (MI300X-like, H100-like) and every Llama-3 key
GEMM shape, select twice: once on the full topology and once on a
cache-stripped ablation (same constants, ``levels = (backing, staging)``).
A differing selection is a config the L2/MALL terms *changed* — the
tentpole claim of the topology refactor: grouped swizzle and tile shape are
priced by cache residency, not hardcoded.  The per-level byte split of the
chosen config (closed-form model vs the event simulator's measured reuse
distances) lands in the CSV.

    PYTHONPATH=src python -m benchmarks.hierarchy_sweep
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List

from benchmarks.common import write_csv
from benchmarks.llama3_shapes import llama3_gemms
from repro.core import (GemmProblem, get_hardware, level_traffic,
                        select_gemm_config, simulate_gemm)

MULTI_LEVEL_PRESETS = ("gpu_mi300x_like", "gpu_h100_like")


def strip_caches(topo):
    """Ablation: same constants, no intermediate cache levels."""
    return dataclasses.replace(topo, name=topo.name + "_nocache",
                               levels=(topo.levels[0], topo.levels[-1]))


def run(sizes=("8b", "70b"), presets=MULTI_LEVEL_PRESETS,
        simulate: bool = True, verbose: bool = True) -> Dict[str, Dict]:
    rows: List = []
    summary: Dict[str, Dict] = {}
    for hw_name in presets:
        full = get_hardware(hw_name)
        flat = strip_caches(full)
        cache_names = [lvl.name for lvl in full.cache_levels]
        flips = gm_flips = ksplit = 0
        hbm_saved = []
        for size in sizes:
            for (name, M, N, K) in llama3_gemms(size):
                p = GemmProblem(M=M, N=N, K=K)
                sel = select_gemm_config(M, N, K, hw=full)
                abl = select_gemm_config(M, N, K, hw=flat)
                flipped = sel.config != abl.config
                flips += flipped
                gm_flips += sel.config.group_m != abl.config.group_m
                ksplit += (sel.config.split_k > 1
                           or sel.config.schedule == "stream_k")
                served = level_traffic(p, sel.config, full)
                # HBM bytes the hierarchy terms removed vs the ablation's
                # choice priced flat (all re-reads spill to HBM).
                flat_bytes = sum(
                    level_traffic(p, abl.config, flat).values())
                saved = 1.0 - served[full.backing.name] / flat_bytes
                hbm_saved.append(saved)
                sim_split = ""
                if simulate:
                    r = simulate_gemm(p, sel.config, full)
                    sim_split = "|".join(
                        f"{k}:{v:.3e}" for k, v in r.level_bytes.items())
                rows.append([
                    hw_name, name, M, N, K, str(sel.config), str(abl.config),
                    int(flipped),
                    "|".join(f"{k}:{served[k]:.3e}" for k in served),
                    sim_split, f"{100*saved:.1f}",
                    f"{sel.predicted.occupancy:.4f}", sel.predicted.waves,
                ])
        summary[hw_name] = {
            "n": len(hbm_saved),
            "flips": flips,
            "group_m_flips": gm_flips,
            "k_split_or_stream": ksplit,
            "mean_hbm_saved": sum(hbm_saved) / len(hbm_saved),
            "cache_levels": cache_names,
        }
        if verbose:
            s = summary[hw_name]
            print(f"[hierarchy:{hw_name}] cache levels {cache_names}: "
                  f"{s['flips']}/{s['n']} selections changed by the "
                  f"hierarchy terms ({s['group_m_flips']} group_m flips, "
                  f"{s['k_split_or_stream']} split-K/stream-K), "
                  f"mean HBM-byte saving {100*s['mean_hbm_saved']:.1f}%")
    write_csv("hierarchy_sweep.csv",
              ["hw", "gemm", "M", "N", "K", "selected", "flat_ablation",
               "flipped", "model_level_bytes", "sim_level_bytes",
               "hbm_saved_pct", "occupancy", "waves"], rows)
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-sim", action="store_true",
                    help="skip the event-simulator cross-check")
    args = ap.parse_args()
    run(simulate=not args.no_sim)


if __name__ == "__main__":
    main()
