"""§Fidelity: the paper's %-of-exhaustive-autotune table (calib oracle).

For every preset, price the FULL candidate menu of each llama3 key-GEMM
shape on the simulator-backed virtual device, record the empirical argmin,
and report what fraction of that optimum the zero-autotune analytical
selection achieves (paper's >95% headline claim).  Artifacts land in
``experiments/calib/fidelity_report.{json,csv,md}``.

    PYTHONPATH=src python -m benchmarks.model_fidelity [--smoke | --full]
        [--presets a,b,...]

``--smoke`` divides the shapes by 8 (exhaustive simulation of several
hundred candidates per shape is minutes per GPU preset at full scale) —
the CI rot check; ``--full`` runs 8b+70b at three token counts.
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional, Sequence

from repro.calib.oracle import fidelity_report
from repro.core import PRESETS


def run(presets: Optional[Sequence[str]] = None, smoke: bool = False,
        full: bool = False, verbose: bool = True) -> Dict:
    presets = tuple(presets or sorted(PRESETS))
    if full:
        sizes, tokens, scale = ("8b", "70b"), (1024, 4096, 8192), 1
    elif smoke:
        sizes, tokens, scale = ("8b",), (1024,), 8
    else:
        sizes, tokens, scale = ("8b",), (1024,), 1
    return fidelity_report(presets=presets, sizes=sizes, tokens=tokens,
                           scale=scale, verbose=verbose)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shapes / 8 — pipeline rot check")
    ap.add_argument("--full", action="store_true",
                    help="8b + 70b at all token counts (slow)")
    ap.add_argument("--presets", default=None,
                    help="comma-separated preset names (default: all)")
    args = ap.parse_args()
    run(presets=args.presets.split(",") if args.presets else None,
        smoke=args.smoke, full=args.full)


if __name__ == "__main__":
    main()
