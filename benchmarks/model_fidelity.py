"""§Fidelity: the paper's %-of-exhaustive-autotune table (calib oracle).

For every preset, price the FULL candidate menu of each llama3 key-GEMM
shape on the simulator-backed virtual device, record the empirical argmin,
and report what fraction of that optimum the zero-autotune analytical
selection achieves (paper's >95% headline claim).  Artifacts land in
``experiments/calib/fidelity_report.{json,csv,md}``.

    PYTHONPATH=src python -m benchmarks.model_fidelity [--smoke | --full]
        [--presets a,b,...] [--pruned]

``--smoke`` divides the shapes by 8 (exhaustive simulation of several
hundred candidates per shape is minutes per GPU preset at full scale) —
the CI rot check; ``--full`` runs 8b+70b at three token counts.  The
oracle prices the WHOLE menu unpruned by default (one batched simulator
pass per shape); ``--pruned`` restores the lower-bound-pruned scalar
search for A/B-ing the bound.
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional, Sequence

from repro.calib.oracle import fidelity_report
from repro.core import PRESETS


def run(presets: Optional[Sequence[str]] = None, smoke: bool = False,
        full: bool = False, verbose: bool = True,
        prune: bool = False) -> Dict:
    presets = tuple(presets or sorted(PRESETS))
    if full:
        sizes, tokens, scale = ("8b", "70b"), (1024, 4096, 8192), 1
    elif smoke:
        sizes, tokens, scale = ("8b",), (1024,), 8
    else:
        sizes, tokens, scale = ("8b",), (1024,), 1
    return fidelity_report(presets=presets, sizes=sizes, tokens=tokens,
                           scale=scale, verbose=verbose, prune=prune)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shapes / 8 — pipeline rot check")
    ap.add_argument("--full", action="store_true",
                    help="8b + 70b at all token counts (slow)")
    ap.add_argument("--presets", default=None,
                    help="comma-separated preset names (default: all)")
    ap.add_argument("--pruned", action="store_true",
                    help="lower-bound-pruned oracle search instead of the "
                         "batched full-menu sweep")
    args = ap.parse_args()
    run(presets=args.presets.split(",") if args.presets else None,
        smoke=args.smoke, full=args.full, prune=args.pruned)


if __name__ == "__main__":
    main()
