"""Paper Fig. 6: selection quality on key Llama-3 GEMM shapes.

The projection GEMMs of Llama-3 8B and 70B (qkv, attn-out, gate/up, down,
vocab head) at common token counts — the real inference/training shapes the
paper highlights.  Reports selection efficiency vs the simulator-exhaustive
argmin per shape.
"""
from __future__ import annotations

import argparse

from benchmarks.common import write_csv
from repro.configs.llama3_shapes import (  # noqa: F401  (re-export)
    LLAMA3, TOKENS, llama3_gemms)
from repro.core import (GemmProblem, candidate_tiles, exhaustive_best,
                        get_hardware, select_gemm_config, simulate_gemm)


def run(hw_name: str = "tpu_v5e", verbose: bool = True,
        sizes=tuple(LLAMA3), tokens=TOKENS):
    hw = get_hardware(hw_name)
    rows = []
    effs = []
    for size in sizes:
        for (name, M, N, K) in llama3_gemms(size, tokens):
            p = GemmProblem(M=M, N=N, K=K)
            sel = select_gemm_config(M, N, K, hw=hw)
            best_t, best_r = exhaustive_best(p, hw, candidate_tiles(p, hw))
            r = simulate_gemm(p, sel.config, hw)
            eff = best_r.time / r.time
            effs.append(eff)
            rows.append([name, M, N, K, str(sel.config),
                         round(p.flops / r.time / 1e12, 1),
                         f"{eff:.4f}"])
    write_csv("llama3_shapes.csv",
              ["gemm", "M", "N", "K", "selected", "sim_tflops",
               "efficiency"], rows)
    if verbose:
        worst = min(effs)
        print(f"[fig6] llama3 GEMMs: mean efficiency "
              f"{100*sum(effs)/len(effs):.1f}%, worst {100*worst:.1f}% "
              f"over {len(effs)} shapes")
        for r in rows[:5]:
            print("   ", r[0], r[4], f"{r[5]} TF/s", f"eff={r[6]}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="tpu_v5e")
    run(hw_name=ap.parse_args().hw)


if __name__ == "__main__":
    main()
