"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import csv
import os
import sys
import time
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def out_path(name: str) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, name)


def write_csv(name: str, header: Sequence[str], rows: Iterable[Sequence]):
    path = out_path(name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def random_shapes(n: int, seed: int = 0, max_mult: int = 32,
                  unit: int = 128) -> List[Tuple[int, int, int]]:
    """The paper's Fig-3 distribution: dims are multiples of 128 below a
    cap (paper: <=8193; default cap here 4096 to bound simulator time)."""
    rng = np.random.default_rng(seed)
    ms = rng.integers(1, max_mult + 1, size=(n, 3)) * unit
    return [tuple(int(v) for v in row) for row in ms]


def timed(fn, *args, repeat: int = 1, **kw) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat, out
