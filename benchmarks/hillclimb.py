"""§Perf hillclimb driver: re-run the three chosen cells with candidate
optimizations and diff the roofline terms against the baseline artifacts.

    PYTHONPATH=src python -m benchmarks.hillclimb          # run variants
    PYTHONPATH=src python -m benchmarks.hillclimb --report # table only

Cells (per the brief — baseline table, EXPERIMENTS.md §Roofline):
    mixtral-8x22b     x train_4k   (MOST COLLECTIVE-BOUND: 240 s collective)
    qwen3-moe-30b-a3b x decode_32k (WORST ROOFLINE FRACTION: useful 0.020)
    internlm2-20b     x train_4k   (MOST REPRESENTATIVE of the technique:
                                    pure selector-driven dense GEMM stack)

Variants are cumulative where the tag chains flags.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

BASE = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(BASE, "experiments", "hillclimb")

# (cell, variant-tag, extra dryrun flags)
RUNS = [
    ("internlm2-20b", "train_4k", "kvrep", ["--kv-repeat-weights"]),
    ("internlm2-20b", "train_4k", "kvrep_mb", ["--kv-repeat-weights",
                                               "--microbatches", "0"]),
    ("internlm2-20b", "train_4k", "kvrep_mb_sp", ["--kv-repeat-weights",
                                                  "--microbatches", "0",
                                                  "--sp-stash"]),
    ("mixtral-8x22b", "train_4k", "kvrep", ["--kv-repeat-weights"]),
    ("mixtral-8x22b", "train_4k", "kvrep_mb", ["--kv-repeat-weights",
                                               "--microbatches", "0"]),
    ("qwen3-moe-30b-a3b", "decode_32k", "gqapack", ["--gqa-packed-decode"]),
    ("qwen3-moe-30b-a3b", "decode_32k", "gqapack_moedense",
     ["--gqa-packed-decode", "--moe-dense-decode"]),
    ("qwen3-moe-30b-a3b", "decode_32k", "gqapack_moedense_kvrep",
     ["--gqa-packed-decode", "--moe-dense-decode", "--kv-repeat-weights"]),
    # Attribution runs for the bf16-TP-reduction change (kernels/ref.py):
    # no flags => isolates the pure bf16-collective effect vs baseline.
    ("internlm2-20b", "train_4k", "bf16coll", []),
    ("internlm2-20b", "train_4k", "bf16coll_kvrep", ["--kv-repeat-weights"]),
    ("mixtral-8x22b", "train_4k", "bf16coll", []),
    ("qwen3-moe-30b-a3b", "decode_32k", "bf16coll", []),
]


def run_variants(only=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(BASE, "src")
    for arch, shape, tag, flags in RUNS:
        if only and tag != only:
            continue
        out_dir = os.path.join(OUT, tag)
        print(f"== {arch} x {shape} [{tag}] {' '.join(flags)}")
        cmd = [sys.executable, "-u", "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--with-probes",
               "--out", out_dir, *flags]
        r = subprocess.run(cmd, env=env, cwd=BASE)
        if r.returncode:
            print(f"   FAILED rc={r.returncode}")


def _load(path):
    out = {}
    for f in glob.glob(os.path.join(path, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def report():
    base = _load(os.path.join(BASE, "experiments", "dryrun"))
    print(f"{'cell':34s} {'variant':14s} {'compute_s':>10s} {'memory_s':>9s} "
          f"{'coll_s':>9s} {'roofline_s':>10s} {'bound':>10s} {'useful':>7s}")

    def row(r, tag):
        rf = r["roofline"]
        cell = f"{r['arch']} x {r['shape']}"
        print(f"{cell:34s} {tag:14s} {rf['compute_s']:10.3f} "
              f"{rf['memory_s']:9.3f} {rf['collective_s']:9.3f} "
              f"{rf['roofline_s']:10.3f} {rf['bottleneck']:>10s} "
              f"{rf['useful_flop_ratio']:7.3f}")

    cells = sorted({(a, s) for a, s, _, _ in RUNS})
    for (arch, shape) in cells:
        if (arch, shape) in base:
            row(base[(arch, shape)], "baseline")
        for tag in [t for a, s, t, _ in RUNS if (a, s) == (arch, shape)]:
            v = _load(os.path.join(OUT, tag))
            if (arch, shape) in v:
                row(v[(arch, shape)], tag)
        print()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    if not args.report:
        run_variants(only=args.only)
    report()


if __name__ == "__main__":
    main()
