"""Wave-quantization sweep: the occupancy stage's tail-wave cliffs.

For each multi-core preset, sweep M in whole-block steps so the output
tile count walks across multiples of the chip's core count.  At every
"cliff" (tiles = k*cores + 1) a fixed data-parallel schedule strands the
last wave on a near-empty chip: the modeled tail-wave efficiency
``units / (waves * cores)`` dips, and the event simulator — which
schedules units round-robin over the cores, sharing nothing with the
model but the Topology constants — independently reproduces the latency
jump.  The sweep also re-selects per shape, showing the menu (split-K
multiplying units, stream-K erasing the tile-granular tail) buying the
dip back — the paper's Alg. 4 rationale for k-splitting on GPUs.

    PYTHONPATH=src python -m benchmarks.wave_quantization
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Sequence

from benchmarks.common import write_csv
from repro.core import (GemmProblem, TileConfig, gemm_latency, get_hardware,
                        select_gemm_config, simulate_gemm, wave_model)

MULTI_CORE_PRESETS = ("gpu_mi300x_like", "gpu_h100_like")
# Fixed probe tile per preset (data_parallel, sk=1): the schedule whose tail
# wave the sweep exposes.
PROBE = {
    "gpu_mi300x_like": TileConfig(bm=128, bn=128, bk=64),
    "gpu_h100_like": TileConfig(bm=128, bn=128, bk=64),
}


def sweep_points(hw, bm: int, bn: int, N: int,
                 waves_span=(1, 2)) -> List[int]:
    """M values (block multiples) placing the tile count just below, at,
    and just above each wave boundary in ``waves_span``."""
    C = hw.total_cores()
    Tn = -(-N // bn)
    out = []
    for w in waves_span:
        tm_at = max(1, (w * C) // Tn)            # tiles ~= w * cores
        for tm in (tm_at - 1, tm_at, tm_at + 1):
            if tm >= 1:
                out.append(tm * bm)
    return sorted(set(out))


def run(presets: Sequence[str] = MULTI_CORE_PRESETS, N: int = 4096,
        K: int = 4096, simulate: bool = True, smoke: bool = False,
        verbose: bool = True) -> Dict[str, Dict]:
    rows: List = []
    summary: Dict[str, Dict] = {}
    for hw_name in presets:
        hw = get_hardware(hw_name)
        probe = PROBE[hw_name]
        C = hw.total_cores()
        points = sweep_points(hw, probe.bm, probe.bn, N,
                              waves_span=(1,) if smoke else (1, 2))
        occs, sim_tf, model_tf = [], [], []
        recovered = 0
        for M in points:
            p = GemmProblem(M=M, N=N, K=K)
            units, waves, _ = wave_model(p, probe, hw)
            fixed = gemm_latency(p, probe, hw)
            sel = select_gemm_config(M, N, K, hw=hw)
            row = [hw_name, M, N, K, units, waves, C,
                   f"{fixed.occupancy:.4f}", f"{fixed.total*1e6:.1f}",
                   str(sel.config), f"{sel.predicted.occupancy:.4f}",
                   f"{sel.predicted.total*1e6:.1f}"]
            if simulate:
                r = simulate_gemm(p, probe, hw)
                row += [f"{r.time*1e6:.1f}", r.waves]
                sim_tf.append(p.flops / r.time / 1e12)
            else:
                row += ["", ""]
            rows.append(row)
            occs.append(fixed.occupancy)
            model_tf.append(p.flops / fixed.total / 1e12)
            recovered += sel.predicted.total < fixed.total * 0.999
        # Cliff depth: best-to-worst tail-wave efficiency over the sweep —
        # the model's dip, and (when simulated) the simulator's.
        model_dip = 1.0 - min(occs) / max(occs)
        sim_dip = (1.0 - min(sim_tf) / max(sim_tf)) if sim_tf else None
        summary[hw_name] = {
            "points": len(points), "cores": C,
            "model_dip": model_dip, "sim_dip": sim_dip,
            "selection_recovered": recovered,
        }
        if verbose:
            s = summary[hw_name]
            sim_txt = (f", sim dip {100*s['sim_dip']:.0f}%"
                       if s["sim_dip"] is not None else "")
            print(f"[waves:{hw_name}] {C} cores: modeled tail-wave dip "
                  f"{100*s['model_dip']:.0f}% across the cliff{sim_txt}; "
                  f"selection recovered latency on "
                  f"{s['selection_recovered']}/{s['points']} points")
    write_csv("wave_quantization.csv",
              ["hw", "M", "N", "K", "units", "waves", "cores",
               "probe_occupancy", "probe_model_us", "selected",
               "sel_occupancy", "sel_model_us", "sim_us", "sim_waves"],
              rows)
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-sim", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(simulate=not args.no_sim, smoke=args.smoke)


if __name__ == "__main__":
    main()
