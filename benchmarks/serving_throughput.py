"""Serving throughput: model-priced buckets vs the pow2 baseline.

Drives the continuous-batching engine (``launch/engine.py``) over one
ragged request set twice — once admitted into a model-priced
:func:`~repro.core.bucketing.plan_buckets` plan, once into the shape-blind
:func:`~repro.core.bucketing.pow2_plan` — and reports measured tokens/s,
padding overhead, and bucket-hit counts next to each plan's modeled total
latency.  Right-padding is exact for causal attention, so the two runs
must emit bit-identical tokens: the benchmark asserts it.

    PYTHONPATH=src python -m benchmarks.serving_throughput [--full]

Artifact: ``experiments/bench/serving_throughput.csv``.
"""
from __future__ import annotations

import argparse
from typing import Dict

import numpy as np

import jax

from benchmarks.common import write_csv
from repro.configs.registry import get_config
from repro.core.bucketing import plan_buckets, pow2_plan, step_gemms
from repro.kernels import ops
from repro.launch.engine import ServingEngine
from repro.nn.model import Model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def run(smoke: bool = True, verbose: bool = True, seed: int = 0,
        arch: str = "phi4-mini-3.8b") -> Dict:
    cfg = get_config(arch, smoke=True)        # CPU container: smoke model
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    n_req = 8 if smoke else 24
    max_batch = 3 if smoke else 4
    gen = 4 if smoke else 12
    lo, hi = (6, 20) if smoke else (16, 56)
    rng = np.random.default_rng(seed)
    lens = rng.integers(lo, hi + 1, size=n_req).tolist()
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in lens]

    gemms = step_gemms(cfg.d_model, cfg.d_ff,
                       kv_dim=cfg.num_kv_heads * cfg.head_dim,
                       vocab=cfg.vocab_size,
                       swiglu=cfg.activation == "swiglu")
    hw = ops.get_default_hardware()
    plans = {
        "model_priced": plan_buckets(lens, gemms=gemms, hw=hw,
                                     max_buckets=4),
        "pow2": pow2_plan(lens, gemms=gemms, hw=hw),
    }

    max_len = max(max(p.edges) for p in plans.values()) + gen
    rows, out, tokens_by_plan = [], {}, {}
    for name, plan in plans.items():
        eng = ServingEngine(model, params, max_batch=max_batch,
                            max_len=max_len, plan=plan, temperature=0.0,
                            seed=seed, sync_every=4)
        for p in prompts:
            eng.submit(p, max_new_tokens=gen)
        eng.warm_start()
        stats = eng.run()
        tokens_by_plan[name] = [stats["results"][r].tokens
                                for r in sorted(stats["results"])]
        hit_rate = {e: c / n_req for e, c in stats["bucket_hits"].items()}
        out[name] = {
            "edges": list(plan.edges),
            "modeled_total_s": plan.modeled_total_s,
            "modeled_pad_fraction": plan.pad_fraction,
            "tokens_per_s": stats["tokens_per_s"],
            "pad_fraction": stats["pad_fraction"],
            "bucket_hits": stats["bucket_hits"],
            "hit_rate": hit_rate,
            "steps": stats["steps"],
        }
        rows.append([name, " ".join(map(str, plan.edges)),
                     plan.modeled_total_s * 1e3,
                     f"{plan.pad_fraction:.4f}",
                     f"{stats['tokens_per_s']:.1f}",
                     f"{stats['pad_fraction']:.4f}",
                     ";".join(f"{e}:{c}" for e, c in
                              sorted(stats["bucket_hits"].items()))])
        if verbose:
            print(f"[serving] {name:13s} edges={list(plan.edges)} "
                  f"modeled {plan.modeled_total_s*1e3:.2f}ms "
                  f"pad {stats['pad_fraction']*100:.1f}% -> "
                  f"{stats['tokens_per_s']:.1f} tok/s")

    # Padding is numerically invisible under causal attention: both plans
    # must generate the same tokens.
    for a, b in zip(tokens_by_plan["model_priced"], tokens_by_plan["pow2"]):
        assert np.array_equal(a, b), "bucketing changed generated tokens"

    # Tracing-overhead check: the model-priced run again with the full
    # telemetry stack on (tracer + metrics registry).  Tokens must be
    # bit-identical — telemetry only observes — and the tok/s ratio is
    # the measured cost of leaving tracing enabled.
    prev_tracer = obs_trace.set_tracer(obs_trace.Tracer())
    prev_metrics = obs_metrics.enable_metrics(True)
    try:
        plan = plans["model_priced"]
        eng = ServingEngine(model, params, max_batch=max_batch,
                            max_len=max_len, plan=plan, temperature=0.0,
                            seed=seed, sync_every=4, quiet=True)
        for p in prompts:
            eng.submit(p, max_new_tokens=gen)
        eng.warm_start()
        stats = eng.run()
        traced_tokens = [stats["results"][r].tokens
                         for r in sorted(stats["results"])]
        n_spans = len(obs_trace.get_tracer().spans)
    finally:
        obs_trace.set_tracer(prev_tracer)
        obs_metrics.enable_metrics(prev_metrics)
    for a, b in zip(tokens_by_plan["model_priced"], traced_tokens):
        assert np.array_equal(a, b), "tracing changed generated tokens"
    base_tps = out["model_priced"]["tokens_per_s"]
    out["tracing_overhead"] = {
        "tokens_per_s_disabled": base_tps,
        "tokens_per_s_enabled": stats["tokens_per_s"],
        "ratio": stats["tokens_per_s"] / max(base_tps, 1e-12),
        "spans": n_spans,
    }
    if verbose:
        print(f"[serving] tracing overhead: {base_tps:.1f} tok/s off vs "
              f"{stats['tokens_per_s']:.1f} tok/s on "
              f"({out['tracing_overhead']['ratio']:.3f}x, "
              f"{n_spans} spans)")

    write_csv("serving_throughput.csv",
              ["plan", "edges", "modeled_total_ms", "modeled_pad_frac",
               "tokens_per_s", "measured_pad_frac", "bucket_hits"], rows)
    if verbose:
        mp, p2 = out["model_priced"], out["pow2"]
        print(f"[serving] model-priced vs pow2: modeled "
              f"{p2['modeled_total_s']/mp['modeled_total_s']:.2f}x, "
              f"measured {mp['tokens_per_s']/p2['tokens_per_s']:.2f}x "
              f"tokens/s")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    args = ap.parse_args()
    run(smoke=not args.full, arch=args.arch)


if __name__ == "__main__":
    main()
