"""Paper Table II: selection time — analytical model vs autotuning.

The autotune column compiles-and-runs every candidate with the Pallas
kernel in interpret mode (the only execution path on this CPU container);
for the largest sizes it is measured on a candidate subset and scaled
linearly in P (documented in the CSV), exactly because running it in full
is the paper's point.  tritonBLAS column: first-call (cold) and cached
selection wall time.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Dict, List

import numpy as np

import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.core import (GemmProblem, candidate_tiles, clear_selection_cache,
                        score_candidate, select_gemm_config)
from repro.core.hardware import TPU_V5E
from repro.core.selector import (load_selection_cache, select_fast,
                                 select_gemm_config_batch,
                                 unload_selection_cache)
from repro.kernels import matmul


def measure_autotune(M: int, N: int, K: int, max_candidates: int = 8
                     ) -> tuple:
    """Compile+run `max_candidates` candidates in interpret mode; scale to
    the full space. Returns (estimated_full_s, measured_s, P)."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((M, K)), dtype=jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype=jnp.bfloat16)
    p = GemmProblem(M=M, N=N, K=K)
    cands = candidate_tiles(p, TPU_V5E, allow_split_k=False,
                            allow_grouping=False)
    subset = cands[:: max(1, len(cands) // max_candidates)][:max_candidates]
    t0 = time.perf_counter()
    for t in subset:
        out = matmul(a, b, out_dtype=jnp.float32, config=t,
                     backend="pallas_interpret")
        out.block_until_ready()
    measured = time.perf_counter() - t0
    full = measured * len(cands) / len(subset)
    return full, measured, len(cands)


def measure_scoring(M: int, N: int, K: int, repeats: int = 9) -> tuple:
    """Cold-selection path: Python enumeration + per-candidate
    ``score_candidate`` loop (seed behaviour) vs the vectorized
    enumeration + batch-scoring pass ``select_gemm_config`` now runs.
    Best-of-``repeats`` wall time each; both must pick the same argmin.
    Returns (loop_s, vec_s, speedup, P)."""
    p = GemmProblem(M=M, N=N, K=K)

    def loop_select():
        cands = candidate_tiles(p, TPU_V5E)
        best, best_score = None, None
        for t in cands:
            s = score_candidate(p, t, TPU_V5E)
            if best_score is None or s < best_score - 1e-15 or (
                    abs(s - best_score) <= 1e-15
                    and (t.bm * t.bn * t.bk) > (best.bm * best.bn * best.bk)):
                best, best_score = t, s
        return best

    def vec_select():
        return select_fast(p, TPU_V5E)[0]

    # Warm up both paths (numpy import layout, static grid caches), then time
    # each in its own phase — interleaving lets the loop path's churn pollute
    # the vectorized path's cache lines.
    best_loop, best_vec = loop_select(), vec_select()
    t_loop, t_vec = float("inf"), float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        best_loop = loop_select()
        t_loop = min(t_loop, time.perf_counter() - t0)
    for _ in range(repeats):
        t0 = time.perf_counter()
        best_vec = vec_select()
        t_vec = min(t_vec, time.perf_counter() - t0)
    assert best_vec == best_loop, (best_vec, best_loop)
    return t_loop, t_vec, t_loop / t_vec, len(
        candidate_tiles(p, TPU_V5E))


def _llama3_sweep_shapes() -> List[tuple]:
    """The 30 projection GEMMs of Llama-3 8B + 70B at the default token
    counts — the realistic many-shape cold sweep a serving warm-up runs."""
    from repro.configs.llama3_shapes import llama3_gemms
    return [(m, n, k) for size in ("8b", "70b")
            for (_, m, n, k) in llama3_gemms(size)]


def measure_batch_selection(repeats: int = 5, verbose: bool = True) -> Dict:
    """Batched cold selection (``select_gemm_config_batch``) vs N scalar
    ``select_gemm_config`` calls over the 30-shape Llama-3 sweep.

    Reports best-of-``repeats`` wall times (the file's convention, see
    ``measure_scoring``) for BOTH serving-relevant modes: pure in-memory
    (no persistence) and disk-recording
    (``REPRO_SELECTION_CACHE`` set — the scalar path pays per-shape
    merge-on-write flushes, the batch path one bulk merge).  Every repeat
    asserts the batch selections are bit-identical to the scalar ones
    (config, candidate count, and the predicted total down to the float
    bit pattern)."""
    shapes = _llama3_sweep_shapes()
    hw = TPU_V5E

    def scalar_run():
        return [select_gemm_config(m, n, k, hw=hw) for m, n, k in shapes]

    def batch_run():
        return select_gemm_config_batch(shapes, hw=hw)

    def check(ref, got):
        for a, b in zip(ref, got):
            assert a.config == b.config, (a.config, b.config)
            assert a.n_candidates == b.n_candidates
            assert a.predicted.total.hex() == b.predicted.total.hex()

    out: Dict = {"n_shapes": len(shapes)}
    # -- in-memory mode ----------------------------------------------------
    scalar_run()                                    # one warm-up of each
    clear_selection_cache()
    batch_run()
    ts, tb = [], []
    for _ in range(repeats):
        clear_selection_cache()
        t0 = time.perf_counter()
        ref = scalar_run()
        ts.append(time.perf_counter() - t0)
        clear_selection_cache()
        t0 = time.perf_counter()
        got = batch_run()
        tb.append(time.perf_counter() - t0)
        check(ref, got)
    out["mem_scalar_s"] = min(ts)
    out["mem_batch_s"] = min(tb)
    out["mem_speedup"] = out["mem_scalar_s"] / out["mem_batch_s"]

    # -- disk-recording mode (the persistent-server cold path) -------------
    prev = os.environ.get("REPRO_SELECTION_CACHE")
    ts, tb = [], []
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "selections.json")
        os.environ["REPRO_SELECTION_CACHE"] = path
        try:
            for _ in range(repeats):
                for fn, acc in ((scalar_run, ts), (batch_run, tb)):
                    if os.path.exists(path):
                        os.unlink(path)
                    clear_selection_cache()
                    load_selection_cache(path)      # fresh empty table
                    t0 = time.perf_counter()
                    fn()
                    acc.append(time.perf_counter() - t0)
        finally:
            if prev is None:
                os.environ.pop("REPRO_SELECTION_CACHE", None)
            else:
                os.environ["REPRO_SELECTION_CACHE"] = prev
            unload_selection_cache()                # drop temp-dir path
            load_selection_cache()                  # restore prior state
            clear_selection_cache()
    out["disk_scalar_s"] = min(ts)
    out["disk_batch_s"] = min(tb)
    out["disk_speedup"] = out["disk_scalar_s"] / out["disk_batch_s"]

    write_csv("batch_selection.csv",
              ["mode", "scalar_ms", "batch_ms", "speedup", "n_shapes"],
              [["memory", out["mem_scalar_s"] * 1e3,
                out["mem_batch_s"] * 1e3, out["mem_speedup"], len(shapes)],
               ["disk", out["disk_scalar_s"] * 1e3,
                out["disk_batch_s"] * 1e3, out["disk_speedup"],
                len(shapes)]])
    if verbose:
        print(f"[batch] {len(shapes)}-shape llama3 cold sweep: "
              f"in-memory {out['mem_scalar_s']*1e3:.2f}ms -> "
              f"{out['mem_batch_s']*1e3:.2f}ms "
              f"({out['mem_speedup']:.1f}x); "
              f"disk-recording {out['disk_scalar_s']*1e3:.2f}ms -> "
              f"{out['disk_batch_s']*1e3:.2f}ms "
              f"({out['disk_speedup']:.1f}x)")
    return out


def measure_simulator_batch(repeats: int = 3, verbose: bool = True,
                            shape: tuple = (1024, 4096, 4096)) -> Dict:
    """Batched oracle pricing (``simulate_gemm_batch``) vs P scalar
    ``simulate_gemm`` calls over a full multi-core candidate menu — the
    cost of one unpruned exhaustive-oracle shape, the sweep the nightly
    fidelity job runs per llama3 GEMM.

    Best-of-``repeats`` wall times (the file's convention); every repeat
    asserts the batched results are bit-identical to the scalar ones
    (seconds and per-level byte ledgers down to the float bit pattern).
    Placement (pass 1) is per-candidate Python in both paths, so the
    speedup measures what vectorizing the pricing pass (populations +
    per-core byte clocks) actually buys."""
    from repro.core.hardware import GPU_H100_LIKE
    from repro.core.simulator import simulate_gemm, simulate_gemm_batch

    hw = GPU_H100_LIKE
    p = GemmProblem(M=shape[0], N=shape[1], K=shape[2])
    cands = candidate_tiles(p, hw)

    def check(ref, got):
        assert len(ref) == len(got)
        for a, b in zip(ref, got):
            assert a.time.hex() == b.time.hex(), (a.time, b.time)
            assert {k: v.hex() for k, v in a.level_bytes.items()} \
                == {k: v.hex() for k, v in b.level_bytes.items()}

    t_sc, t_ba = float("inf"), float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        ref = [simulate_gemm(p, t, hw) for t in cands]
        t_sc = min(t_sc, time.perf_counter() - t0)
        t0 = time.perf_counter()
        got = simulate_gemm_batch(p, cands, hw)
        t_ba = min(t_ba, time.perf_counter() - t0)
        check(ref, got)
    out = {"n_candidates": len(cands), "scalar_s": t_sc, "batch_s": t_ba,
           "speedup": t_sc / t_ba}
    write_csv("simulator_batch.csv",
              ["preset", "P", "scalar_s", "batch_s", "speedup"],
              [[hw.name, len(cands), t_sc, t_ba, out["speedup"]]])
    if verbose:
        print(f"[simbatch] {hw.name} {p.M}x{p.N}x{p.K} P={len(cands)}: "
              f"scalar {t_sc:.2f}s -> batch {t_ba:.2f}s "
              f"({out['speedup']:.2f}x, bit-identical)")
    return out


def run(sizes=(256, 512, 1024, 2048, 4096, 8192, 16384),
        autotune_upto: int = 512, verbose: bool = True):
    rows: List = []
    for s in sizes:
        clear_selection_cache()
        t0 = time.perf_counter()
        sel = select_gemm_config(s, s, s)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(100):
            select_gemm_config(s, s, s)
        cached = (time.perf_counter() - t0) / 100
        if s <= autotune_upto:
            auto_full, auto_meas, P = measure_autotune(s, s, s)
            note = f"measured {P} cands (subset extrapolated)"
        else:
            # O(P*M*N*K): scale the largest measured point
            base = rows[-1] if rows else None
            auto_full = (rows[-1][4] * (s / sizes[0]) ** 3
                         if rows else float("nan"))
            P = sel.n_candidates
            note = "extrapolated O(P*M*N*K)"
        t_loop, t_vec, speedup, P = measure_scoring(s, s, s)
        rows.append([s, sel.n_candidates, cold * 1e6, cached * 1e6,
                     auto_full, t_loop * 1e6, t_vec * 1e6, speedup, note])
        if verbose:
            print(f"[tableII] {s}^3: select cold {cold*1e6:8.0f}us "
                  f"cached {cached*1e6:6.2f}us  "
                  f"autotune(est) {auto_full:10.1f}s  P={sel.n_candidates}  "
                  f"scoring loop {t_loop*1e6:7.0f}us -> vec "
                  f"{t_vec*1e6:6.0f}us ({speedup:.1f}x)")
    write_csv("selection_overhead.csv",
              ["size", "P", "select_cold_us", "select_cached_us",
               "autotune_s", "score_loop_us", "score_vec_us",
               "score_speedup", "note"], rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--autotune-upto", type=int, default=512)
    args = ap.parse_args()
    run(autotune_upto=args.autotune_upto)


if __name__ == "__main__":
    main()
