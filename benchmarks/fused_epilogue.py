"""Fused vs unfused epilogue: HBM bytes and modeled latency per layer shape.

For each dense-layer GEMM family in ``src/repro/configs`` the seed executed
the epilogue (bias / activation / gate-multiply / residual) as separate XLA
elementwise ops — one full-output HBM round trip each.  The fused kernel
runs the same work inside the accumulator flush, paying only the compulsory
operand reads.  This bench prices both formulations with the roofline
accounting (``hbm_traffic`` + ``epilogue_unfused_extra_bytes``) and the
closed-form latency model, per representative (M, N, K, epilogue) cell.

    PYTHONPATH=src python -m benchmarks.fused_epilogue
"""
from __future__ import annotations

import argparse
from typing import List

from benchmarks.common import write_csv
from repro.core import (Epilogue, GemmProblem, epilogue_unfused_extra_bytes,
                        gemm_latency, get_hardware, hbm_traffic,
                        select_gemm_config)

# (name, M, N, K, epilogue) — M = tokens per step (B*S), weights from
# llama3-8B-ish / phi4 / mixtral expert shapes; the epilogue mirrors what
# nn/layers.py & nn/moe.py now fuse.
CASES = [
    ("mlp_up_gelu",      8192,  14336, 4096, Epilogue(activation="gelu")),
    ("mlp_gate_swiglu",  8192,  14336, 4096,
     Epilogue(activation="swiglu_gate")),
    ("mlp_down_residual", 8192, 4096, 14336, Epilogue(residual=True)),
    ("attn_wo_residual", 8192,  4096,  4096, Epilogue(residual=True)),
    ("expert_gate",      2048,  2816,  4096,
     Epilogue(activation="swiglu_gate")),
    ("bias_gelu_skinny",   64,  4096,  4096,
     Epilogue(bias=True, activation="gelu")),
]


def run(hw_name: str = "tpu_v5e", in_dtype: str = "bfloat16",
        out_dtype: str = "bfloat16", verbose: bool = True) -> List:
    hw = get_hardware(hw_name)
    rows: List = []
    for (name, M, N, K, ep) in CASES:
        fused_p = GemmProblem(M=M, N=N, K=K, in_dtype=in_dtype,
                              out_dtype=out_dtype, epilogue=ep)
        plain_p = GemmProblem(M=M, N=N, K=K, in_dtype=in_dtype,
                              out_dtype=out_dtype)
        sel = select_gemm_config(M, N, K, in_dtype=in_dtype,
                                 out_dtype=out_dtype, epilogue=ep, hw=hw)
        t = sel.config
        fused_bytes = hbm_traffic(fused_p, t)
        fused_lat = gemm_latency(fused_p, t, hw).total
        # Unfused: plain GEMM traffic + one full-output round trip per
        # post-op (+ operand reads) + per-op dispatch overhead.
        extra = epilogue_unfused_extra_bytes(fused_p)
        unfused_bytes = hbm_traffic(plain_p, t) + extra
        unfused_lat = (gemm_latency(plain_p, t, hw).total
                       + extra / hw.hbm_bandwidth
                       + ep.n_ops * hw.kernel_launch)
        byte_save = 1.0 - fused_bytes / unfused_bytes
        lat_save = 1.0 - fused_lat / unfused_lat
        rows.append([name, M, N, K, str(ep), str(t),
                     fused_bytes, unfused_bytes, 100 * byte_save,
                     fused_lat * 1e6, unfused_lat * 1e6, 100 * lat_save])
        if verbose:
            print(f"[fused_epilogue] {name:18s} {M}x{N}x{K} ep={ep}: "
                  f"bytes {unfused_bytes/1e6:8.1f}MB -> "
                  f"{fused_bytes/1e6:8.1f}MB (-{100*byte_save:.1f}%)  "
                  f"latency {unfused_lat*1e6:8.1f}us -> "
                  f"{fused_lat*1e6:8.1f}us (-{100*lat_save:.1f}%)")
    write_csv("fused_epilogue.csv",
              ["name", "M", "N", "K", "epilogue", "config",
               "fused_bytes", "unfused_bytes", "byte_savings_pct",
               "fused_us", "unfused_us", "latency_savings_pct"], rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="tpu_v5e")
    args = ap.parse_args()
    run(hw_name=args.hw)


if __name__ == "__main__":
    main()
