"""End-to-end driver: train a ~100M-param LM with the library API.

    PYTHONPATH=src python examples/train_lm.py            # CPU-sized default
    PYTHONPATH=src python examples/train_lm.py --d-model 768 --layers 12 \
        --steps 300                                       # ~100M params

Uses the full production stack: selector-driven kernels (reference backend
on CPU), sharded state on a local mesh, AdamW + warmup-cosine, the
deterministic data pipeline, checkpointing and the straggler monitor —
the same components launch/train.py deploys on a pod.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.distributed import (batch_shardings, opt_shardings,
                               param_shardings, replicated)
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import TrainState, make_train_step
from repro.nn.model import Model
from repro.optim import AdamW, warmup_cosine
from repro.runtime import StragglerMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("phi4-mini-3.8b", smoke=True),
        name="example-lm", num_layers=args.layers, d_model=args.d_model,
        num_heads=args.heads, num_kv_heads=max(1, args.heads // 2),
        head_dim=args.d_model // args.heads, d_ff=4 * args.d_model,
        vocab_size=args.vocab, remat=True)
    model = Model(cfg)
    print(f"params: {model.param_count()/1e6:.1f}M  "
          f"devices: {jax.device_count()}")

    mesh = make_local_mesh()
    opt = AdamW(lr=warmup_cosine(args.lr, 20, args.steps))
    p_sh = param_shardings(model, mesh)
    state_sh = TrainState(params=p_sh, opt=opt_shardings(p_sh, mesh),
                          step=replicated(mesh))
    params = jax.jit(model.init, out_shardings=p_sh)(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))

    specs = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq),
                                            jnp.int32)}
    step_fn = jax.jit(make_train_step(model, opt),
                      in_shardings=(state_sh, batch_shardings(specs, mesh)),
                      out_shardings=(state_sh, replicated(mesh)),
                      donate_argnums=(0,))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    stream = Prefetcher(data.iterate(0), depth=2)
    monitor = StragglerMonitor()

    first_loss = None
    t_start = time.time()
    for step in range(args.steps):
        batch = {"tokens": jnp.asarray(next(stream)["tokens"])}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        monitor.record(time.time() - t0)
        if first_loss is None:
            first_loss = loss
        if (step + 1) % 25 == 0:
            toks = args.batch * args.seq * (step + 1)
            print(f"step {step+1:4d}  loss {loss:.4f}  "
                  f"{toks/(time.time()-t_start):,.0f} tok/s")
    stream.close()
    print(f"\nloss {first_loss:.3f} -> {loss:.3f} over {args.steps} steps "
          f"({len(monitor.flagged)} straggler events)")
    assert loss < first_loss


if __name__ == "__main__":
    main()
