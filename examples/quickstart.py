"""Quickstart: zero-autotuning GEMM — select, run, verify.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import TPU_V5E, rank_candidates, select_gemm_config
from repro.core.latency import GemmProblem
from repro.kernels import matmul
from repro.kernels.ref import matmul_ref

# 1. A GEMM problem: C[M,N] = A[M,K] @ B[K,N].
M, N, K = 1024, 2048, 512

# 2. Deterministic analytical selection (microseconds, no autotuning).
sel = select_gemm_config(M, N, K, in_dtype="bfloat16", hw=TPU_V5E)
print("selected:", sel)
print(f"  predicted {sel.predicted.total*1e6:.1f} us on {sel.hardware}, "
      f"bottleneck: {sel.predicted.bottleneck}")
print(f"  candidate space: {sel.n_candidates} configs "
      f"(an autotuner would compile+benchmark every one)")

# 3. Top of the ranking — what the model believes about the space.
print("\ntop-5 candidates by predicted latency:")
for cfg, pred in rank_candidates(GemmProblem(M=M, N=N, K=K))[:5]:
    print(f"  {str(cfg):22s} {pred.total*1e6:8.1f} us  {pred.bottleneck}")

# 4. Run the Pallas kernel with the selected BlockSpec tiling.
#    (interpret=True executes the kernel body on CPU; on a TPU runtime the
#    same call lowers through Mosaic.)
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((M, K)), dtype=jnp.bfloat16)
b = jnp.asarray(rng.standard_normal((K, N)), dtype=jnp.bfloat16)
out = matmul(a, b, out_dtype=jnp.float32, backend="pallas_interpret")
want = matmul_ref(a, b, out_dtype=jnp.float32)
err = float(jnp.max(jnp.abs(out - want)))
print(f"\nPallas kernel vs jnp oracle: max |err| = {err:.3e}")
assert err < 0.3 * np.sqrt(K)
print("OK")
