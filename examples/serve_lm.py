"""Batched serving example: prefill a batch of prompts, decode with a KV
cache, sample with temperature — across any of the ten architectures.

    PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-7b]
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    import sys
    sys.argv = ["serve", "--arch", args.arch, "--smoke",
                "--batch", str(args.batch), "--prompt-len", "24",
                "--gen", str(args.gen)]
    return serve.main()


if __name__ == "__main__":
    raise SystemExit(main())
