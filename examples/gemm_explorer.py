"""GEMM explorer: inspect the analytical model's view of a problem.

    PYTHONPATH=src python examples/gemm_explorer.py --m 4096 --n 4096 \
        --k 4096 [--dtype bfloat16] [--hw tpu_v5e] [--top 10]

Shows the ranked candidate table (predicted latency, bottleneck, reuse),
the simulator's cross-check, per-level byte splits on multi-level
topologies (--hw gpu_mi300x_like / gpu_h100_like), and how the choice
changes across hardware presets (paper Fig. 5 portability).
"""
import argparse

from repro.core import (GemmProblem, get_hardware, rank_candidates,
                        reuse_fraction, select_gemm_config, simulate_gemm)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=4096)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--k", type=int, default=4096)
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--hw", default="tpu_v5e")
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    hw = get_hardware(args.hw)
    p = GemmProblem(M=args.m, N=args.n, K=args.k, in_dtype=args.dtype)
    print(f"problem: {args.m}x{args.n}x{args.k} {args.dtype} on {hw.name}")
    print(f"  {p.flops/1e9:.2f} GFLOP, arithmetic intensity "
          f"{p.arithmetic_intensity:.1f} flops/byte\n")

    ranked = rank_candidates(p, hw)
    print(f"{len(ranked)} candidates; top {args.top}:")
    print(f"{'config':24s} {'model us':>9s} {'sim us':>9s} "
          f"{'TF/s(sim)':>9s} {'reuse':>6s}  bottleneck")
    for cfg, pred in ranked[:args.top]:
        sim = simulate_gemm(p, cfg, hw)
        print(f"{str(cfg):24s} {pred.total*1e6:9.1f} {sim.time*1e6:9.1f} "
              f"{p.flops/sim.time/1e12:9.1f} "
              f"{reuse_fraction(p, cfg, hw):6.2f}  {pred.bottleneck}")

    if hw.cache_levels:
        best_cfg, best_pred = ranked[0]
        sim = simulate_gemm(p, best_cfg, hw)
        print(f"\nper-level bytes for {best_cfg} "
              f"(model | simulator reuse distances):")
        for name_, b in best_pred.level_bytes.items():
            print(f"  {name_:6s} {b/1e6:12.1f} MB | "
                  f"{sim.level_bytes.get(name_, 0.0)/1e6:12.1f} MB")

    print("\nportability (same model, constants swapped — paper Fig. 5):")
    for name in ("tpu_v5e", "tpu_v5p", "tpu_v4", "gpu_mi300x_like",
                 "gpu_h100_like"):
        s = select_gemm_config(args.m, args.n, args.k, in_dtype=args.dtype,
                               hw=get_hardware(name))
        print(f"  {name:16s} -> {str(s.config):20s} "
              f"{s.predicted.total*1e6:9.1f} us  "
              f"{s.predicted_tflops:6.1f} TF/s  {s.predicted.bottleneck}")


if __name__ == "__main__":
    main()
