"""Fault-tolerance machinery for thousand-node runs.

* ``StragglerMonitor`` — rolling z-score over step times; flags slow steps
  (ICI neighbor stalls, host paging) so the launcher can alert/evict.
* ``retry`` — bounded exponential backoff around a step function; transient
  runtime errors (preempted device, DMA timeout) retry, deterministic
  errors re-raise immediately.
* ``PreemptionGuard`` — SIGTERM/SIGINT hook that flips a flag the train
  loop polls to checkpoint-and-exit cleanly inside the grace period.
* ``Heartbeat`` — liveness file another process/agent can watch.
* ``elastic_reshard`` — move a state pytree onto a *new* mesh (device count
  changed after failures) given new shardings; with checkpoints this gives
  restart-elastic scaling.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, List, Optional, Tuple

import jax


class StragglerMonitor:
    def __init__(self, window: int = 50, z_threshold: float = 4.0,
                 min_steps: int = 10):
        self.times: Deque[float] = deque(maxlen=window)
        self.z = z_threshold
        self.min_steps = min_steps
        self.flagged: List[Tuple[int, float, float]] = []
        self._step = 0

    def record(self, seconds: float) -> Optional[str]:
        self._step += 1
        msg = None
        if len(self.times) >= self.min_steps:
            mean = sum(self.times) / len(self.times)
            var = sum((t - mean) ** 2 for t in self.times) / len(self.times)
            std = max(var ** 0.5, 1e-9)
            z = (seconds - mean) / std
            if z > self.z and seconds > 1.5 * mean:
                self.flagged.append((self._step, seconds, z))
                msg = (f"straggler: step {self._step} took {seconds:.3f}s "
                       f"(z={z:.1f}, mean={mean:.3f}s)")
        self.times.append(seconds)
        return msg


_TRANSIENT_MARKERS = (
    "RESOURCE_EXHAUSTED", "UNAVAILABLE", "DEADLINE_EXCEEDED", "ABORTED",
    "preempted", "Socket closed", "transient",
)


def is_transient(err: Exception) -> bool:
    s = repr(err)
    return any(m in s for m in _TRANSIENT_MARKERS)


def retry(fn: Callable, *args, retries: int = 3, base_delay: float = 0.5,
          on_retry: Optional[Callable[[int, Exception], None]] = None,
          **kwargs):
    """Run fn with bounded exponential backoff on *transient* errors."""
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except Exception as e:                      # noqa: BLE001
            if attempt >= retries or not is_transient(e):
                raise
            if on_retry:
                on_retry(attempt, e)
            time.sleep(base_delay * (2 ** attempt))
            attempt += 1


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers; loop polls .should_stop."""

    def __init__(self, install: bool = True):
        self._stop = threading.Event()
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:
                    pass                             # non-main thread

    def _handler(self, signum, frame):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def request_stop(self):
        self._stop.set()


class Heartbeat:
    """Writes a monotonically-increasing liveness timestamp to a file."""

    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        while not self._stop.wait(self.interval):
            try:
                with open(self.path, "w") as f:
                    f.write(f"{time.time():.3f}\n")
            except OSError:
                pass

    def close(self):
        self._stop.set()


def elastic_reshard(tree: Any, new_shardings: Any) -> Any:
    """Re-place a state pytree onto new shardings (mesh may differ)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, new_shardings)
