"""Fault-tolerance machinery for thousand-node runs.

* ``StragglerMonitor`` — rolling z-score over step times; flags slow steps
  (ICI neighbor stalls, host paging) so the launcher can alert/evict.
* ``retry`` — bounded, full-jitter exponential backoff around a step
  function; transient runtime errors (preempted device, DMA timeout)
  retry, deterministic errors re-raise immediately.
* ``PreemptionGuard`` — SIGTERM/SIGINT hook that flips a flag the train
  loop polls to checkpoint-and-exit cleanly inside the grace period.
  Context-manager support restores the previous handlers on exit.
* ``Heartbeat`` — liveness file another process/agent can watch; writes
  are atomic (temp file + ``os.replace``) so a reader never observes an
  empty or partial file.
* ``elastic_reshard`` — move a state pytree onto a *new* mesh (device count
  changed after failures) given new shardings; with checkpoints this gives
  restart-elastic scaling.
"""
from __future__ import annotations

import os
import random
import signal
import tempfile
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Deque, List, Optional, Sequence, Tuple)

import jax


class StragglerMonitor:
    def __init__(self, window: int = 50, z_threshold: float = 4.0,
                 min_steps: int = 10):
        self.times: Deque[float] = deque(maxlen=window)
        self.dispatch_times: Deque[float] = deque(maxlen=window)
        self.z = z_threshold
        self.min_steps = min_steps
        self.flagged: List[Tuple[int, float, float]] = []
        self._step = 0

    def record(self, seconds: float,
               dispatch_s: Optional[float] = None) -> Optional[str]:
        """Record one step.  ``seconds`` is the step's wall/device time the
        z-score watches; ``dispatch_s`` optionally tracks the host-side
        enqueue cost separately — an async decode loop that never blocks
        has ~µs dispatches, and a dispatch that creeps toward the device
        time means the host round-trips (the bug this channel surfaces)."""
        self._step += 1
        msg = None
        if dispatch_s is not None:
            self.dispatch_times.append(dispatch_s)
        if len(self.times) >= self.min_steps:
            mean = sum(self.times) / len(self.times)
            var = sum((t - mean) ** 2 for t in self.times) / len(self.times)
            std = max(var ** 0.5, 1e-9)
            z = (seconds - mean) / std
            if z > self.z and seconds > 1.5 * mean:
                self.flagged.append((self._step, seconds, z))
                msg = (f"straggler: step {self._step} took {seconds:.3f}s "
                       f"(z={z:.1f}, mean={mean:.3f}s)")
        self.times.append(seconds)
        return msg

    def dispatch_mean(self) -> float:
        """Mean host-side dispatch seconds over the window (0.0 if the
        caller never supplied the channel)."""
        if not self.dispatch_times:
            return 0.0
        return sum(self.dispatch_times) / len(self.dispatch_times)


_TRANSIENT_MARKERS = (
    "RESOURCE_EXHAUSTED", "UNAVAILABLE", "DEADLINE_EXCEEDED", "ABORTED",
    "preempted", "Socket closed", "transient",
)


def is_transient(err: Exception,
                 extra_markers: Sequence[str] = ()) -> bool:
    s = repr(err)
    return any(m in s for m in (*_TRANSIENT_MARKERS, *extra_markers))


def retry(fn: Callable, *args, retries: int = 3, base_delay: float = 0.5,
          max_delay: float = 30.0,
          transient_markers: Sequence[str] = (),
          on_retry: Optional[Callable[[int, Exception], None]] = None,
          rng: Optional[random.Random] = None,
          **kwargs):
    """Run fn with bounded, full-jitter exponential backoff on *transient*
    errors.

    The backoff ceiling grows as ``base_delay * 2**attempt`` but is capped
    at ``max_delay`` (the unbounded seed formula slept 2+ minutes by
    attempt 8), and the actual sleep is drawn uniformly from
    ``[0, ceiling]`` — AWS-style full jitter, so a thundering herd of
    preempted replicas does not retry in lockstep.  ``transient_markers``
    extends the built-in marker set per call site (e.g. a serving stack
    whose collective layer surfaces its own error strings).  ``rng`` pins
    the jitter draw for deterministic tests (defaults to the module
    ``random``)."""
    draw = (rng or random).uniform
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except Exception as e:                      # noqa: BLE001
            if attempt >= retries or not is_transient(e, transient_markers):
                raise
            if on_retry:
                on_retry(attempt, e)
            ceiling = min(base_delay * (2 ** attempt), max_delay)
            time.sleep(draw(0.0, ceiling))
            attempt += 1


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers; loop polls .should_stop.

    Use as a context manager (or call :meth:`uninstall`) to restore the
    previous handlers — a guard that leaks its handlers past the serving
    loop turns every later Ctrl-C into a silent flag flip."""

    def __init__(self, install: bool = True):
        self._stop = threading.Event()
        self._prev = {}
        if install:
            self.install()

    def install(self) -> None:
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:
                pass                                 # non-main thread

    def uninstall(self) -> None:
        """Restore the handlers that were active before install()."""
        for sig, prev in self._prev.items():
            try:
                signal.signal(sig, prev)
            except ValueError:
                pass
        self._prev = {}

    def __enter__(self) -> "PreemptionGuard":
        return self

    def __exit__(self, *exc) -> None:
        self.uninstall()

    def _handler(self, signum, frame):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def request_stop(self):
        self._stop.set()


class Heartbeat:
    """Writes a monotonically-increasing liveness timestamp to a file.

    Writes go to a temp file in the same directory followed by
    ``os.replace`` (the selection cache's atomic-write convention): a
    watcher reading between the old truncate-then-write steps could
    observe an empty or half-written file and declare the process dead."""

    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def beat(self) -> None:
        """Write one liveness timestamp now (atomic)."""
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".hb.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(f"{time.time():.3f}\n")
            os.replace(tmp, self.path)
        except OSError:
            if os.path.exists(tmp):
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    def _run(self):
        while not self._stop.wait(self.interval):
            self.beat()

    def close(self):
        self._stop.set()


def elastic_reshard(tree: Any, new_shardings: Any) -> Any:
    """Re-place a state pytree onto new shardings (mesh may differ)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, new_shardings)
