from repro.runtime.fault_tolerance import (
    Heartbeat,
    PreemptionGuard,
    StragglerMonitor,
    elastic_reshard,
    is_transient,
    retry,
)
from repro.runtime.metrics import MetricLogger

__all__ = ["Heartbeat", "PreemptionGuard", "StragglerMonitor",
           "elastic_reshard", "is_transient", "retry", "MetricLogger"]
