"""Step metrics: rolling stats + JSONL logging.

``MetricLogger`` is a thin shim over :class:`repro.obs.metrics.JsonlSink`:
the record schema and rolling ``steps_per_s`` computation are unchanged
from the original hand-rolled implementation, but file handling (append
mode, directory creation, flush-per-record) is delegated to the shared
telemetry sink so all JSONL writers in the repo behave identically.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, Optional

from repro.obs.metrics import JsonlSink


class MetricLogger:
    def __init__(self, path: Optional[str] = None, window: int = 20):
        self.path = path
        self.window = deque(maxlen=window)
        self._sink = JsonlSink(path) if path else None

    def log(self, step: int, **metrics: Any) -> Dict:
        rec: Dict[str, Any] = {"step": step, "time": time.time()}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        if "step_time" in rec:
            self.window.append(rec["step_time"])
            rec["steps_per_s"] = (len(self.window)
                                  / max(sum(self.window), 1e-9))
        if self._sink is not None:
            self._sink.write(rec)
        return rec

    def close(self):
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    def __enter__(self) -> "MetricLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
