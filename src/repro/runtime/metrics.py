"""Step metrics: rolling stats + JSONL logging."""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Any, Dict, Optional


class MetricLogger:
    def __init__(self, path: Optional[str] = None, window: int = 20):
        self.path = path
        self.window = deque(maxlen=window)
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._f = open(path, "a")
        else:
            self._f = None

    def log(self, step: int, **metrics: Any) -> Dict:
        rec = {"step": step, "time": time.time()}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        if "step_time" in rec:
            self.window.append(rec["step_time"])
            rec["steps_per_s"] = (len(self.window)
                                  / max(sum(self.window), 1e-9))
        if self._f:
            self._f.write(json.dumps(rec) + "\n")
            self._f.flush()
        return rec

    def close(self):
        if self._f:
            self._f.close()
