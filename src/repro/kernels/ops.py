"""Public kernel ops: selector-driven, backend-switchable, jit-friendly.

Backends
--------
``pallas``            real Mosaic lowering (TPU runtime)
``pallas_interpret``  kernel body executed in Python on CPU (tests/validation)
``reference``         pure-jnp oracle with identical semantics — used by the
                      multi-pod dry-run (Mosaic cannot lower for the CPU
                      platform) and as the default on CPU hosts; its FLOP and
                      byte counts match the kernel algorithm, which is what
                      the roofline reads.

Selection happens at *trace time* from static shapes via
``repro.core.select_gemm_config`` — the tritonBLAS contract: zero autotuning,
deterministic, memoised.

Fail-soft launch (DESIGN.md §9): selector-driven launches re-validate the
selection before lowering and, on a kernel compile/launch failure, walk a
deterministic fallback ladder — next-ranked candidate, conservative safe
config, reference kernel — each transient-retried and each downgrade
reported through the selection hooks as a ``fallback:<rung>`` source.
Explicitly-passed ``config`` objects are the caller's contract and never
silently swapped: they get the transient retry but not the ladder.
"""
from __future__ import annotations

import os
import warnings
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.dtypes import DTYPE_BYTES
from repro.core.hardware import TPU_V5E
from repro.core.topology import DegradedModeWarning, HardwareSpec
from repro.core.latency import EPILOGUE_NONE, Epilogue, TileConfig, cdiv
from repro.core.selector import (Selection, emit_fallback, fallback_ladder,
                                 select_gemm_config, validate_selection)
from repro.kernels import ref
from repro.kernels.flash_attention import (
    flash_attention_pallas,
    select_attention_blocks,
)
from repro.kernels.matmul import matmul_pallas
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime.fault_tolerance import retry

_BACKENDS = ("pallas", "pallas_interpret", "reference")
_backend_override: Optional[str] = None


def set_backend(name: Optional[str]) -> None:
    """Force a kernel backend globally (None -> auto)."""
    global _backend_override
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"backend {name!r} not in {_BACKENDS}")
    _backend_override = name


def get_backend() -> str:
    if _backend_override is not None:
        return _backend_override
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in _BACKENDS:
            raise ValueError(f"REPRO_KERNEL_BACKEND={env!r} not in {_BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


# ---------------------------------------------------------------------------
# Default serving hardware.  Call sites that don't pass ``hw`` price their
# selections against this topology; ``launch/serve.py`` points it at a
# calibrated-topology artifact (or its stock-preset fallback when the
# artifact was quarantined).  ``None`` -> the tpu_v5e preset.
# ---------------------------------------------------------------------------

_hw_override: Optional[HardwareSpec] = None


def set_default_hardware(hw: Optional[HardwareSpec]) -> None:
    """Set the topology used when call sites omit ``hw`` (None -> preset)."""
    global _hw_override
    _hw_override = hw


def get_default_hardware() -> HardwareSpec:
    return _hw_override if _hw_override is not None else TPU_V5E


# ---------------------------------------------------------------------------
# Launch fault injection (the chaos harness's hook, repro.calib.faults).
# When set, the injector is invoked with the TileConfig about to launch and
# may raise — a transient-marked error exercises the retry path, anything
# else the fallback ladder.  Never set in production.
# ---------------------------------------------------------------------------

_launch_fault_injector: Optional[Callable[[TileConfig], None]] = None


def set_launch_fault_injector(
        fn: Optional[Callable[[TileConfig], None]]
) -> Optional[Callable[[TileConfig], None]]:
    """Install (or clear, with None) the launch fault injector; returns
    the previous injector so tests can restore it."""
    global _launch_fault_injector
    prev = _launch_fault_injector
    _launch_fault_injector = fn
    return prev


# Transient-retry policy for kernel launches: short, capped backoff — a
# launch retry protects against injected/driver transients, not outages.
_LAUNCH_RETRIES = 2
_LAUNCH_BASE_DELAY = 0.01
_LAUNCH_MAX_DELAY = 0.1


def _dtype_name(x) -> str:
    return jnp.dtype(x).name


def _pad2(x: jax.Array, m: int, n: int) -> jax.Array:
    pm, pn = (-x.shape[-2]) % m, (-x.shape[-1]) % n
    if pm or pn:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)])
    return x


def _normalize_epilogue(
    epilogue: Optional[Union[str, Epilogue]],
    bias, gate, residual,
) -> Epilogue:
    """Accept an Epilogue spec, an activation-name shorthand, or infer the
    spec from which operands were passed; validate operand presence."""
    if isinstance(epilogue, Epilogue):
        ep = epilogue
    elif isinstance(epilogue, str):
        ep = Epilogue(bias=bias is not None, activation=epilogue,
                      residual=residual is not None)
    else:
        ep = Epilogue(bias=bias is not None,
                      activation="swiglu_gate" if gate is not None else None,
                      residual=residual is not None)
    if ep.bias != (bias is not None):
        raise ValueError(f"epilogue {ep} vs bias operand "
                         f"{'present' if bias is not None else 'missing'}")
    if (ep.activation == "swiglu_gate") != (gate is not None):
        raise ValueError(f"epilogue {ep} vs gate operand "
                         f"{'present' if gate is not None else 'missing'}")
    if ep.residual != (residual is not None):
        raise ValueError(f"epilogue {ep} vs residual operand "
                         f"{'present' if residual is not None else 'missing'}")
    return ep


def _model_dtype_name(dt) -> str:
    """The dtype name handed to the cost model — epilogue write bytes must be
    priced in the TRUE out_dtype (bf16 halves them); fall back to f32 only
    for dtypes the model has no byte width for."""
    name = _dtype_name(dt)
    return name if name in DTYPE_BYTES else "float32"


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    out_dtype=None,
    hw: Optional[HardwareSpec] = None,
    config: Optional[TileConfig] = None,
    backend: Optional[str] = None,
    epilogue: Optional[Union[str, Epilogue]] = None,
    bias: Optional[jax.Array] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
) -> jax.Array:
    """Selector-driven fused GEMM: ``epilogue(a @ b)``.

    a: (..., M, K) [leading dims folded], b: (K, N).  Epilogue operands:
    bias (N,), gate/residual (..., M, N) matching a's leading dims.
    ``epilogue`` may be an :class:`Epilogue`, an activation name shorthand
    ("gelu" | "silu" | "swiglu_gate"), or omitted (inferred from operands).

    The analytical selection uses the *local* (per-shard) static shapes and
    the fused epilogue traffic, so calling this under shard_map gives
    per-chip-optimal tiles — the intended deployment (see
    distributed.collectives.tp_matmul).

    ``config`` (and selections made against multi-core topologies) may
    carry ``TileConfig.schedule``: ``"data_parallel"`` or ``"stream_k"``.
    The schedule is a *pricing* distinction of the occupancy-aware wave
    model (DESIGN.md §2); on the TPU backend both lower to the same
    in-kernel split-K grid (`kernels.matmul` module docstring), so passing
    a stream_k selection here is valid and numerically identical.
    """
    be = backend or get_backend()
    hw = hw if hw is not None else get_default_hardware()
    out_dtype = out_dtype or a.dtype
    ep = _normalize_epilogue(epilogue, bias, gate, residual)
    lead = a.shape[:-2] if a.ndim > 2 else ()
    M = 1
    for s in (*lead, a.shape[-2]):
        M *= s
    K, N = b.shape
    a2 = a.reshape(M, K)
    gate2 = gate.reshape(M, N) if gate is not None else None
    res2 = residual.reshape(M, N) if residual is not None else None

    def _reference() -> jax.Array:
        out = ref.matmul_ref(a2, b, out_dtype=out_dtype, epilogue=ep,
                             bias=bias, gate=gate2, residual=res2)
        return out.reshape(*lead, a.shape[-2], N) if lead else out

    if be == "reference":
        return _reference()

    selected: Optional[Selection] = None
    if config is None:
        selected = select_gemm_config(M, N, K,
                                      in_dtype=_dtype_name(a.dtype),
                                      out_dtype=_model_dtype_name(out_dtype),
                                      epilogue=ep,
                                      hw=hw)
        config = selected.config
    interpret = be == "pallas_interpret"

    def _launch(cfg: TileConfig) -> jax.Array:
        if _launch_fault_injector is not None:
            _launch_fault_injector(cfg)
        sk = cfg.split_k
        a_p = _pad2(a2, cfg.bm, cfg.bk * sk)
        b_p = _pad2(b, cfg.bk * sk, cfg.bn)
        kw = {}
        if ep.bias:
            kw["bias"] = _pad2(bias.reshape(1, N), 1, cfg.bn)
        if gate2 is not None:
            kw["gate"] = _pad2(gate2, cfg.bm, cfg.bn)
        if res2 is not None:
            kw["residual"] = _pad2(res2, cfg.bm, cfg.bn)
        out = matmul_pallas(a_p, b_p, cfg, out_dtype=out_dtype, epilogue=ep,
                            interpret=interpret, **kw)
        out = out[:M, :N]
        return out.reshape(*lead, a.shape[-2], N) if lead else out

    def _on_retry(attempt: int, e: Exception) -> None:
        obs_metrics.inc("launch_retries")
        obs_trace.event("launch_retry", cat="fault", track="launch",
                        args={"attempt": attempt, "error": repr(e),
                              "shape": [M, N, K]})

    def _try(cfg: TileConfig) -> jax.Array:
        return retry(_launch, cfg, retries=_LAUNCH_RETRIES,
                     base_delay=_LAUNCH_BASE_DELAY,
                     max_delay=_LAUNCH_MAX_DELAY,
                     on_retry=_on_retry)

    if selected is None:
        # Explicit config: the caller's contract.  Transient-retry the
        # launch, but never silently substitute a different config —
        # deterministic failures propagate.
        return _try(config)

    # Selector-driven launch: re-validate before lowering, then walk the
    # deterministic fallback ladder on any launch failure (DESIGN.md §9).
    p = selected.problem
    reason = validate_selection(p, config, hw)
    first_err: Optional[Exception] = None
    if reason is None:
        try:
            return _try(config)
        except Exception as e:                      # noqa: BLE001
            first_err = e
            reason = f"launch failed: {e!r}"
    obs_metrics.inc("launch_validation_failures")
    obs_trace.event("selection_rejected", cat="fault", track="launch",
                    args={"shape": [M, N, K], "reason": reason})
    warnings.warn(
        f"selected config {config} rejected ({reason}); "
        f"walking fallback ladder", DegradedModeWarning, stacklevel=2)
    for sel_f, rung in fallback_ladder(p, hw, config):
        if validate_selection(p, sel_f.config, hw) is not None:
            continue
        obs_metrics.inc("fallback_rungs", labels={"rung": rung})
        obs_trace.event("fallback_rung", cat="fault", track="launch",
                        args={"shape": [M, N, K], "rung": rung})
        emit_fallback(sel_f, rung)
        try:
            return _try(sel_f.config)
        except Exception as e:                      # noqa: BLE001
            first_err = first_err or e
            continue
    # Every tiled rung failed — the reference oracle is semantically
    # identical and cannot mis-tile; report it as the final rung.
    obs_metrics.inc("fallback_rungs", labels={"rung": "reference"})
    obs_trace.event("fallback_rung", cat="fault", track="launch",
                    args={"shape": [M, N, K], "rung": "reference"})
    emit_fallback(selected, "reference")
    warnings.warn(
        f"all tiled fallbacks failed for {p.M}x{p.N}x{p.K} "
        f"(first error: {first_err!r}); serving reference kernel",
        DegradedModeWarning, stacklevel=2)
    return _reference()


def expert_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    out_dtype=None,
    hw: Optional[HardwareSpec] = None,
    backend: Optional[str] = None,
    epilogue: Optional[Union[str, Epilogue]] = None,
    bias: Optional[jax.Array] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
) -> jax.Array:
    """Grouped GEMM with per-group weights: x (E, M, K) @ w (E, K, N) ->
    (E, M, N), with the same fused epilogue as :func:`matmul`.

    This is exactly the paper's "batched or grouped GEMM dimensions" case
    (§II-A): the selector prices the per-expert (M, K, N) contraction once
    and every expert reuses the config.  Epilogue operands carry the leading
    E dim: bias (E, N), gate/residual (E, M, N).
    """
    be = backend or get_backend()
    hw = hw if hw is not None else get_default_hardware()
    out_dtype = out_dtype or x.dtype
    ep = _normalize_epilogue(epilogue, bias, gate, residual)

    if be == "reference":
        acc = jnp.einsum("emk,ekn->emn", x, w,
                         preferred_element_type=jnp.float32)
        bias_b = bias[:, None, :] if bias is not None else None
        acc = ref.apply_epilogue_ref(acc, ep, bias=bias_b, gate=gate,
                                     residual=residual)
        return acc.astype(out_dtype)

    extras = []
    if ep.bias:
        extras.append(bias)
    if ep.activation == "swiglu_gate":
        extras.append(gate)
    if ep.residual:
        extras.append(residual)

    def one(xi, wi, *ex):
        it = iter(ex)
        kw = {}
        if ep.bias:
            kw["bias"] = next(it)
        if ep.activation == "swiglu_gate":
            kw["gate"] = next(it)
        if ep.residual:
            kw["residual"] = next(it)
        return matmul(xi, wi, out_dtype=out_dtype, hw=hw, backend=be,
                      epilogue=ep, **kw)

    return jax.vmap(one)(x, w, *extras)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    hw: Optional[HardwareSpec] = None,
    blocks: Optional[Tuple[int, int]] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Selector-driven attention. q: (B,H,Sq,d), k/v: (B,Hkv,Skv,d)."""
    be = backend or get_backend()
    hw = hw if hw is not None else get_default_hardware()
    if be == "reference":
        return ref.attention_ref(q, k, v, causal=causal, scale=scale)

    B, H, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    if blocks is None:
        blocks = select_attention_blocks(
            Sq, Skv, d, in_dtype=_dtype_name(q.dtype), hw=hw, causal=causal)
    bq, bkv = blocks
    bq, bkv = min(bq, max(128, Sq)), min(bkv, max(128, Skv))
    q_p = jnp.pad(q, ((0, 0), (0, 0), (0, (-Sq) % bq), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    out = flash_attention_pallas(
        q_p, k_p, v_p, block_q=bq, block_kv=bkv, causal=causal, scale=scale,
        q_len=Sq, kv_len=Skv, interpret=(be == "pallas_interpret"))
    return out[:, :, :Sq, :]
