"""Public kernel ops: selector-driven, backend-switchable, jit-friendly.

Backends
--------
``pallas``            real Mosaic lowering (TPU runtime)
``pallas_interpret``  kernel body executed in Python on CPU (tests/validation)
``reference``         pure-jnp oracle with identical semantics — used by the
                      multi-pod dry-run (Mosaic cannot lower for the CPU
                      platform) and as the default on CPU hosts; its FLOP and
                      byte counts match the kernel algorithm, which is what
                      the roofline reads.

Selection happens at *trace time* from static shapes via
``repro.core.select_gemm_config`` — the tritonBLAS contract: zero autotuning,
deterministic, memoised.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hardware import TPU_V5E, HardwareSpec
from repro.core.latency import TileConfig, cdiv
from repro.core.selector import select_gemm_config
from repro.kernels import ref
from repro.kernels.flash_attention import (
    flash_attention_pallas,
    select_attention_blocks,
)
from repro.kernels.matmul import matmul_pallas, matmul_split_k

_BACKENDS = ("pallas", "pallas_interpret", "reference")
_backend_override: Optional[str] = None


def set_backend(name: Optional[str]) -> None:
    """Force a kernel backend globally (None -> auto)."""
    global _backend_override
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"backend {name!r} not in {_BACKENDS}")
    _backend_override = name


def get_backend() -> str:
    if _backend_override is not None:
        return _backend_override
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in _BACKENDS:
            raise ValueError(f"REPRO_KERNEL_BACKEND={env!r} not in {_BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _dtype_name(x) -> str:
    return jnp.dtype(x).name


def _pad2(x: jax.Array, m: int, n: int) -> jax.Array:
    pm, pn = (-x.shape[-2]) % m, (-x.shape[-1]) % n
    if pm or pn:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)])
    return x


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    out_dtype=None,
    hw: HardwareSpec = TPU_V5E,
    config: Optional[TileConfig] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Selector-driven GEMM. a: (..., M, K) [leading dims folded], b: (K, N).

    The analytical selection uses the *local* (per-shard) static shapes, so
    calling this under shard_map gives per-chip-optimal tiles — the intended
    deployment (see distributed.collectives.tp_matmul).
    """
    be = backend or get_backend()
    out_dtype = out_dtype or a.dtype
    lead = a.shape[:-2] if a.ndim > 2 else ()
    M = 1
    for s in (*lead, a.shape[-2]):
        M *= s
    K, N = b.shape
    a2 = a.reshape(M, K)

    if be == "reference":
        out = ref.matmul_ref(a2, b, out_dtype=out_dtype)
        return out.reshape(*lead, a.shape[-2], N) if lead else out

    if config is None:
        sel = select_gemm_config(M, N, K,
                                 in_dtype=_dtype_name(a.dtype),
                                 out_dtype=_dtype_name(out_dtype)
                                 if jnp.dtype(out_dtype) == jnp.float32
                                 else "float32",
                                 hw=hw)
        config = sel.config
    interpret = be == "pallas_interpret"

    sk = config.split_k
    a_p = _pad2(a2, config.bm, config.bk * sk)
    b_p = _pad2(b, config.bk * sk, config.bn)
    if sk > 1:
        out = matmul_split_k(a_p, b_p, config, out_dtype=out_dtype,
                             interpret=interpret)
    else:
        out = matmul_pallas(a_p, b_p, config, out_dtype=out_dtype,
                            interpret=interpret)
    out = out[:M, :N]
    return out.reshape(*lead, a.shape[-2], N) if lead else out


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    hw: HardwareSpec = TPU_V5E,
    blocks: Optional[Tuple[int, int]] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Selector-driven attention. q: (B,H,Sq,d), k/v: (B,Hkv,Skv,d)."""
    be = backend or get_backend()
    if be == "reference":
        return ref.attention_ref(q, k, v, causal=causal, scale=scale)

    B, H, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    if blocks is None:
        blocks = select_attention_blocks(
            Sq, Skv, d, in_dtype=_dtype_name(q.dtype), hw=hw, causal=causal)
    bq, bkv = blocks
    bq, bkv = min(bq, max(128, Sq)), min(bkv, max(128, Skv))
    q_p = jnp.pad(q, ((0, 0), (0, 0), (0, (-Sq) % bq), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    out = flash_attention_pallas(
        q_p, k_p, v_p, block_q=bq, block_kv=bkv, causal=causal, scale=scale,
        q_len=Sq, kv_len=Skv, interpret=(be == "pallas_interpret"))
    return out[:, :, :Sq, :]
