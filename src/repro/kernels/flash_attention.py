"""Blocked online-softmax attention kernel with analytically selected blocks.

The paper scopes itself to GEMM and lists attention as future work (§III-A);
this kernel is our *beyond-paper extension*: the same latency model —
max(compute, DMA) per grid step over a VMEM-constrained candidate space —
selects (block_q, block_kv) deterministically, with zero autotuning.

Layout: q (B, H, Sq, d), k/v (B, Hkv, Skv, d); GQA is handled by mapping each
q head onto its kv group in the index maps (no materialized KV repeat).
Grid: (B, H, Tq, Tkv), kv innermost; running (m, l, acc) scratch in VMEM.
Sequences must be pre-padded to block multiples (ops.flash_attention pads and
masks).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dtypes import DTYPE_BYTES
from repro.core.hardware import TPU_V5E
from repro.core.topology import HardwareSpec
from repro.core.latency import cdiv

_NEG_INF = float("-inf")
_LANES = 128


def select_attention_blocks(
    s_q: int,
    s_kv: int,
    head_dim: int,
    *,
    in_dtype: str = "bfloat16",
    hw: HardwareSpec = TPU_V5E,
    causal: bool = False,
) -> Tuple[int, int]:
    """Analytical (block_q, block_kv) selection — tritonBLAS model applied to
    the attention inner loop (two chained GEMMs per step).

    Per (bq, bkv) grid step:
      FLOPs  = 2*bq*bkv*d (qk) + 2*bq*bkv*d (pv) + O(bq*bkv) softmax VPU work
      HBM    = (k + v blocks) = 2*bkv*d*bytes   (q amortized over Tkv)
      VMEM   = q, k, v, acc, s blocks (+double buffering on k, v)
    Score = steps * max(compute, memory); argmin over the menu.
    """
    bi = DTYPE_BYTES[in_dtype]
    menu = (128, 256, 512, 1024, 2048)
    budget = hw.vmem_budget()
    flops = hw.flops(in_dtype)
    best, best_score = None, None
    for bq in menu:
        if bq > max(s_q, 128) * 2:
            continue
        for bkv in menu:
            if bkv > max(s_kv, 128) * 2:
                continue
            # VMEM: q,acc (f32),m,l + double-buffered k,v + s scores
            use = (bq * head_dim * (bi + 4)
                   + hw.pipeline_depth * 2 * bkv * head_dim * bi
                   + bq * bkv * 4 + 2 * bq * _LANES * 4)
            if use > budget:
                continue
            steps = cdiv(s_q, bq) * cdiv(s_kv, bkv)
            if causal:
                steps = max(1, steps // 2)        # half the blocks masked off
            comp = (4.0 * bq * bkv * head_dim) / flops
            vpu = (6.0 * bq * bkv) / (hw.vmem_bandwidth / 4)  # exp/max/scale
            mem = (2.0 * bkv * head_dim * bi) / hw.hbm_bandwidth + hw.dma_fixed
            score = steps * max(comp + vpu, mem)
            key = (score, -(bq * bkv))
            if best_score is None or key < best_score:
                best, best_score = (bq, bkv), key
    assert best is not None, "attention candidate space empty"
    return best


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 n_kv: int, scale: float, causal: bool,
                 block_q: int, block_kv: int, q_len: int, kv_len: int,
                 out_dtype):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_kv
    # Skip blocks strictly above the causal diagonal.
    run = (q_start + block_q - 1 >= k_start) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)           # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_ids < kv_len                          # padding mask
        if causal:
            mask = jnp.logical_and(mask, q_ids >= k_ids)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                          # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # Rows with no valid key yet keep m = -inf; guard the exp.
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(mask, s - safe_m, _NEG_INF))
        alpha = jnp.where(jnp.isfinite(m_prev),
                          jnp.exp(m_prev - safe_m), 0.0)  # (bq, 1)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_ref[:, :1]
        denom = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(out_dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int,
    block_kv: int,
    causal: bool = False,
    scale: Optional[float] = None,
    q_len: Optional[int] = None,
    kv_len: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, Sq, d) padded to block_q; k/v: (B, Hkv, Skv, d) padded to
    block_kv.  q_len/kv_len are the *real* lengths for masking."""
    B, H, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    assert Sq % block_q == 0 and Skv % block_kv == 0
    Tq, Tkv = Sq // block_q, Skv // block_kv
    scale = scale if scale is not None else d ** -0.5
    q_len = q_len or Sq
    kv_len = kv_len or Skv

    kernel = functools.partial(
        _attn_kernel, n_kv=Tkv, scale=scale, causal=causal,
        block_q=block_q, block_kv=block_kv, q_len=q_len, kv_len=kv_len,
        out_dtype=q.dtype)

    return pl.pallas_call(
        kernel,
        grid=(B, H, Tq, Tkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, _LANES), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),        # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
