"""Pallas TPU GEMM kernel, parameterized by the analytical selector's config.

This is the tritonBLAS kernel ported to the TPU execution model: one kernel
template whose BlockSpec tiling (bm, bn, bk), grid iteration order (grouped
row swizzle) and split-K factor are *runtime parameters chosen analytically*
— never autotuned.

Grid layout: ``(num_output_tiles, Tk)`` with k innermost (the Pallas grid is
iterated row-major, last dim fastest), so the f32 accumulator scratch carries
across the k loop and flushes on the last k step.  The grouped iteration
order (paper Alg. 6's cache-tile factorization; on TPU it selects which
operand benefits from the Mosaic revisit-skip) is folded into the index maps.

Inputs must be pre-padded to block multiples — ``ops.matmul`` does this.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.latency import TileConfig, cdiv


def _swizzle(pid, Tm: int, Tn: int, group_m: int):
    """Flattened tile id -> (pid_m, pid_n) under grouped iteration order."""
    if group_m <= 1:
        return pid // Tn, pid % Tn
    group_size = group_m * Tn
    gid = pid // group_size
    first_m = gid * group_m
    rows = jnp.minimum(Tm - first_m, group_m)   # ragged final group
    local = pid % group_size
    pid_m = first_m + local % rows
    pid_n = local // rows
    return pid_m, pid_n


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    config: TileConfig,
    *,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with A:(M,K), B:(K,N) already padded to block multiples."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = config.bm, config.bn, config.bk
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"inputs must be padded to blocks: {(M, N, K)} vs {config}")
    Tm, Tn, Tk = M // bm, N // bn, K // bk
    gm = config.group_m

    def a_index(pid, k):
        pid_m, _ = _swizzle(pid, Tm, Tn, gm)
        return pid_m, k

    def b_index(pid, k):
        _, pid_n = _swizzle(pid, Tm, Tn, gm)
        return k, pid_n

    def o_index(pid, k):
        pid_m, pid_n = _swizzle(pid, Tm, Tn, gm)
        return pid_m, pid_n

    kernel = functools.partial(_matmul_kernel, n_k=Tk, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(Tm * Tn, Tk),
        in_specs=[
            pl.BlockSpec((bm, bk), a_index),
            pl.BlockSpec((bk, bn), b_index),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_index),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


def matmul_split_k(
    a: jax.Array,
    b: jax.Array,
    config: TileConfig,
    *,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Split-K variant (the paper's Stream-K analogue for small M*N grids):
    partials over k-shards computed by a vmapped kernel, combined in f32."""
    sk = config.split_k
    M, K = a.shape
    _, N = b.shape
    assert K % sk == 0, (K, sk)
    a_s = a.reshape(M, sk, K // sk).swapaxes(0, 1)          # (sk, M, K/sk)
    b_s = b.reshape(sk, K // sk, N)                          # (sk, K/sk, N)
    inner = functools.partial(
        matmul_pallas,
        config=TileConfig(bm=config.bm, bn=config.bn, bk=config.bk,
                          split_k=1, group_m=config.group_m),
        out_dtype=jnp.float32,
        interpret=interpret,
    )
    partials = jax.vmap(lambda x, y: inner(x, y))(a_s, b_s)  # (sk, M, N) f32
    return jnp.sum(partials, axis=0).astype(out_dtype)
