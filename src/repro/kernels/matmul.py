"""Pallas TPU GEMM kernel, parameterized by the analytical selector's config.

This is the tritonBLAS kernel ported to the TPU execution model: one kernel
template whose BlockSpec tiling (bm, bn, bk), grid iteration order (grouped
row swizzle), split-K factor and fused epilogue are *runtime parameters
chosen analytically* — never autotuned.

Grid layout: ``(num_output_tiles, split_k, Tk)`` iterated row-major (k
fastest, then the k-shard index), so the f32 accumulator scratch carries
across ALL of a tile's k-shards and flushes exactly once — split-K is
*in-kernel*: no ``(sk, M, N)`` HBM partial tensor, no follow-up combine pass.
The grouped iteration order (paper Alg. 6's cache-tile factorization) is
folded into the index maps; since the topology refactor the selector prices
``group_m`` per memory hierarchy — on TPU it selects which operand benefits
from the Mosaic revisit-skip, on multi-level topologies it buys L2 residency
of the re-walked operand — and this kernel executes whatever swizzle the
selection carries, semantics unchanged.

The epilogue (bias add, gelu/silu/swiglu-gate, residual add, out-dtype cast
— see ``repro.core.latency.Epilogue``) runs inside the flush step on the f32
accumulator, removing the full-output HBM round trips XLA would spend on
separate post-ops (DESIGN.md §3).

``TileConfig.schedule`` (occupancy stage, DESIGN.md §2): selections made on
multi-core topologies may carry ``schedule="stream_k"`` — a persistent
strip-scheduled kernel on GPUs.  The TPU Pallas grid is already persistent
(one sequential pipeline walks every tile), so this kernel LOWERS stream_k
to the existing split-K grid: the ``(tiles, sk, Tk)`` iteration order is
exactly the flattened strip walk of a single core, and the in-VMEM
accumulator plays the role of the strip-boundary partial (of which a
1-core schedule has none).  The field therefore changes nothing about the
lowering here — it exists so one selection table can drive both backends.

Inputs must be pre-padded to block multiples — ``ops.matmul`` does this.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.latency import EPILOGUE_NONE, Epilogue, TileConfig, cdiv


def _swizzle(pid, Tm: int, Tn: int, group_m: int):
    """Flattened tile id -> (pid_m, pid_n) under grouped iteration order."""
    if group_m <= 1:
        return pid // Tn, pid % Tn
    group_size = group_m * Tn
    gid = pid // group_size
    first_m = gid * group_m
    rows = jnp.minimum(Tm - first_m, group_m)   # ragged final group
    local = pid % group_size
    pid_m = first_m + local % rows
    pid_n = local // rows
    return pid_m, pid_n


def _apply_epilogue(acc, ep: Epilogue, bias_ref, gate_ref, res_ref):
    """Flush-step epilogue on the f32 accumulator (order: DESIGN.md §3)."""
    if ep.bias:
        acc = acc + bias_ref[...].astype(jnp.float32)
    if ep.activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif ep.activation == "silu":
        acc = jax.nn.silu(acc)
    elif ep.activation == "swiglu_gate":
        acc = jax.nn.silu(acc) * gate_ref[...].astype(jnp.float32)
    if ep.residual:
        acc = acc + res_ref[...].astype(jnp.float32)
    return acc


def _make_kernel(ep: Epilogue, n_sk: int, n_k: int, out_dtype):
    def kernel(*refs):
        a_ref, b_ref = refs[0], refs[1]
        i = 2
        bias_ref = gate_ref = res_ref = None
        if ep.bias:
            bias_ref, i = refs[i], i + 1
        if ep.activation == "swiglu_gate":
            gate_ref, i = refs[i], i + 1
        if ep.residual:
            res_ref, i = refs[i], i + 1
        o_ref, acc_ref = refs[i], refs[i + 1]

        s, k = pl.program_id(1), pl.program_id(2)

        @pl.when((s == 0) & (k == 0))
        def _zero():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                preferred_element_type=jnp.float32)

        @pl.when((s == n_sk - 1) & (k == n_k - 1))
        def _flush():
            acc = _apply_epilogue(acc_ref[...], ep,
                                  bias_ref, gate_ref, res_ref)
            o_ref[...] = acc.astype(out_dtype)

    return kernel


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    config: TileConfig,
    *,
    out_dtype=jnp.float32,
    epilogue: Optional[Epilogue] = None,
    bias: Optional[jax.Array] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    interpret: bool = False,
) -> jax.Array:
    """C = epilogue(A @ B) with A:(M,K), B:(K,N) already padded to block
    multiples (K to ``bk * split_k``).  Epilogue operands, when present, are
    padded alongside the output: bias (1, N), gate/residual (M, N).

    One ``pallas_call`` regardless of split_k: k-shards accumulate into the
    VMEM scratch and the output is written exactly once.
    ``config.schedule`` is accepted from any selection (TPU or GPU-shaped
    topology) and lowered identically — ``stream_k`` degenerates to the
    sequential split-K grid on a single-core chip (module docstring).
    """
    ep = epilogue or EPILOGUE_NONE
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = config.bm, config.bn, config.bk
    sk = config.split_k
    assert M % bm == 0 and N % bn == 0 and K % (bk * sk) == 0, (
        f"inputs must be padded to blocks: {(M, N, K)} vs {config}")
    Tm, Tn = M // bm, N // bn
    Tk = K // (bk * sk)                 # k blocks per shard
    gm = config.group_m

    def a_index(pid, s, k):
        pid_m, _ = _swizzle(pid, Tm, Tn, gm)
        return pid_m, s * Tk + k

    def b_index(pid, s, k):
        _, pid_n = _swizzle(pid, Tm, Tn, gm)
        return s * Tk + k, pid_n

    def out_index(pid, s, k):
        pid_m, pid_n = _swizzle(pid, Tm, Tn, gm)
        return pid_m, pid_n

    def bias_index(pid, s, k):
        _, pid_n = _swizzle(pid, Tm, Tn, gm)
        return 0, pid_n

    inputs = [a, b]
    in_specs = [
        pl.BlockSpec((bm, bk), a_index),
        pl.BlockSpec((bk, bn), b_index),
    ]
    if ep.bias:
        assert bias is not None and bias.shape == (1, N), (
            "bias must be pre-shaped (1, N)", None if bias is None
            else bias.shape)
        inputs.append(bias)
        in_specs.append(pl.BlockSpec((1, bn), bias_index))
    if ep.activation == "swiglu_gate":
        assert gate is not None and gate.shape == (M, N), (
            "gate must be pre-padded (M, N)")
        inputs.append(gate)
        in_specs.append(pl.BlockSpec((bm, bn), out_index))
    if ep.residual:
        assert residual is not None and residual.shape == (M, N), (
            "residual must be pre-padded (M, N)")
        inputs.append(residual)
        in_specs.append(pl.BlockSpec((bm, bn), out_index))

    kernel = _make_kernel(ep, n_sk=sk, n_k=Tk, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(Tm * Tn, sk, Tk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), out_index),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*inputs)
