"""Pallas TPU kernels for the perf-critical hot spots + selector-driven ops."""
from repro.kernels.ops import (
    flash_attention,
    get_backend,
    matmul,
    set_backend,
)
from repro.kernels.flash_attention import select_attention_blocks

__all__ = ["flash_attention", "get_backend", "matmul", "set_backend",
           "select_attention_blocks"]
