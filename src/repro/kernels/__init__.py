"""Pallas TPU kernels for the perf-critical hot spots + selector-driven ops."""
from repro.core.latency import EPILOGUE_NONE, Epilogue
from repro.kernels.ops import (
    expert_matmul,
    flash_attention,
    get_backend,
    matmul,
    set_backend,
)
from repro.kernels.flash_attention import select_attention_blocks

__all__ = ["EPILOGUE_NONE", "Epilogue", "expert_matmul", "flash_attention",
           "get_backend", "matmul", "set_backend",
           "select_attention_blocks"]
