"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.latency import EPILOGUE_NONE, Epilogue


def apply_epilogue_ref(
    acc: jax.Array,
    ep: Epilogue,
    *,
    bias: Optional[jax.Array] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
) -> jax.Array:
    """The fused kernel's flush-step epilogue, in f32, same operation order
    (DESIGN.md §3): +bias -> activation (silu(y)*gate for swiglu_gate) ->
    +residual.  Caller casts to out_dtype."""
    acc = acc.astype(jnp.float32)
    if ep.bias:
        acc = acc + bias.astype(jnp.float32)
    if ep.activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif ep.activation == "silu":
        acc = jax.nn.silu(acc)
    elif ep.activation == "swiglu_gate":
        acc = jax.nn.silu(acc) * gate.astype(jnp.float32)
    if ep.residual:
        acc = acc + residual.astype(jnp.float32)
    return acc


def matmul_ref(
    a: jax.Array,
    b: jax.Array,
    out_dtype=jnp.float32,
    *,
    epilogue: Optional[Epilogue] = None,
    bias: Optional[jax.Array] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
) -> jax.Array:
    """C = epilogue(A @ B). a: (..., M, K), b: (K, N).

    For bf16 outputs *without* an epilogue the dot's preferred_element_type
    is bf16: the MXU still accumulates in f32 internally, but TP partial sums
    then cross the ICI in bf16 — halving the row-parallel all-reduce wire
    bytes (EXPERIMENTS.md §Perf).  Epilogue paths accumulate and fuse in f32
    exactly like the kernel's flush, then cast."""
    ep = epilogue or EPILOGUE_NONE
    if ep.is_identity:
        if jnp.dtype(out_dtype) == jnp.bfloat16:
            return jnp.matmul(a, b, preferred_element_type=jnp.bfloat16)
        return jnp.matmul(a, b,
                          preferred_element_type=jnp.float32).astype(out_dtype)
    acc = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    acc = apply_epilogue_ref(acc, ep, bias=bias, gate=gate, residual=residual)
    return acc.astype(out_dtype)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    kv_len: Optional[int] = None,
) -> jax.Array:
    """Dense softmax attention oracle with GQA head-group broadcast.

    q: (B, H, Sq, d); k, v: (B, Hkv, Skv, d). Returns (B, H, Sq, d).
    """
    B, H, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    group = H // Hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if kv_len is not None:
        mask = mask & (jnp.arange(Skv)[None, :] < kv_len)
    if causal:
        mask = mask & (jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :])
    s = jnp.where(mask, s, float("-inf"))
    # Guard fully-masked rows (padding queries): softmax of all -inf -> 0.
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom > 0, denom, 1.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
