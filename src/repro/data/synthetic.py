"""Deterministic synthetic LM data pipeline.

Every batch is a pure function of (seed, step, host) — restart-safe by
construction (checkpoint restore resumes the stream exactly), sharded per
host, with a background prefetch thread.  Token draws follow a power-law
over the vocab (Zipf-ish) so the loss curve behaves like language rather
than uniform noise.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    skew: float = 3.0            # power-law exponent for token frequencies


class SyntheticLM:
    """Host-sharded deterministic token stream."""

    def __init__(self, cfg: DataConfig,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.cfg = cfg
        self.pi = (jax.process_index() if process_index is None
                   else process_index)
        self.pc = (jax.process_count() if process_count is None
                   else process_count)
        assert cfg.global_batch % self.pc == 0, (cfg.global_batch, self.pc)
        self.local_batch = cfg.global_batch // self.pc

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The (deterministic) local batch for a given global step."""
        c = self.cfg
        rng = np.random.default_rng(
            np.uint64(hash((c.seed, int(step), self.pi)) & 0x7FFFFFFFFFFFFFF))
        u = rng.random((self.local_batch, c.seq_len))
        tokens = np.floor((u ** c.skew) * c.vocab_size).astype(np.int32)
        # Inject structure: short repeated motifs so the LM has signal.
        motif = rng.integers(0, c.vocab_size, size=(8,), dtype=np.int32)
        pos = rng.integers(0, max(1, c.seq_len - 8))
        tokens[:, pos:pos + 8] = motif
        return {"tokens": tokens}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-N) over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
