"""Mesh-level applications of the analytical model (beyond-paper extension).

The paper scopes itself to one GPU (§III-A Non-Goals).  We extend its
max(compute, data-movement) scoring with ICI terms to *rank sharding
layouts* for a GEMM on the production mesh — the same zero-autotune
decision procedure, one level up the hierarchy:

    per-chip GEMM latency (paper model)  vs  collective latency (ring model)

``tp_matmul`` is the deployment shape for the Pallas kernel under TP: a
shard_map whose *local* shapes feed the selector (per-chip-optimal tiles)
followed by the psum the layout chooser priced.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                   # jax >= 0.5 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.dtypes import DTYPE_BYTES
from repro.core.hardware import TPU_V5E
from repro.core.topology import HardwareSpec
from repro.core.latency import GemmProblem
from repro.core.selector import select_gemm_config
from repro.kernels import ops as kops


def ring_all_reduce_s(nbytes: float, n: int, hw: HardwareSpec) -> float:
    """Bidirectional-ring all-reduce time: 2(n-1)/n * bytes / link_bw."""
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * nbytes / hw.ici_bandwidth


def ring_all_gather_s(nbytes_local: float, n: int, hw: HardwareSpec) -> float:
    if n <= 1:
        return 0.0
    return (n - 1) * nbytes_local / hw.ici_bandwidth


@dataclass(frozen=True)
class LayoutChoice:
    layout: str            # "dp" | "tp_n" | "tp_k" | "replicated"
    predicted_s: float
    per_chip: Tuple[int, int, int]
    collective_s: float


def choose_gemm_layout(M: int, N: int, K: int, n_chips: int,
                       in_dtype: str = "bfloat16",
                       hw: HardwareSpec = TPU_V5E) -> LayoutChoice:
    """Rank {row-shard M (DP), col-shard N (TP-n), shard K (TP-k + psum)}
    with the paper's per-chip latency model + ring collective terms."""
    b = DTYPE_BYTES[in_dtype]
    cands = []
    if M % n_chips == 0:
        sel = select_gemm_config(M // n_chips, N, K, in_dtype=in_dtype, hw=hw)
        cands.append(LayoutChoice("dp", sel.predicted.total,
                                  (M // n_chips, N, K), 0.0))
    if N % n_chips == 0:
        sel = select_gemm_config(M, N // n_chips, K, in_dtype=in_dtype, hw=hw)
        cands.append(LayoutChoice("tp_n", sel.predicted.total,
                                  (M, N // n_chips, K), 0.0))
    if K % n_chips == 0:
        sel = select_gemm_config(M, N, K // n_chips, in_dtype=in_dtype, hw=hw)
        coll = ring_all_reduce_s(M * N * 4.0, n_chips, hw)
        cands.append(LayoutChoice(
            "tp_k", sel.predicted.total + coll, (M, N, K // n_chips), coll))
    if not cands:
        sel = select_gemm_config(M, N, K, in_dtype=in_dtype, hw=hw)
        cands.append(LayoutChoice("replicated", sel.predicted.total,
                                  (M, N, K), 0.0))
    return min(cands, key=lambda c: c.predicted_s)


def tp_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, axis: str = "model",
              *, reduce_k: bool = False, backend: Optional[str] = None
              ) -> jax.Array:
    """Tensor-parallel GEMM via shard_map: the selector sees LOCAL shapes.

    reduce_k=False: w column-sharded (D, F/axis) -> output sharded on F.
    reduce_k=True : w row-sharded (D/axis, F), x sharded on D -> psum."""
    if reduce_k:
        in_specs = (P(None, axis), P(axis, None))
        out_spec = P(None, None)

        def f(xl, wl):
            y = kops.matmul(xl, wl, backend=backend, out_dtype=jnp.float32)
            return jax.lax.psum(y, axis)
    else:
        in_specs = (P(None, None), P(None, axis))
        out_spec = P(None, axis)

        def f(xl, wl):
            return kops.matmul(xl, wl, backend=backend)

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_spec)(x, w)
