"""Logical-axis -> mesh-axis sharding rules (t5x-style), with auto-drop.

Every parameter declares logical axis names (layers.ParamDef); this module
maps them onto the production mesh ("pod", "data", "model").  Two safety
mechanisms make one rule table serve all ten architectures:

* divisibility auto-drop: a mapping is applied only if the dim divides by
  the mesh-axis product (e.g. mixtral's 8 experts don't divide the 16-way
  "model" axis -> the experts dim stays replicated and per-expert d_ff
  picks the axis up instead);
* first-come-first-served axes: within one array each mesh axis is used at
  most once, scanning dims left to right (e.g. qwen3 experts take "model",
  so per-expert mlp stays unsharded).

FSDP (cfg.fsdp) adds "embed" -> "data": every weight then carries a second
shard axis, and XLA SPMD inserts the ZeRO-3-style all-gathers at use sites.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.config import ModelConfig, ShapeSpec
from repro.nn.model import Model

BATCH_AXES = ("pod", "data")
SEQ_AXES = ("pod", "data", "model")    # KV-seq fallback for tiny batches


def rules_for(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "state": None,
        "embed": "data" if cfg.fsdp else None,
        "embed_novar": None,          # embed/lm_head d_model: never FSDP
        # Expert axes mirror the dense rules.  Two measured dead ends
        # (EXPERIMENTS.md §Perf it. 9): F->("model","data") turns wd into
        # 256-way partial sums (4x worse); D->None un-FSDPs 268 GB of
        # mixtral expert weights (OOM).  The real fix is a dedicated EP
        # mesh axis + all-to-all dispatch (designed, not yet implemented).
        "expert_embed": "data" if cfg.fsdp else None,
        "expert_mlp": "model",
        "layers": None,
        "experts_in": None,
    }


def spec_for(shape: Sequence[int], axes: Optional[Sequence[Optional[str]]],
             rules: Dict[str, Any], mesh: Mesh) -> P:
    axes = axes if axes is not None else [None] * len(shape)
    used: set = set()
    parts = []
    for dim, name in zip(shape, axes):
        target = rules.get(name) if name else None
        if target is None:
            parts.append(None)
            continue
        cand = target if isinstance(target, tuple) else (target,)
        sel = [a for a in cand if a in mesh.shape and a not in used]
        total = int(np.prod([mesh.shape[a] for a in sel])) if sel else 1
        if sel and dim % total == 0:
            parts.append(tuple(sel) if len(sel) > 1 else sel[0])
            used.update(sel)
        else:
            parts.append(None)
    return P(*parts)


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# Trees of shardings for params / optimizer / batches / caches.
# ---------------------------------------------------------------------------

def param_shardings(model: Model, mesh: Mesh) -> Any:
    rules = rules_for(model.cfg)
    abst = model.abstract_params()
    axes = model.param_axes()

    def one(a, ax):
        return _named(mesh, spec_for(a.shape, ax, rules, mesh))

    return jax.tree_util.tree_map(one, abst, axes)


def opt_shardings(param_sh: Any, mesh: Mesh) -> Any:
    """Adam m/v mirror the param shardings; the count scalar is replicated."""
    from repro.optim.adamw import OptState
    return OptState(m=param_sh, v=param_sh, count=_named(mesh, P()))


def batch_shardings(specs: Dict, mesh: Mesh) -> Dict:
    """tokens (B, S) / frame_embed (B, S, D) / patch_embed (B, P, D) /
    decode tokens (B,) / pos scalar."""
    out = {}
    for name, s in specs.items():
        if s.ndim == 0:
            out[name] = _named(mesh, P())
            continue
        batch_axes = [a for a in BATCH_AXES if a in mesh.shape]
        total = int(np.prod([mesh.shape[a] for a in batch_axes])) or 1
        first = tuple(batch_axes) if batch_axes and s.shape[0] % total == 0 \
            else None
        parts = [first] + [None] * (s.ndim - 1)
        out[name] = _named(mesh, P(*parts))
    return out


def cache_shardings(cache_specs: Any, mesh: Mesh, cfg: ModelConfig) -> Any:
    """Decode-cache layout: batch over ("pod","data"); KV sequence over
    "model" (flash-decode); with tiny batches the sequence dim absorbs the
    idle batch axes too (long_500k: S over ("pod","data","model"))."""
    def one(path, s):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        used: set = set()
        batch_axes = [a for a in BATCH_AXES if a in mesh.shape]
        bt = int(np.prod([mesh.shape[a] for a in batch_axes])) or 1

        if name.endswith("k") or name.endswith("v"):
            # (L, B, Hkv, S, d)
            _, B, Hkv, S, _ = s.shape
            parts: list = [None] * 5
            if batch_axes and B % bt == 0:
                parts[1] = tuple(batch_axes)
                used.update(batch_axes)
            seq_axes = [a for a in SEQ_AXES
                        if a in mesh.shape and a not in used]
            st = int(np.prod([mesh.shape[a] for a in seq_axes])) or 1
            if seq_axes and S % st == 0:
                parts[3] = tuple(seq_axes) if len(seq_axes) > 1 \
                    else seq_axes[0]
            return _named(mesh, P(*parts))

        # mamba caches: (L, B, ...) — batch + channel/head dims.
        parts = [None] * s.ndim
        B = s.shape[1]
        if batch_axes and B % bt == 0:
            parts[1] = tuple(batch_axes)
            used.update(batch_axes)
        if "model" in mesh.shape:
            m = mesh.shape["model"]
            # shard the widest remaining dim that divides
            order = sorted(range(2, s.ndim), key=lambda i: -s.shape[i])
            for i in order:
                if s.shape[i] % m == 0:
                    parts[i] = "model"
                    break
        return _named(mesh, P(*parts))

    paths = jax.tree_util.tree_flatten_with_path(cache_specs)
    leaves = [one(p, s) for p, s in paths[0]]
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def replicated(mesh: Mesh) -> NamedSharding:
    return _named(mesh, P())
