from repro.meshctx import constrain, get_mesh, set_mesh
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    opt_shardings,
    param_shardings,
    replicated,
    rules_for,
    spec_for,
)
from repro.distributed.collectives import (
    LayoutChoice,
    choose_gemm_layout,
    ring_all_gather_s,
    ring_all_reduce_s,
    tp_matmul,
)

__all__ = ["constrain", "get_mesh", "set_mesh", "batch_shardings", "cache_shardings", "opt_shardings",
           "param_shardings", "replicated", "rules_for", "spec_for",
           "LayoutChoice", "choose_gemm_layout", "ring_all_gather_s",
           "ring_all_reduce_s", "tp_matmul"]
