"""Gradient compression for the data-parallel axes (distributed-optimization
trick): int8 quantization with error feedback.

The bandwidth-honest collective shape: ``all_gather`` of int8 shards + local
dequant-reduce moves 1/4 the bytes of an f32 all-reduce (and 1/2 of bf16).
Error feedback keeps the quantization bias out of the trajectory (Seide et
al.; Karimireddy et al. 2019).  Used inside shard_map over the DP axes —
see distributed.collectives.compressed_psum and launch.train (--compress-dp).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, err: jax.Array
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q, scale, new_err): err accumulates what int8 dropped."""
    y = g.astype(jnp.float32) + err
    q, scale = quantize_int8(y)
    new_err = y - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis_name
                    ) -> Tuple[jax.Array, jax.Array]:
    """Mean-reduce g over ``axis_name`` moving int8 on the wire.

    Must run inside shard_map/pmap with ``axis_name`` bound.  Returns
    (mean_g_f32, new_err)."""
    q, scale, new_err = compress_with_feedback(g, err)
    qs = jax.lax.all_gather(q, axis_name)          # int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)
    n = qs.shape[0]
    total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))
    return total / n, new_err
