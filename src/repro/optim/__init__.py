from repro.optim.adamw import AdamW, OptState, global_norm
from repro.optim.compression import (
    compress_with_feedback,
    compressed_psum,
    dequantize_int8,
    quantize_int8,
)
from repro.optim.schedule import constant, warmup_cosine

__all__ = ["AdamW", "OptState", "global_norm", "compress_with_feedback",
           "compressed_psum", "dequantize_int8", "quantize_int8",
           "constant", "warmup_cosine"]
