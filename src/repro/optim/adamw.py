"""AdamW with global-norm clipping — functional, pytree-native.

Moments are kept in f32 regardless of param dtype (bf16 params + f32 state is
the deployment configuration the dry-run memory analysis accounts)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


class OptState(NamedTuple):
    m: Dict
    v: Dict
    count: jax.Array


@dataclass(frozen=True)
class AdamW:
    lr: Union[float, Schedule] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Dict) -> OptState:
        zeros = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda p: jnp.zeros(p.shape, jnp.float32), t)
        return OptState(m=zeros(params), v=zeros(params),
                        count=jnp.zeros((), jnp.int32))

    def abstract_state(self, abstract_params: Dict) -> OptState:
        f32 = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), t)
        return OptState(m=f32(abstract_params), v=f32(abstract_params),
                        count=jax.ShapeDtypeStruct((), jnp.int32))

    def update(self, grads: Dict, state: OptState, params: Dict
               ) -> Tuple[Dict, OptState, Dict]:
        """Returns (new_params, new_state, metrics)."""
        count = state.count + 1
        gnorm = global_norm(grads)
        if self.clip_norm > 0:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
        else:
            scale = jnp.ones_like(gnorm)
        lr = self.lr(count) if callable(self.lr) else jnp.float32(self.lr)
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m2 / b1c
            vh = v2 / b2c
            step = mh / (jnp.sqrt(vh) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, OptState(m=new_m, v=new_v, count=count), metrics


def global_norm(tree: Dict) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
