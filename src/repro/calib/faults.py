"""Seeded, deterministic fault injection for the whole pipeline (DESIGN.md §9).

The calibration -> selection -> serving path assumes a trusted measurement
substrate; production does not grant one.  This module makes every failure
mode the fail-soft layer handles *reproducible in CI*:

* :class:`FaultPlan` — the seeded fault schedule.  Each injection site
  draws from a hash of ``(seed, site, kind, call-index)`` — the same seed
  and the same call sequence always produce the same fault sequence, with
  no shared RNG stream to perturb (the VirtualDevice jitter convention).
  Every fired fault is appended to ``plan.log`` so tests can assert the
  exact sequence.
* :class:`FaultyDevice` — decorates any :class:`~repro.calib.device.Device`
  with probe-layer faults: hangs (caught by the ``probes.py`` watchdog
  deadline), NaN, multiplicative outliers (Theil–Sen's job), and
  sign-flipped measurements (physically impossible; the probe layer drops
  them).
* :func:`launch_injector` / :func:`scripted_injector` — callables for
  ``kernels.ops.set_launch_fault_injector``: seeded compile/transient
  launch failures, or an exact scripted sequence for ladder tests.
* :func:`decode_injector` — per-step transient faults for the serving
  loop's retry path (``launch/serve.py``).
* Artifact/cache corruption helpers — tampered fingerprints, truncated
  (mid-write) files, and parseable-but-illegal cache entries.

Faults are injected at *wrapper* boundaries only; no production module
imports this one.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.calib.device import Device
from repro.core.latency import GemmProblem, TileConfig

# Probe-measurement fault kinds, in draw order (at most one fires per
# call — earlier kinds shadow later ones, so rates compose predictably).
PROBE_FAULT_KINDS = ("timeout", "nan", "outlier", "signflip")


@dataclass
class FaultPlan:
    """A seeded, deterministic fault schedule.

    Rates are per-call probabilities in ``[0, 1]``.  Each (site, kind)
    pair keeps its own call counter; the k-th draw for a pair is a pure
    function of ``(seed, site, kind, k)`` — deterministic, order-robust
    across unrelated sites, and replayable: re-running the same workload
    against ``FaultPlan(seed=s, ...)`` reproduces the identical fault
    sequence (acceptance criterion of ISSUE 6).
    """

    seed: int = 0
    # --- probe-layer measurement faults (FaultyDevice) ---
    probe_timeout: float = 0.0    # hang for hang_s (watchdog's job)
    probe_nan: float = 0.0        # measurement comes back NaN
    probe_outlier: float = 0.0    # measurement x outlier_factor
    probe_signflip: float = 0.0   # measurement negated (impossible value)
    # --- kernel-launch faults (launch_injector) ---
    launch_compile: float = 0.0   # deterministic "compile failure"
    launch_transient: float = 0.0  # transient-marked launch failure
    # --- serving faults (decode_injector) ---
    decode_transient: float = 0.0
    # --- fault shapes ---
    hang_s: float = 0.05          # how long a "timeout" fault blocks
    outlier_factor: float = 40.0  # survives Theil-Sen, wrecks least squares
    log: List[Tuple[str, int, str]] = field(default_factory=list)
    _counters: Dict[Tuple[str, str], int] = field(default_factory=dict)

    def _rate(self, kind: str) -> float:
        return float(getattr(self, kind))

    def draw(self, site: str, kind: str) -> bool:
        """Advance the (site, kind) counter and decide whether the fault
        fires this call; fired faults are recorded in ``log``."""
        rate = self._rate(kind)
        k = self._counters.get((site, kind), 0)
        self._counters[(site, kind)] = k + 1
        if rate <= 0.0:
            return False
        h = hashlib.md5(repr((self.seed, site, kind, k)).encode()).digest()
        u = int.from_bytes(h[:8], "big") / float(1 << 64)     # [0, 1)
        fired = u < rate
        if fired:
            self.log.append((site, k, kind))
        return fired

    def probe_fault(self, site: str) -> Optional[str]:
        """The probe-fault kind firing for this call, if any (first in
        ``PROBE_FAULT_KINDS`` order wins; every kind's counter advances
        so the sequence stays deterministic regardless of which fires)."""
        fired = None
        for kind in PROBE_FAULT_KINDS:
            if self.draw(site, f"probe_{kind}") and fired is None:
                fired = kind
        return fired

    def reset(self) -> None:
        """Rewind to the pristine schedule (counters and log cleared) —
        replaying the same workload reproduces the same faults."""
        self._counters.clear()
        self.log.clear()


class FaultyDevice:
    """A :class:`Device` decorated with a :class:`FaultPlan`.

    Each timing primitive draws its probe faults under its own site name
    (``stream`` / ``compute`` / ``wave`` / ``gemm``), then corrupts the
    inner device's honest measurement:

    * ``timeout``  — block for ``plan.hang_s`` before answering; only the
      probes' watchdog deadline turns this into a dropped sample.
    * ``nan``      — NaN (``validate_measured``-class poison).
    * ``outlier``  — honest value x ``plan.outlier_factor``; must be
      survived by the robust fit, not the probe layer.
    * ``signflip`` — honest value negated; physically impossible, dropped
      at the probe layer like any non-positive sample.
    """

    def __init__(self, inner: Device, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.name = f"faulty:{inner.name}"

    def _corrupt(self, site: str, value: float) -> float:
        kind = self.plan.probe_fault(site)
        if kind == "timeout":
            time.sleep(self.plan.hang_s)
            return value
        if kind == "nan":
            return float("nan")
        if kind == "outlier":
            return value * self.plan.outlier_factor
        if kind == "signflip":
            return -value
        return value

    def stream_time(self, nbytes: float, window: int,
                    n_chunks: int) -> float:
        return self._corrupt(
            "stream", self.inner.stream_time(nbytes, window, n_chunks))

    def compute_time(self, dtype: str, n_atoms: int,
                     n_parallel: int = 1) -> float:
        return self._corrupt(
            "compute", self.inner.compute_time(dtype, n_atoms, n_parallel))

    def wave_time(self, n_units: int, unit_atoms: int,
                  dtype: str) -> float:
        return self._corrupt(
            "wave", self.inner.wave_time(n_units, unit_atoms, dtype))

    def gemm_time(self, p: GemmProblem, t: TileConfig) -> float:
        return self._corrupt("gemm", self.inner.gemm_time(p, t))


# ---------------------------------------------------------------------------
# Kernel-launch and serving injectors.  The "transient" marker string is in
# runtime.fault_tolerance._TRANSIENT_MARKERS, so transient-kind faults are
# retried in place; compile-kind faults are deterministic and drive the
# fallback ladder.
# ---------------------------------------------------------------------------


class InjectedCompileError(RuntimeError):
    """A deterministic injected kernel compile/lowering failure."""


class InjectedTransientError(RuntimeError):
    """An injected transient fault (repr carries the 'transient' marker)."""


def launch_injector(plan: FaultPlan) -> Callable[[TileConfig], None]:
    """An injector for ``kernels.ops.set_launch_fault_injector`` drawing
    from ``plan``: compile faults (deterministic -> ladder) are drawn
    first, then transient faults (-> in-place retry)."""
    def inject(cfg: TileConfig) -> None:
        if plan.draw("launch", "launch_compile"):
            raise InjectedCompileError(
                f"injected compile failure for {cfg}")
        if plan.draw("launch", "launch_transient"):
            raise InjectedTransientError(
                f"transient: injected launch fault for {cfg}")
    return inject


def scripted_injector(
        script: Sequence[Optional[Exception]]) -> Callable[[TileConfig], None]:
    """An injector that replays an exact failure script: the i-th launch
    attempt raises ``script[i]`` (None -> succeed); attempts beyond the
    script succeed.  For ladder tests that need a precise sequence like
    [compile, compile, None] without tuning seeds."""
    it = iter(list(script))

    def inject(cfg: TileConfig) -> None:
        err = next(it, None)
        if err is not None:
            raise err
    return inject


def decode_injector(plan: FaultPlan) -> Callable[..., None]:
    """A per-decode-step fault hook for the serving loop
    (``run_serving(..., decode_fault=...)``): raises an
    :class:`InjectedTransientError` (retried by the loop's ``retry``
    wrapper) when the plan's ``decode_transient`` draw fires.  The hook
    runs *before* the step's donated-cache execution, so a retry replays
    an intact cache.  ``guard`` is the serving loop's PreemptionGuard —
    unused here, available to custom hooks (e.g. request a drain)."""
    def inject(step: int, guard=None) -> None:
        if plan.draw("decode", "decode_transient"):
            raise InjectedTransientError(
                f"transient: injected decode fault at step {step}")
    return inject


# ---------------------------------------------------------------------------
# Artifact / cache corruption.  These mutate files the way real rot does —
# a partial write, a bit-rotted constant, an entry edited out-of-band — so
# the guarded loaders' quarantine/fall-through behaviour is testable.
# ---------------------------------------------------------------------------


def tamper_artifact_fingerprint(path: str) -> None:
    """Edit one topology constant in a calibrated-topology artifact while
    leaving its recorded fingerprint untouched — the canonical 'constants
    edited after the fit' corruption ``load_calibrated_topology`` must
    reject."""
    with open(path) as f:
        doc = json.load(f)
    levels = doc["topology"]["levels"]
    levels[0]["bandwidth"] = float(levels[0]["bandwidth"]) * 1.5
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)


def truncate_file(path: str, frac: float = 0.5) -> None:
    """Cut a file mid-write: keep the leading ``frac`` of its bytes — the
    on-disk state a crash between ``write`` and ``replace`` leaves behind
    for any NON-atomic writer."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(int(size * frac), 1))


def corrupt_cache_entry(path: str, *, bm: int = 12288) -> int:
    """Tamper every entry of a persistent selection-cache file into a
    parseable-but-illegal config (non-menu, budget-busting ``bm``) without
    touching its topology fingerprint — valid JSON that only per-entry
    re-validation (``validate_selection``) can catch.  Returns the number
    of entries tampered."""
    with open(path) as f:
        table = json.load(f)
    n = 0
    for entry in table.values():
        cfg = entry.get("config")
        if isinstance(cfg, dict):
            cfg["bm"] = bm
            n += 1
    with open(path, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    return n
