"""Microbenchmark probes: the measurement layer of calibration (DESIGN.md §8).

Each probe runs a sweep of one :class:`~repro.calib.device.Device`
primitive and returns a :class:`ProbeSweep` — the raw ``(x, seconds)``
samples plus the fixed parameters, which the fit layer turns into Topology
constants and which land verbatim in the calibrated-topology artifact's
provenance.  Probes never fit; fits never measure.

The sweeps, and what their slopes/intercepts mean (``fit.py``):

* ``stream:<level>`` — nbytes sweep at a *fixed* reuse window targeting one
  memory level (bigger than every inner level's budget, within the target's)
  with a fixed chunk count, so ``d(time)/d(nbytes) = 1/bandwidth(level)``.
* ``latency`` — single-pass small transfers (``window == nbytes``,
  one chunk): the intercept isolates launch + first-byte latency.
* ``issue`` — chunk-count sweep at fixed bytes/window:
  ``d(time)/d(n_chunks) = dma_fixed``.
* ``compute:<dtype>`` — macro-atom count sweep on resident operands:
  ``d(time)/d(n_atoms) = atom_flops / peak_flops[dtype]``.
* ``wave`` — work-unit sweep in exact multiples of the declared core count:
  ``d(time)/d(waves)`` is the per-wave unit time under the occupancy
  stage's *static* 1/C bandwidth-share simplification, and the intercept is
  ``kernel_launch``.  Two extra off-staircase samples (C and C+1 units)
  record the tail-wave cliff itself.

Window targeting walks the declared capacity chain — capacities and core
counts are structural datasheet facts; calibration measures *rates*
(paper §V-E: retarget by swapping measured constants only).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.calib.device import Device
from repro.core.topology import Topology, reference_dtype

# Target wall times per sweep point.  Sweep sizes (bytes, atoms, chunk
# counts) are derived from these and the *base* preset's order-of-magnitude
# constants, so every probe's signal dwarfs launch overhead and measurement
# noise on machines of any speed — a fixed atom count that keeps a TPU busy
# for 20 us vanishes inside the launch jitter of a chip with 16^3 atoms.
# Sizing only needs the preset to be right to an order of magnitude; the
# fit replaces the constants with what was measured.
STREAM_TARGETS_S = (50e-6, 100e-6, 200e-6, 400e-6, 800e-6)
LATENCY_TARGETS_S = (0.5e-6, 1e-6, 1.5e-6, 2e-6, 3e-6, 4e-6)
ISSUE_TARGETS_S = (6.25e-6, 12.5e-6, 25e-6, 50e-6)
COMPUTE_TARGETS_S = (20e-6, 40e-6, 80e-6, 160e-6)
WAVE_UNIT_TARGET_S = 5e-6
WAVE_MULTIPLES = (1, 2, 3, 4, 5, 6, 7, 8)  # x total_cores -> exact waves


@dataclass(frozen=True)
class ProbeSweep:
    """One probe's raw measurements: ``samples[i] = (x_i, seconds_i)``."""

    kind: str                 # stream | latency | issue | compute | wave
    target: str               # stream/latency: level name; compute/wave:
                              # dtype; "" for machine-wide (issue)
    params: Dict[str, float]  # fixed sweep parameters
    samples: Tuple[Tuple[float, float], ...]

    def xs(self) -> List[float]:
        return [x for x, _ in self.samples]

    def ys(self) -> List[float]:
        return [y for _, y in self.samples]

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "target": self.target,
                "params": dict(self.params),
                "samples": [list(s) for s in self.samples]}


def level_windows(base: Topology) -> List[Tuple[int, str, int]]:
    """(level index, name, reuse-window bytes) targeting each probeable
    level of the chain, innermost first, backing memory last.

    A window targets level ℓ when it exceeds the budget of every level
    *inner* than ℓ (so nearer levels cannot serve the re-touches) while
    fitting ℓ's own budget.  A cache whose budget does not leave room above
    its inner neighbours (a budget inversion) is reported unprobeable by
    omission — the fit keeps its preset bandwidth."""
    out: List[Tuple[int, str, int]] = []
    levels = base.levels
    for i in range(len(levels) - 1, 0, -1):           # innermost first
        inner = max((l.budget() for l in levels[i + 1:]), default=0)
        budget = levels[i].budget()
        window = min(budget, 2 * inner) if inner else max(budget // 2, 1)
        if window <= inner:
            continue                                   # budget inversion
        out.append((i, levels[i].name, window))
    inner = max((l.budget() for l in levels[1:]), default=1)
    out.append((0, levels[0].name, 2 * inner))         # backing: spills all
    return out


def probe_stream_levels(device: Device, base: Topology, *,
                        n_chunks: int = 64,
                        targets: Sequence[float] = STREAM_TARGETS_S,
                        ) -> Dict[str, ProbeSweep]:
    """Per-level bandwidth sweeps: fixed window, nbytes varied.  nbytes per
    point is sized from the level's *preset* bandwidth to hit the target
    wall times (a KB-scale window needs hundreds of thousands of passes
    before its port time is visible over launch overhead)."""
    out: Dict[str, ProbeSweep] = {}
    for idx, name, window in level_windows(base):
        bw = base.levels[idx].bandwidth
        samples = tuple(
            (nb, device.stream_time(nb, window, n_chunks))
            for nb in (float(max(2 * window, int(T * bw)))
                       for T in targets))
        out[f"stream:{name}"] = ProbeSweep(
            kind="stream", target=name,
            params={"window": window, "n_chunks": n_chunks},
            samples=samples)
    return out


def probe_latency(device: Device, base: Topology,
                  targets: Sequence[float] = LATENCY_TARGETS_S) -> ProbeSweep:
    """Single-pass small transfers: ``window == nbytes``, one chunk — the
    intercept over nbytes is launch + first-byte latency + issue cost.
    Transfers are kept small (sub-launch-scale) so the intercept
    extrapolation stays short."""
    bw = base.backing.bandwidth
    samples = tuple(
        (nb, device.stream_time(nb, int(nb), 1))
        for nb in (float(max(int(T * bw), 1)) for T in targets))
    return ProbeSweep(kind="latency", target=base.backing.name,
                      params={"n_chunks": 1}, samples=samples)


def probe_issue(device: Device, base: Topology,
                targets: Sequence[float] = ISSUE_TARGETS_S) -> ProbeSweep:
    """DMA-issue cost: chunk-count sweep at fixed (small) bytes and window
    so the constant byte term stays small next to the issue term.  Chunk
    counts are sized from the preset ``dma_fixed``."""
    window = max(base.staging.budget() // 2, 1)
    nbytes = float(2 * window)
    dma = base.dma_fixed or 1e-9
    chunks = sorted({max(1, int(T / dma)) for T in targets})
    samples = tuple(
        (float(c), device.stream_time(nbytes, window, c)) for c in chunks)
    return ProbeSweep(kind="issue", target="",
                      params={"window": window, "nbytes": nbytes},
                      samples=samples)


def probe_compute(device: Device, base: Topology, dtype: str,
                  targets: Sequence[float] = COMPUTE_TARGETS_S) -> ProbeSweep:
    """Issue-rate sweep for one dtype: n resident macro-atoms back-to-back,
    n sized from the preset peak to hit the target wall times."""
    mm, mn, mk = base.mxu_shape
    atom_flops = 2.0 * mm * mn * mk
    peak = base.flops(dtype)
    lanes = base.total_cores()      # chip-wide rate needs every core busy
    samples = tuple(
        (float(n), device.compute_time(dtype, n, lanes))
        for n in (max(16 * lanes, int(T * peak / atom_flops))
                  for T in targets))
    return ProbeSweep(kind="compute", target=dtype,
                      params={"mxu_m": mm, "mxu_n": mn, "mxu_k": mk,
                              "n_parallel": lanes},
                      samples=samples)


def _wave_unit_atoms(base: Topology) -> int:
    """Atoms per wave unit sized so one wave ~ WAVE_UNIT_TARGET_S."""
    mm, mn, mk = base.mxu_shape
    atom_flops = 2.0 * mm * mn * mk
    ref = reference_dtype(base.peak_flops)
    return max(16, int(WAVE_UNIT_TARGET_S * base.peak_flops[ref]
                       / (atom_flops * base.total_cores())))


def probe_wave(device: Device, base: Topology, *,
               unit_atoms: Optional[int] = None,
               multiples: Sequence[int] = WAVE_MULTIPLES) -> ProbeSweep:
    """Wave-latency staircase: unit counts in exact multiples of the
    declared core count (x == wave count), plus the C / C+1 cliff pair."""
    if unit_atoms is None:
        unit_atoms = _wave_unit_atoms(base)
    C = base.total_cores()
    ref = reference_dtype(base.peak_flops)
    samples = [(float(k), device.wave_time(k * C, unit_atoms, ref))
               for k in multiples]
    cliff = ((float(C), device.wave_time(C, unit_atoms, ref)),
             (float(C + 1), device.wave_time(C + 1, unit_atoms, ref)))
    return ProbeSweep(kind="wave", target=ref,
                      params={"unit_atoms": unit_atoms, "cores": C,
                              "cliff_units": C,
                              "cliff_before_s": cliff[0][1],
                              "cliff_after_s": cliff[1][1]},
                      samples=tuple(samples))


def run_probes(device: Device, base: Topology, *,
               dtypes: Optional[Sequence[str]] = None,
               ) -> Dict[str, ProbeSweep]:
    """The full probe suite for one device against one base topology."""
    sweeps = probe_stream_levels(device, base)
    sweeps["latency"] = probe_latency(device, base)
    sweeps["issue"] = probe_issue(device, base)
    for dt in (dtypes if dtypes is not None else sorted(base.peak_flops)):
        sweeps[f"compute:{dt}"] = probe_compute(device, base, dt)
    sweeps["wave"] = probe_wave(device, base)
    return sweeps
