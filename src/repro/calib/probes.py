"""Microbenchmark probes: the measurement layer of calibration (DESIGN.md §8).

Each probe runs a sweep of one :class:`~repro.calib.device.Device`
primitive and returns a :class:`ProbeSweep` — the raw ``(x, seconds)``
samples plus the fixed parameters, which the fit layer turns into Topology
constants and which land verbatim in the calibrated-topology artifact's
provenance.  Probes never fit; fits never measure.

The sweeps, and what their slopes/intercepts mean (``fit.py``):

* ``stream:<level>`` — nbytes sweep at a *fixed* reuse window targeting one
  memory level (bigger than every inner level's budget, within the target's)
  with a fixed chunk count, so ``d(time)/d(nbytes) = 1/bandwidth(level)``.
* ``latency`` — single-pass small transfers (``window == nbytes``,
  one chunk): the intercept isolates launch + first-byte latency.
* ``issue`` — chunk-count sweep at fixed bytes/window:
  ``d(time)/d(n_chunks) = dma_fixed``.
* ``compute:<dtype>`` — macro-atom count sweep on resident operands:
  ``d(time)/d(n_atoms) = atom_flops / peak_flops[dtype]``.
* ``wave`` — work-unit sweep in exact multiples of the declared core count:
  ``d(time)/d(waves)`` is the per-wave unit time under the occupancy
  stage's *static* 1/C bandwidth-share simplification, and the intercept is
  ``kernel_launch``.  Two extra off-staircase samples (C and C+1 units)
  record the tail-wave cliff itself.

Window targeting walks the declared capacity chain — capacities and core
counts are structural datasheet facts; calibration measures *rates*
(paper §V-E: retarget by swapping measured constants only).

Fail-soft measurement (DESIGN.md §9): every probe call can be bounded by a
watchdog ``deadline_s`` — a hung device (wedged driver, injected hang)
raises :class:`ProbeTimeout` inside the watchdog instead of wedging the
calibration run, and the sample is *dropped*, not recorded.  Samples that
come back non-finite or non-positive (NaN poison, sign flips — physically
impossible times) are dropped the same way; per-sweep drop counts land in
``params["n_dropped"]`` so provenance shows how degraded a sweep was.
Outliers are NOT dropped here: plausible-but-wrong values are the robust
fit's job (Theil–Sen), not the measurement layer's.
"""
from __future__ import annotations

import math
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FuturesTimeout
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.calib.device import Device
from repro.core.topology import Topology, reference_dtype


class ProbeTimeout(RuntimeError):
    """A probe call exceeded its watchdog deadline (hung device/driver)."""


def _measure(fn: Callable[[], float],
             deadline_s: Optional[float]) -> float:
    """Run one timing call under the watchdog.  ``deadline_s=None`` means
    unbounded (the trusted-substrate fast path: no thread hop)."""
    if deadline_s is None:
        return fn()
    ex = ThreadPoolExecutor(max_workers=1)
    try:
        fut = ex.submit(fn)
        try:
            return fut.result(timeout=deadline_s)
        except _FuturesTimeout:
            fut.cancel()
            raise ProbeTimeout(
                f"probe call exceeded watchdog deadline {deadline_s:g}s"
            ) from None
    finally:
        # Don't block on a wedged worker — it is left to die with the
        # process (the injected-hang case sleeps bounded time anyway).
        ex.shutdown(wait=False)


def _guarded(fn: Callable[[], float],
             deadline_s: Optional[float]) -> Optional[float]:
    """One guarded sample: None (dropped) on watchdog timeout or a
    non-finite / non-positive measurement; the honest value otherwise."""
    try:
        y = _measure(fn, deadline_s)
    except ProbeTimeout:
        return None
    if not math.isfinite(y) or y <= 0.0:
        return None
    return y

# Target wall times per sweep point.  Sweep sizes (bytes, atoms, chunk
# counts) are derived from these and the *base* preset's order-of-magnitude
# constants, so every probe's signal dwarfs launch overhead and measurement
# noise on machines of any speed — a fixed atom count that keeps a TPU busy
# for 20 us vanishes inside the launch jitter of a chip with 16^3 atoms.
# Sizing only needs the preset to be right to an order of magnitude; the
# fit replaces the constants with what was measured.
STREAM_TARGETS_S = (50e-6, 100e-6, 200e-6, 400e-6, 800e-6)
LATENCY_TARGETS_S = (0.5e-6, 1e-6, 1.5e-6, 2e-6, 3e-6, 4e-6)
ISSUE_TARGETS_S = (6.25e-6, 12.5e-6, 25e-6, 50e-6)
COMPUTE_TARGETS_S = (20e-6, 40e-6, 80e-6, 160e-6)
WAVE_UNIT_TARGET_S = 5e-6
WAVE_MULTIPLES = (1, 2, 3, 4, 5, 6, 7, 8)  # x total_cores -> exact waves


@dataclass(frozen=True)
class ProbeSweep:
    """One probe's raw measurements: ``samples[i] = (x_i, seconds_i)``."""

    kind: str                 # stream | latency | issue | compute | wave
    target: str               # stream/latency: level name; compute/wave:
                              # dtype; "" for machine-wide (issue)
    params: Dict[str, float]  # fixed sweep parameters
    samples: Tuple[Tuple[float, float], ...]

    def xs(self) -> List[float]:
        return [x for x, _ in self.samples]

    def ys(self) -> List[float]:
        return [y for _, y in self.samples]

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "target": self.target,
                "params": dict(self.params),
                "samples": [list(s) for s in self.samples]}


def level_windows(base: Topology) -> List[Tuple[int, str, int]]:
    """(level index, name, reuse-window bytes) targeting each probeable
    level of the chain, innermost first, backing memory last.

    A window targets level ℓ when it exceeds the budget of every level
    *inner* than ℓ (so nearer levels cannot serve the re-touches) while
    fitting ℓ's own budget.  A cache whose budget does not leave room above
    its inner neighbours (a budget inversion) is reported unprobeable by
    omission — the fit keeps its preset bandwidth."""
    out: List[Tuple[int, str, int]] = []
    levels = base.levels
    for i in range(len(levels) - 1, 0, -1):           # innermost first
        inner = max((l.budget() for l in levels[i + 1:]), default=0)
        budget = levels[i].budget()
        window = min(budget, 2 * inner) if inner else max(budget // 2, 1)
        if window <= inner:
            continue                                   # budget inversion
        out.append((i, levels[i].name, window))
    inner = max((l.budget() for l in levels[1:]), default=1)
    out.append((0, levels[0].name, 2 * inner))         # backing: spills all
    return out


def probe_stream_levels(device: Device, base: Topology, *,
                        n_chunks: int = 64,
                        targets: Sequence[float] = STREAM_TARGETS_S,
                        deadline_s: Optional[float] = None,
                        ) -> Dict[str, ProbeSweep]:
    """Per-level bandwidth sweeps: fixed window, nbytes varied.  nbytes per
    point is sized from the level's *preset* bandwidth to hit the target
    wall times (a KB-scale window needs hundreds of thousands of passes
    before its port time is visible over launch overhead)."""
    out: Dict[str, ProbeSweep] = {}
    for idx, name, window in level_windows(base):
        bw = base.levels[idx].bandwidth
        samples: List[Tuple[float, float]] = []
        dropped = 0
        for T in targets:
            nb = float(max(2 * window, int(T * bw)))
            y = _guarded(lambda: device.stream_time(nb, window, n_chunks),
                         deadline_s)
            if y is None:
                dropped += 1
            else:
                samples.append((nb, y))
        out[f"stream:{name}"] = ProbeSweep(
            kind="stream", target=name,
            params={"window": window, "n_chunks": n_chunks,
                    "n_dropped": dropped},
            samples=tuple(samples))
    return out


def probe_latency(device: Device, base: Topology,
                  targets: Sequence[float] = LATENCY_TARGETS_S,
                  deadline_s: Optional[float] = None) -> ProbeSweep:
    """Single-pass small transfers: ``window == nbytes``, one chunk — the
    intercept over nbytes is launch + first-byte latency + issue cost.
    Transfers are kept small (sub-launch-scale) so the intercept
    extrapolation stays short."""
    bw = base.backing.bandwidth
    samples: List[Tuple[float, float]] = []
    dropped = 0
    for T in targets:
        nb = float(max(int(T * bw), 1))
        y = _guarded(lambda: device.stream_time(nb, int(nb), 1), deadline_s)
        if y is None:
            dropped += 1
        else:
            samples.append((nb, y))
    return ProbeSweep(kind="latency", target=base.backing.name,
                      params={"n_chunks": 1, "n_dropped": dropped},
                      samples=tuple(samples))


def probe_issue(device: Device, base: Topology,
                targets: Sequence[float] = ISSUE_TARGETS_S,
                deadline_s: Optional[float] = None) -> ProbeSweep:
    """DMA-issue cost: chunk-count sweep at fixed (small) bytes and window
    so the constant byte term stays small next to the issue term.  Chunk
    counts are sized from the preset ``dma_fixed``."""
    window = max(base.staging.budget() // 2, 1)
    nbytes = float(2 * window)
    dma = base.dma_fixed or 1e-9
    chunks = sorted({max(1, int(T / dma)) for T in targets})
    samples: List[Tuple[float, float]] = []
    dropped = 0
    for c in chunks:
        y = _guarded(lambda: device.stream_time(nbytes, window, c),
                     deadline_s)
        if y is None:
            dropped += 1
        else:
            samples.append((float(c), y))
    return ProbeSweep(kind="issue", target="",
                      params={"window": window, "nbytes": nbytes,
                              "n_dropped": dropped},
                      samples=tuple(samples))


def probe_compute(device: Device, base: Topology, dtype: str,
                  targets: Sequence[float] = COMPUTE_TARGETS_S,
                  deadline_s: Optional[float] = None) -> ProbeSweep:
    """Issue-rate sweep for one dtype: n resident macro-atoms back-to-back,
    n sized from the preset peak to hit the target wall times."""
    mm, mn, mk = base.mxu_shape
    atom_flops = 2.0 * mm * mn * mk
    peak = base.flops(dtype)
    lanes = base.total_cores()      # chip-wide rate needs every core busy
    samples: List[Tuple[float, float]] = []
    dropped = 0
    for T in targets:
        n = max(16 * lanes, int(T * peak / atom_flops))
        y = _guarded(lambda: device.compute_time(dtype, n, lanes),
                     deadline_s)
        if y is None:
            dropped += 1
        else:
            samples.append((float(n), y))
    return ProbeSweep(kind="compute", target=dtype,
                      params={"mxu_m": mm, "mxu_n": mn, "mxu_k": mk,
                              "n_parallel": lanes, "n_dropped": dropped},
                      samples=tuple(samples))


def _wave_unit_atoms(base: Topology) -> int:
    """Atoms per wave unit sized so one wave ~ WAVE_UNIT_TARGET_S."""
    mm, mn, mk = base.mxu_shape
    atom_flops = 2.0 * mm * mn * mk
    ref = reference_dtype(base.peak_flops)
    return max(16, int(WAVE_UNIT_TARGET_S * base.peak_flops[ref]
                       / (atom_flops * base.total_cores())))


def probe_wave(device: Device, base: Topology, *,
               unit_atoms: Optional[int] = None,
               multiples: Sequence[int] = WAVE_MULTIPLES,
               deadline_s: Optional[float] = None) -> ProbeSweep:
    """Wave-latency staircase: unit counts in exact multiples of the
    declared core count (x == wave count), plus the C / C+1 cliff pair."""
    if unit_atoms is None:
        unit_atoms = _wave_unit_atoms(base)
    C = base.total_cores()
    ref = reference_dtype(base.peak_flops)
    samples: List[Tuple[float, float]] = []
    dropped = 0
    for k in multiples:
        y = _guarded(lambda: device.wave_time(k * C, unit_atoms, ref),
                     deadline_s)
        if y is None:
            dropped += 1
        else:
            samples.append((float(k), y))
    cliff = []
    for units in (C, C + 1):
        y = _guarded(lambda: device.wave_time(units, unit_atoms, ref),
                     deadline_s)
        if y is None:
            dropped += 1
            y = float("nan")          # provenance-only; never fitted
        cliff.append(y)
    return ProbeSweep(kind="wave", target=ref,
                      params={"unit_atoms": unit_atoms, "cores": C,
                              "cliff_units": C,
                              "cliff_before_s": cliff[0],
                              "cliff_after_s": cliff[1],
                              "n_dropped": dropped},
                      samples=tuple(samples))


def run_probes(device: Device, base: Topology, *,
               dtypes: Optional[Sequence[str]] = None,
               deadline_s: Optional[float] = None,
               ) -> Dict[str, ProbeSweep]:
    """The full probe suite for one device against one base topology.

    ``deadline_s`` bounds every individual timing call with the watchdog
    (None -> trust the device not to hang)."""
    sweeps = probe_stream_levels(device, base, deadline_s=deadline_s)
    sweeps["latency"] = probe_latency(device, base, deadline_s=deadline_s)
    sweeps["issue"] = probe_issue(device, base, deadline_s=deadline_s)
    for dt in (dtypes if dtypes is not None else sorted(base.peak_flops)):
        sweeps[f"compute:{dt}"] = probe_compute(device, base, dt,
                                                deadline_s=deadline_s)
    sweeps["wave"] = probe_wave(device, base, deadline_s=deadline_s)
    return sweeps
