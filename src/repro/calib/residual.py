"""Learned residual corrector on the drift stream (DESIGN.md §12).

The analytical model is the interpretable prior; what its probes can't
isolate (compiler scheduling, cache politics, measurement substrate) shows
up as a systematic ratio between predicted and measured seconds.  This
module fits that ratio — a ridge regression on ``log(measured /
predicted)`` over shape/config features — from exactly the rows the drift
monitor already writes (``repro/drift/v1`` JSONL: PR 9's serving
telemetry) and/or device sweeps, and packages it as a fingerprint-stamped
``repro/residual/v1`` artifact with the same provenance / digest /
quarantine semantics as calibrated topologies.

Training-set hygiene is the whole game (the satellite bugfixes in this
PR exist because it is):

* rows are grouped by **topology fingerprint** and only rows matching the
  live topology's fingerprint are kept — a recalibration orphans the old
  rows instead of letting them steer the new model;
* a ``topo`` column holding a preset *name* (the old
  ``record_selection`` default) is refused with a counted warning — names
  survive recalibration unchanged and cannot be validated;
* rows without a config (whole-step sites), with non-positive /
  non-finite times, or on malformed JSONL lines are counted and dropped.

Application is an opt-in post-ranking stage: ``repro.core.selector``
re-prices only the top-F analytically-ranked candidates through
:meth:`ResidualCorrector.correct` (duck-typed — core never imports this
module), with the correction clipped in log space and a switch margin so
an uncertain residual can neither explode a price nor churn selections
the model already got right.  With no corrector installed, selection is
bit-identical to this module not existing.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import re
import time as _time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.selector import select_topk
from repro.core.topology import (SCHEDULES, DegradedModeWarning, Topology,
                                 quarantine_artifact, topology_fingerprint)
from repro.obs.drift import DRIFT_SCHEMA

RESIDUAL_SCHEMA = "repro/residual/v1"

# A topology fingerprint is 16 lowercase hex chars (md5 prefix,
# core/topology.py).  Anything else in a ``topo`` column is name-shaped —
# unverifiable against the live topology, refused by the fitter.
_FP_RE = re.compile(r"^[0-9a-f]{16}$")

FEATURE_NAMES: Tuple[str, ...] = (
    "log2_m", "log2_n", "log2_k", "log2_batch",
    "log2_bm", "log2_bn", "log2_bk", "log2_sk", "log2_gm",
    "log2_tm", "log2_tn", "log2_tk", "log2_steps",
    "log2_waves", "tail_frac", "log2_intensity",
) + tuple(f"sched_{s}" for s in SCHEDULES)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _feature_vector(M: int, N: int, K: int, batch: int,
                    bm: int, bn: int, bk: int, sk: int, gm: int,
                    schedule: str, cores: int) -> np.ndarray:
    """One row of the design matrix.  Everything the drift stream records
    about a GEMM, in log2 where spans are multiplicative: problem dims,
    config dims, and the derived grid/wave terms the model's misses
    correlate with (tail waves, arithmetic intensity)."""
    Tm, Tn = _cdiv(M, bm), _cdiv(N, bn)
    Tk = _cdiv(_cdiv(K, sk), bk) * sk
    steps = Tm * Tn * Tk * batch
    base_tiles = Tm * Tn * batch * sk
    waves = _cdiv(base_tiles, cores)
    tail = (base_tiles - (waves - 1) * cores) / cores
    intensity = 2.0 * M * N * K / (M * K + K * N + M * N)
    lg = math.log2
    vec = [lg(M), lg(N), lg(K), lg(batch),
           lg(bm), lg(bn), lg(bk), lg(sk), lg(gm),
           lg(Tm), lg(Tn), lg(Tk), lg(steps),
           lg(waves), tail, lg(intensity)]
    vec += [1.0 if schedule == s else 0.0 for s in SCHEDULES]
    return np.asarray(vec, np.float64)


@dataclass(frozen=True)
class ResidualRow:
    """One training sample: a (shape, config) whose prediction was checked
    against a measurement."""

    M: int
    N: int
    K: int
    batch: int
    config: Mapping[str, object]     # bm/bn/bk/split_k/group_m/schedule
    predicted_s: float
    measured_s: float

    @property
    def log_ratio(self) -> float:
        return math.log(self.measured_s / self.predicted_s)

    def features(self, cores: int) -> np.ndarray:
        c = self.config
        return _feature_vector(
            self.M, self.N, self.K, self.batch,
            int(c["bm"]), int(c["bn"]), int(c["bk"]),
            int(c.get("split_k", 1)), int(c.get("group_m", 1)),
            str(c.get("schedule", "data_parallel")), cores)


@dataclass(frozen=True)
class ResidualCorrector:
    """The fitted corrector: standardized linear model over
    :data:`FEATURE_NAMES` predicting ``log(measured / predicted)``.

    ``fingerprint`` is the topology content fingerprint the training rows
    were validated against — the selector ignores the corrector (counted
    metric) whenever the live topology's fingerprint differs, exactly as
    the selection cache invalidates on recalibration.  ``clip`` bounds the
    log-space correction; ``top_f`` is how many analytically-ranked
    finalists the selector re-prices; ``switch_margin`` is the relative
    corrected advantage required to overrule the analytical winner."""

    feature_names: Tuple[str, ...]
    mean: Tuple[float, ...]
    scale: Tuple[float, ...]
    weights: Tuple[float, ...]
    intercept: float
    clip: float
    top_f: int
    switch_margin: float
    fingerprint: str                 # topology fingerprint trained against
    hardware: str                    # preset name (display only)
    provenance: Dict = field(default_factory=dict, compare=False)

    # -- application -------------------------------------------------------

    def predict_log_ratio(self, X: np.ndarray) -> np.ndarray:
        z = (X - np.asarray(self.mean)) / np.asarray(self.scale)
        raw = z @ np.asarray(self.weights) + self.intercept
        return np.clip(raw, -self.clip, self.clip)

    def correct(self, p, configs: Sequence, totals, hw) -> np.ndarray:
        """Re-price ``totals`` (model-predicted seconds for ``configs`` of
        problem ``p`` on topology ``hw``) with the learned multiplicative
        residual.  Duck-typed for the selector: ``p`` needs M/N/K/batch,
        configs need bm/bn/bk/split_k/group_m/schedule."""
        cores = hw.total_cores()
        X = np.stack([
            _feature_vector(p.M, p.N, p.K, p.batch, t.bm, t.bn, t.bk,
                            t.split_k, t.group_m, t.schedule, cores)
            for t in configs])
        return np.asarray(totals, np.float64) \
            * np.exp(self.predict_log_ratio(X))

    # -- artifact ----------------------------------------------------------

    def _model_dict(self) -> Dict:
        return {"feature_names": list(self.feature_names),
                "mean": list(self.mean), "scale": list(self.scale),
                "weights": list(self.weights),
                "intercept": self.intercept, "clip": self.clip,
                "top_f": self.top_f, "switch_margin": self.switch_margin}

    def content_fingerprint(self) -> str:
        """Content hash of the model block — the residual memo-namespace
        key in the selector (a refit corrector must re-select)."""
        blob = json.dumps(self._model_dict(), sort_keys=True)
        return hashlib.md5(blob.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict:
        prov = dict(self.provenance)
        prov["fingerprint"] = self.fingerprint
        prov["hardware"] = self.hardware
        prov["model_digest"] = self.content_fingerprint()
        return {"schema": RESIDUAL_SCHEMA, "model": self._model_dict(),
                "provenance": prov}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path


# ---------------------------------------------------------------------------
# Training-set assembly.
# ---------------------------------------------------------------------------

def _row_ok(predicted_s: float, measured_s: float) -> bool:
    return (math.isfinite(predicted_s) and math.isfinite(measured_s)
            and predicted_s > 0.0 and measured_s > 0.0)


def rows_from_drift(path: str, *, fingerprint: str,
                    ) -> Tuple[List[ResidualRow], Dict[str, int]]:
    """Consume a ``drift.jsonl`` stream into training rows for the
    topology with content fingerprint ``fingerprint``.

    Returns ``(rows, stats)`` where stats counts every rejection class:
    ``malformed`` (truncated writer tail), ``no_config`` (whole-step
    sites), ``bad_measurement`` (non-finite / non-positive),
    ``name_shaped_topo`` (a preset name where a fingerprint belongs — the
    pre-fix ``record_selection`` default; refused with a warning),
    ``fingerprint_mismatch`` (rows from a since-recalibrated topology).
    """
    stats = {"total": 0, "kept": 0, "malformed": 0, "no_config": 0,
             "bad_measurement": 0, "name_shaped_topo": 0,
             "fingerprint_mismatch": 0}
    rows: List[ResidualRow] = []
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            stats["total"] += 1
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                stats["malformed"] += 1
                continue
            if rec.get("schema") != DRIFT_SCHEMA:
                stats["malformed"] += 1
                continue
            topo = str(rec.get("topo") or "")
            if topo and not _FP_RE.match(topo):
                stats["name_shaped_topo"] += 1
                continue
            if topo != fingerprint:
                stats["fingerprint_mismatch"] += 1
                continue
            cfg = rec.get("config")
            if not cfg:
                stats["no_config"] += 1
                continue
            try:
                pred = float(rec["predicted_s"])
                meas = float(rec["measured_s"])
                shape = list(rec["shape"])
                row = ResidualRow(
                    M=int(shape[0]), N=int(shape[1]), K=int(shape[2]),
                    batch=int(shape[3]) if len(shape) > 3 else 1,
                    config=dict(cfg), predicted_s=pred, measured_s=meas)
            except (KeyError, TypeError, ValueError, IndexError):
                stats["malformed"] += 1
                continue
            if not _row_ok(pred, meas):
                stats["bad_measurement"] += 1
                continue
            rows.append(row)
            stats["kept"] += 1
    if stats["name_shaped_topo"]:
        warnings.warn(
            f"{path}: refused {stats['name_shaped_topo']} drift row(s) "
            f"whose topo column holds a preset name, not a topology "
            f"fingerprint — they cannot be validated against the live "
            f"topology and would poison the residual training set "
            f"(re-record with a fingerprint-carrying Selection)",
            UserWarning, stacklevel=2)
    return rows, stats


def rows_from_sweep(hw: Topology, device, shapes: Sequence[Sequence[int]],
                    *, k: int = 12) -> List[ResidualRow]:
    """Supplement (or replace) the drift stream by sweeping ``device``
    directly: for each (M, N, K[, batch]) shape, measure the top-``k``
    analytically-ranked candidates.  The default ``k`` deliberately
    over-spans the corrector's ``top_f`` re-pricing slate (8): every
    finalist the corrector will re-price at selection time must be
    in-distribution, with margin — a corrector trained on a narrower
    slate extrapolates onto exactly the configs it is asked to rank."""
    from repro.core.latency import GemmProblem, gemm_latency

    rows: List[ResidualRow] = []
    for s in shapes:
        M, N, K = int(s[0]), int(s[1]), int(s[2])
        batch = int(s[3]) if len(s) > 3 else 1
        p = GemmProblem(M=M, N=N, K=K, batch=batch)
        configs, totals, _ = select_topk(p, hw, k)
        for t, pred in zip(configs, totals.tolist()):
            try:
                meas = float(device.gemm_time(p, t))
            except RuntimeError:
                continue
            if not _row_ok(pred, meas):
                continue
            rows.append(ResidualRow(
                M=M, N=N, K=K, batch=batch,
                config={"bm": t.bm, "bn": t.bn, "bk": t.bk,
                        "split_k": t.split_k, "group_m": t.group_m,
                        "schedule": t.schedule},
                predicted_s=float(pred), measured_s=meas))
    return rows


# ---------------------------------------------------------------------------
# Fitting.
# ---------------------------------------------------------------------------

MIN_FIT_ROWS = 8


def fit_residual(rows: Sequence[ResidualRow], hw: Topology, *,
                 ridge: float = 1e-2, clip: float = 0.5, top_f: int = 8,
                 switch_margin: float = 0.02,
                 sources: Optional[Sequence[str]] = None,
                 stats: Optional[Mapping[str, int]] = None,
                 ) -> ResidualCorrector:
    """Closed-form ridge fit of ``log(measured / predicted)`` on the
    standardized feature matrix.  Numpy-only; deterministic.  Raises
    ``ValueError`` below :data:`MIN_FIT_ROWS` rows — a residual fit on a
    handful of points would memorize noise, not absorb structure."""
    if len(rows) < MIN_FIT_ROWS:
        raise ValueError(
            f"too few rows to fit a residual: {len(rows)} < {MIN_FIT_ROWS}")
    cores = hw.total_cores()
    X = np.stack([r.features(cores) for r in rows])
    y = np.asarray([r.log_ratio for r in rows], np.float64)
    mean = X.mean(axis=0)
    scale = X.std(axis=0)
    scale[scale == 0.0] = 1.0         # constant feature: weight stays 0
    Z = (X - mean) / scale
    n, d = Z.shape
    A = Z.T @ Z + ridge * n * np.eye(d)
    b = Z.T @ (y - y.mean())
    w = np.linalg.solve(A, b)
    intercept = float(y.mean())
    resid = Z @ w + intercept - y
    rmse = float(np.sqrt(np.mean(resid ** 2)))
    prov: Dict = {
        "n_rows": n,
        "train_rmse_log": rmse,
        "train_mean_abs_log_ratio": float(np.mean(np.abs(y))),
        "ridge": ridge,
        "created_unix": _time.time(),
        "sources": list(sources or []),
    }
    if stats:
        prov["row_stats"] = dict(stats)
    return ResidualCorrector(
        feature_names=FEATURE_NAMES, mean=tuple(mean.tolist()),
        scale=tuple(scale.tolist()), weights=tuple(w.tolist()),
        intercept=intercept, clip=float(clip), top_f=int(top_f),
        switch_margin=float(switch_margin),
        fingerprint=topology_fingerprint(hw), hardware=hw.name,
        provenance=prov)


def residual_pick(res: ResidualCorrector, p, hw, *,
                  allow_split_k: bool = True, allow_grouping: bool = True):
    """The corrected argmin over the top-F analytical finalists — the same
    choice rule the selector applies (clip + switch margin), exposed for
    the oracle/fidelity harness to evaluate a corrector WITHOUT installing
    it process-wide.  Returns (config, n_candidates)."""
    configs, totals, n = select_topk(
        p, hw, res.top_f, allow_split_k=allow_split_k,
        allow_grouping=allow_grouping)
    corrected = res.correct(p, configs, totals, hw)
    j = int(np.argmin(corrected))
    if j != 0 and not corrected[j] < corrected[0] * (1.0 - res.switch_margin):
        j = 0
    return configs[j], n


# ---------------------------------------------------------------------------
# Artifact loading — mirrors core/topology.py's calibrated-topology pair:
# a strict parser for tools, a fail-soft guarded loader for serving.
# ---------------------------------------------------------------------------

def load_residual(text: str) -> ResidualCorrector:
    """Parse a ``repro/residual/v1`` artifact.  Validates the schema tag
    and the recorded model digest against the recomputed one — an artifact
    whose weights were edited after the fit is rejected, exactly like a
    calibrated topology whose constants no longer match its fingerprint."""
    doc = json.loads(text)
    schema = doc.get("schema")
    if schema != RESIDUAL_SCHEMA:
        raise ValueError(f"not a residual artifact: schema={schema!r}, "
                         f"expected {RESIDUAL_SCHEMA!r}")
    m = doc["model"]
    prov = dict(doc.get("provenance", {}))
    fp = str(prov.get("fingerprint") or "")
    if not _FP_RE.match(fp):
        raise ValueError(
            f"residual artifact carries no topology fingerprint "
            f"(got {fp!r}) — cannot be validated against a live topology")
    corr = ResidualCorrector(
        feature_names=tuple(m["feature_names"]),
        mean=tuple(float(v) for v in m["mean"]),
        scale=tuple(float(v) for v in m["scale"]),
        weights=tuple(float(v) for v in m["weights"]),
        intercept=float(m["intercept"]), clip=float(m["clip"]),
        top_f=int(m["top_f"]), switch_margin=float(m["switch_margin"]),
        fingerprint=fp, hardware=str(prov.get("hardware", "")),
        provenance=prov)
    if len(corr.mean) != len(corr.feature_names) \
            or len(corr.scale) != len(corr.feature_names) \
            or len(corr.weights) != len(corr.feature_names):
        raise ValueError("residual artifact is corrupt: feature/weight "
                         "vector lengths disagree")
    recorded = prov.get("model_digest")
    actual = corr.content_fingerprint()
    if recorded != actual:
        raise ValueError(
            f"residual artifact for {corr.hardware!r} is corrupt: recorded "
            f"model digest {recorded!r} != recomputed {actual!r} "
            f"(weights were edited after the fit)")
    return corr


def load_residual_guarded(
    path: str,
    *,
    expect: Optional[Topology] = None,
    quarantine: bool = True,
) -> Tuple[Optional[ResidualCorrector], Dict]:
    """Fail-soft residual loading for serving paths (mirrors
    ``load_calibrated_topology_guarded``).  Never raises on a bad
    artifact: a truncated / tampered / wrong-schema file is quarantined to
    a ``.quarantined`` sidecar with a :class:`DegradedModeWarning`, and
    ``(None, info)`` is returned so serving continues on the pure
    analytical model (which is always correct — the corrector is an
    accuracy upgrade, never a dependency).

    ``expect`` additionally rejects an artifact fit for a different
    topology fingerprint — stale, not corrupt, so it is warned about but
    NOT quarantined (it may be the right artifact for another host)."""
    def _degrade(reason: str, *, evidence: bool) -> Tuple[None, Dict]:
        sidecar = None
        if evidence and quarantine and os.path.exists(path):
            try:
                sidecar = quarantine_artifact(path)
            except OSError:
                pass
        warnings.warn(
            f"residual artifact {path!r} rejected ({reason}); serving on "
            f"the pure analytical model"
            + (f"; artifact quarantined to {sidecar!r}" if sidecar else ""),
            DegradedModeWarning, stacklevel=3)
        return None, {"degraded": reason, "quarantined": sidecar}

    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        warnings.warn(
            f"residual artifact {path!r} unreadable ({e}); serving on the "
            f"pure analytical model",
            DegradedModeWarning, stacklevel=2)
        return None, {"degraded": f"unreadable: {e}", "quarantined": None}
    try:
        corr = load_residual(text)
    except (ValueError, KeyError, TypeError) as e:
        return _degrade(str(e) or type(e).__name__, evidence=True)
    if expect is not None:
        live = topology_fingerprint(expect)
        if corr.fingerprint != live:
            return _degrade(
                f"fit for topology fingerprint {corr.fingerprint!r}, live "
                f"topology is {live!r} (stale, not quarantined)",
                evidence=False)
    return corr, dict(corr.provenance)
