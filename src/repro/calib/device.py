"""Device abstraction for the calibration probes (DESIGN.md §8).

A :class:`Device` exposes the four primitives the probe layer times:

* ``stream_time``  — stream ``nbytes`` cyclically through a ``window``-byte
  working set in ``n_chunks`` fetches (per-level bandwidth / latency /
  issue-cost probes);
* ``compute_time`` — ``n_atoms`` back-to-back matrix macro-atoms on
  resident operands (peak issue rate per dtype);
* ``wave_time``    — ``n_units`` identical compute-only units launched as a
  grid (occupancy staircase: core count, launch overhead, and the static
  bandwidth/compute-share term of the occupancy stage);
* ``gemm_time``    — one full GEMM under an explicit ``TileConfig`` (the
  exhaustive-autotune oracle's per-candidate measurement).

Two implementations:

* :class:`VirtualDevice` wraps ``core/simulator.py`` around a *planted*
  topology: fully deterministic (optionally with seeded multiplicative
  noise to exercise the robust-fit path), so the whole probe → fit → oracle
  pipeline is CI-testable — the fit must recover the planted constants.
* :class:`JaxDevice` times real jax executions on whatever backend jax
  sees.  On an actual accelerator these are meaningful microbenchmarks; on
  the CPU container they execute (tiny sizes, used by smoke tests for the
  code path only) but the numbers describe the host, not a TPU/GPU.
"""
from __future__ import annotations

import hashlib
import time
from typing import Optional, Protocol, runtime_checkable

from repro.core.latency import GemmProblem, TileConfig
from repro.core.simulator import (simulate_compute, simulate_gemm,
                                  simulate_gemm_batch, simulate_stream,
                                  simulate_wave)
from repro.core.topology import Topology


@runtime_checkable
class Device(Protocol):
    """What the probe layer needs from a machine under calibration."""

    name: str

    def stream_time(self, nbytes: float, window: int,
                    n_chunks: int) -> float: ...

    def compute_time(self, dtype: str, n_atoms: int,
                     n_parallel: int = 1) -> float: ...

    def wave_time(self, n_units: int, unit_atoms: int,
                  dtype: str) -> float: ...

    def gemm_time(self, p: GemmProblem, t: TileConfig) -> float: ...


class VirtualDevice:
    """The simulator wrapped as a deterministic device.

    ``planted`` is the ground-truth topology whose constants the probes
    observe; the fit pipeline starts from a *different* (or identical) base
    preset and must recover them.  ``noise`` adds a deterministic
    multiplicative jitter in ``[-noise, +noise]`` derived from a hash of
    the call arguments (stable across call order and processes), so the
    least-squares fits are exercised against imperfect measurements
    without flaky tests.
    """

    def __init__(self, planted: Topology, *, noise: float = 0.0,
                 seed: int = 0):
        self.planted = planted
        self.noise = float(noise)
        self.seed = int(seed)
        self.name = f"virtual:{planted.name}"

    def _jitter(self, *key) -> float:
        if not self.noise:
            return 1.0
        h = hashlib.md5(repr((self.seed,) + key).encode()).digest()
        u = int.from_bytes(h[:8], "big") / float(1 << 64)    # [0, 1)
        return 1.0 + self.noise * (2.0 * u - 1.0)

    def stream_time(self, nbytes: float, window: int,
                    n_chunks: int) -> float:
        t = simulate_stream(self.planted, nbytes, window, n_chunks)
        return t * self._jitter("stream", nbytes, window, n_chunks)

    def compute_time(self, dtype: str, n_atoms: int,
                     n_parallel: int = 1) -> float:
        # simulate_compute retires atoms at the full chip rate, so the
        # parallelism hint is already implied (jitter key excludes it).
        t = simulate_compute(self.planted, dtype, n_atoms)
        return t * self._jitter("compute", dtype, n_atoms)

    def wave_time(self, n_units: int, unit_atoms: int,
                  dtype: str) -> float:
        t = simulate_wave(self.planted, n_units, unit_atoms, dtype)
        return t * self._jitter("wave", n_units, unit_atoms, dtype)

    def gemm_time(self, p: GemmProblem, t: TileConfig) -> float:
        # The oracle's per-candidate price: the event-level simulator, which
        # shares no scoring logic with the closed-form model it judges.
        return simulate_gemm(p, t, self.planted).time

    def gemm_time_batch(self, p: GemmProblem, candidates) -> list:
        """Whole-menu pricing through the vectorized simulator — bit-identical
        to ``[self.gemm_time(p, t) for t in candidates]`` (the batched pricer
        shares the scalar placement pass and reduces in the same order), at
        the cost of one numpy pass instead of P python event loops.  The
        unpruned oracle's fast path; optional on the Device protocol —
        callers feature-detect with ``hasattr``."""
        return [r.time for r in simulate_gemm_batch(p, candidates,
                                                    self.planted)]


class JaxDevice:
    """Real-execution device: times jitted jax computations.

    Sizes are the caller's problem — the probe layer scales them from the
    base topology's declared capacities.  All timings are best-of-``repeat``
    wall clock around ``block_until_ready`` after one warm-up call (compile
    time excluded).
    """

    def __init__(self, repeat: int = 3, backend: Optional[str] = None):
        import jax
        self._jax = jax
        self.repeat = int(repeat)
        dev = jax.devices(backend)[0] if backend else jax.devices()[0]
        self._device = dev
        self.name = f"jax:{dev.platform}:{getattr(dev, 'device_kind', '?')}"

    def _time(self, fn, *args) -> float:
        out = fn(*args)
        self._jax.block_until_ready(out)               # warm-up / compile
        best = float("inf")
        for _ in range(self.repeat):
            t0 = time.perf_counter()
            self._jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    def stream_time(self, nbytes: float, window: int,
                    n_chunks: int) -> float:
        import jax
        import jax.numpy as jnp
        elems = max(int(window) // 4, 1)               # f32 working set
        chunks = max(int(n_chunks), 1)
        # Elements per fetch so chunks fetches move ~nbytes total, cycling
        # through the window.  Each iteration dynamic-slices at a start
        # that depends on the loop counter and folds the read into the
        # carried accumulator — neither hoistable nor dead-code-eliminable,
        # so the sweep's nbytes AND n_chunks axes are both honored (the
        # issue probe's slope is d(time)/d(n_chunks)).
        chunk = min(max(int(nbytes / 4) // chunks, 1), elems)
        span = max(elems - chunk + 1, 1)
        x = jnp.arange(elems, dtype=jnp.float32)

        @jax.jit
        def read(x):
            def body(i, acc):
                s = jax.lax.dynamic_slice(x, ((i * chunk) % span,),
                                          (chunk,))
                return acc + s.sum()
            return jax.lax.fori_loop(0, chunks, body, jnp.float32(0))

        return self._time(read, x)

    @staticmethod
    def _dot_dtypes(dtype: str):
        """(operand dtype, accumulator dtype) for a timing chain in the
        *requested* dtype — the probe measures that dtype's issue rate, so
        operands must stay in it every iteration (the wide accumulate is
        cast back; a d x d cast is noise next to the d^3 MACs)."""
        import jax.numpy as jnp
        jd = jnp.dtype(dtype)
        wide = jnp.float32 if jnp.issubdtype(jd, jnp.floating) else jnp.int32
        return jd, wide

    def compute_time(self, dtype: str, n_atoms: int,
                     n_parallel: int = 1) -> float:
        import jax
        import jax.numpy as jnp
        d = 128                                        # resident macro-atom
        jd, wide = self._dot_dtypes(dtype)
        # The fit reads the slope as the CHIP-wide issue rate (the virtual
        # device's convention), so the atoms must be spread over enough
        # independent chains to occupy every core — one serial dependent
        # chain would measure a single core's rate, ~C x too slow on
        # multi-core chips.  ``n_parallel`` comes from the probe layer
        # (the base preset's declared core count).
        lanes = max(int(n_parallel), 1)
        per_lane = max(n_atoms // lanes, 1)
        a = jnp.ones((lanes, d, d), dtype=jd)

        @jax.jit
        def chains(a):
            def lane(x):
                def body(_, acc):
                    return jnp.dot(acc, x,
                                   preferred_element_type=wide).astype(jd)
                return jax.lax.fori_loop(0, per_lane, body, x)
            return jax.vmap(lane)(a).sum()

        return self._time(chains, a)

    def wave_time(self, n_units: int, unit_atoms: int,
                  dtype: str) -> float:
        import jax
        import jax.numpy as jnp
        d = 128
        jd, wide = self._dot_dtypes(dtype)
        a = jnp.ones((n_units, d, d), dtype=jd)

        @jax.jit
        def grid(a):
            def unit(x):
                def body(_, acc):
                    return jnp.dot(acc, x,
                                   preferred_element_type=wide).astype(jd)
                return jax.lax.fori_loop(0, unit_atoms, body, x)
            return jax.vmap(unit)(a).sum()

        return self._time(grid, a)

    def gemm_time(self, p: GemmProblem, t: TileConfig) -> float:
        import jax.numpy as jnp
        from repro.kernels import ops
        a = jnp.ones((p.M, p.K), dtype=jnp.dtype(p.in_dtype))
        b = jnp.ones((p.K, p.N), dtype=jnp.dtype(p.in_dtype))
        return self._time(
            lambda a, b: ops.matmul(a, b, out_dtype=p.out_dtype, config=t),
            a, b)


def get_device(kind: str, base: Topology, *, noise: float = 0.0,
               seed: int = 0, planted: Optional[Topology] = None,
               fault_plan=None) -> Device:
    """Device factory for the CLI / benchmarks: ``virtual`` wraps the
    simulator around ``planted`` (default: the base preset itself — the
    self-consistency check), ``jax`` measures real executions.

    ``fault_plan`` (a ``repro.calib.faults.FaultPlan``) decorates the
    device with seeded, deterministic measurement faults — the chaos
    harness's entry point into the probe pipeline."""
    if kind == "virtual":
        device: Device = VirtualDevice(planted or base, noise=noise,
                                       seed=seed)
    elif kind == "jax":
        device = JaxDevice()
    else:
        raise ValueError(
            f"unknown device kind {kind!r}; choose virtual | jax")
    if fault_plan is not None:
        from repro.calib.faults import FaultyDevice
        device = FaultyDevice(device, fault_plan)
    return device
