"""Fit Topology constants from probe measurements (DESIGN.md §8).

The probe layer (``probes.py``) returns raw ``(x, seconds)`` sweeps; this
module turns their slopes and intercepts into calibrated
:class:`~repro.core.topology.Topology` fields:

* per-level ``bandwidth``   <- 1 / slope of the ``stream:<level>`` sweeps;
* ``peak_flops[dtype]``     <- atom FLOPs / slope of ``compute:<dtype>``;
* ``kernel_launch``         <- intercept of the ``wave`` staircase;
* ``dma_fixed``             <- slope of the ``issue`` sweep;
* backing ``latency``       <- intercept of the single-pass ``latency``
                               sweep minus launch and one issue cost;
* ``static_share``          <- wave slope x fitted peak / (unit work x C):
  how much of the chip's rate one core actually got, ~1.0 when the
  occupancy stage's static 1/C share assumption holds (recorded in
  provenance, not a Topology field — it validates the model's shape).

Fits are Theil-Sen (median of pairwise slopes): robust to the occasional
outlier a wall-clock measurement produces, identical to least squares on
clean data.  Every fitted value passes the same positivity/finiteness
validation ``repro.core.hardware.calibrate`` applies to hand-supplied
microbenchmarks, and the result serializes to the calibrated-topology JSON
artifact (``core/topology.py::calibrated_topology_json``) whose provenance
carries the raw sweeps, per-fit relative residuals, and the topology
fingerprint the selection cache keys invalidation on.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.calib.device import Device
from repro.calib.probes import ProbeSweep, run_probes
from repro.core.topology import (Topology, calibrated_topology_json,
                                 reference_dtype, topology_fingerprint)


def theil_sen(xs: Sequence[float], ys: Sequence[float]
              ) -> Tuple[float, float]:
    """Robust (slope, intercept): median pairwise slope, median residual
    intercept.  Exact on collinear data; breaks down only past ~29%
    outliers."""
    if len(xs) < 2:
        raise ValueError("need >= 2 samples to fit a line")
    slopes = sorted((ys[j] - ys[i]) / (xs[j] - xs[i])
                    for i in range(len(xs)) for j in range(i + 1, len(xs))
                    if xs[j] != xs[i])
    if not slopes:
        # Every surviving x coincides (e.g. watchdog/NaN dropping reduced a
        # sweep to one repeated point): there is no slope to take a median
        # of.  A clean ValueError lets fit_topology(allow_degraded=True)
        # keep the preset constant and record the reason, instead of the
        # bare IndexError _median([]) used to raise.
        raise ValueError(
            f"degenerate sweep: all {len(xs)} samples share x={xs[0]!r}, "
            f"no pairwise slope exists")
    slope = _median(slopes)
    intercept = _median(sorted(y - slope * x for x, y in zip(xs, ys)))
    return slope, intercept


def _median(sorted_vals: List[float]) -> float:
    n = len(sorted_vals)
    mid = n // 2
    return (sorted_vals[mid] if n % 2
            else 0.5 * (sorted_vals[mid - 1] + sorted_vals[mid]))


def _rel_residual(sweep: ProbeSweep, slope: float, intercept: float) -> float:
    """Root-mean-square relative residual of the fitted line."""
    acc = 0.0
    for x, y in sweep.samples:
        pred = intercept + slope * x
        acc += ((pred - y) / y) ** 2 if y else 0.0
    return (acc / len(sweep.samples)) ** 0.5


@dataclass
class CalibrationResult:
    """A fitted topology plus everything needed to audit / reproduce it."""

    base: Topology
    topology: Topology
    device_name: str
    fitted: Dict[str, float]          # field path -> fitted value
    residuals: Dict[str, float]       # field path -> rel RMS residual
    static_share: float               # wave-probe share coefficient (~1.0)
    probes: Dict[str, ProbeSweep] = field(default_factory=dict)
    created_unix: float = 0.0
    degraded: Dict[str, str] = field(default_factory=dict)
    # field path -> why its fit was skipped and the preset value kept
    # (degraded-mode calibration, DESIGN.md §9); empty on a clean fit

    def provenance(self) -> Dict:
        return {
            "device": self.device_name,
            "base_preset": self.base.name,
            "created_unix": self.created_unix,
            "fitted_fields": dict(self.fitted),
            "residuals": dict(self.residuals),
            "degraded": dict(self.degraded),
            "static_share": self.static_share,
            "base_fingerprint": topology_fingerprint(self.base),
            "probes": {k: v.to_dict() for k, v in self.probes.items()},
        }

    def to_json(self) -> str:
        return calibrated_topology_json(self.topology, self.provenance())

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    def compare_to(self, truth: Topology) -> Dict[str, float]:
        """Relative error of each fitted field against a ground-truth
        topology (the virtual device's planted constants, in tests)."""
        out: Dict[str, float] = {}
        truth_levels = {l.name: l for l in truth.levels}
        for key, val in self.fitted.items():
            if key.startswith("levels.") and key.endswith(".bandwidth"):
                ref = truth_levels[key.split(".")[1]].bandwidth
            elif key.startswith("peak_flops."):
                ref = truth.peak_flops[key.split(".", 1)[1]]
            elif key == "hbm_latency":
                ref = truth.backing.latency
            else:
                ref = getattr(truth, key)
            out[key] = abs(val - ref) / ref if ref else abs(val)
        return out


_FIT_ERRORS = (ValueError, KeyError, IndexError, ZeroDivisionError)


def fit_topology(base: Topology, device: Device, *,
                 dtypes: Optional[Sequence[str]] = None,
                 probes: Optional[Mapping[str, ProbeSweep]] = None,
                 deadline_s: Optional[float] = None,
                 allow_degraded: bool = False,
                 ) -> CalibrationResult:
    """Run (or reuse) the probe suite against ``device`` and fit a
    calibrated topology from ``base``'s structure.

    Structure (level chain, capacities, core counts, menus, MXU shape) is
    taken from the datasheet preset; only *rates and overheads* are fitted
    — exactly the paper's §V-E retargeting contract.  Levels whose sweep is
    missing (budget inversion) keep their preset bandwidth.

    ``deadline_s`` bounds each probe call with the watchdog (probes.py).
    ``allow_degraded=True`` turns per-field fit failures (too few surviving
    samples after watchdog drops, a fit value failing ``validate_measured``)
    into *kept preset values* recorded in ``CalibrationResult.degraded``
    (and artifact provenance) instead of aborting the whole calibration —
    the fail-soft mode for untrusted substrates (DESIGN.md §9).  The
    default remains fail-fast: a tool run should see the error."""
    from repro.core.hardware import validate_measured

    sweeps = dict(probes) if probes is not None \
        else run_probes(device, base, dtypes=dtypes, deadline_s=deadline_s)
    mm, mn, mk = base.mxu_shape
    atom_flops = 2.0 * mm * mn * mk
    fitted: Dict[str, float] = {}
    residuals: Dict[str, float] = {}
    degraded: Dict[str, str] = {}

    def _give_up(name: str, e: Exception) -> None:
        if not allow_degraded:
            raise
        degraded[name] = str(e) or type(e).__name__

    # -- compute issue rate per dtype -> peak_flops ------------------------
    peak = dict(base.peak_flops)
    for key, sw in sweeps.items():
        if sw.kind != "compute":
            continue
        try:
            slope, icpt = theil_sen(sw.xs(), sw.ys())
            value = atom_flops / slope
            validate_measured(f"peak_flops.{sw.target}", value)
        except _FIT_ERRORS as e:
            _give_up(f"peak_flops.{sw.target}", e)
            continue
        peak[sw.target] = value
        fitted[f"peak_flops.{sw.target}"] = value
        residuals[f"peak_flops.{sw.target}"] = _rel_residual(sw, slope, icpt)

    # -- wave staircase -> kernel_launch + static-share coefficient --------
    kernel_launch = base.kernel_launch
    static_share = 1.0          # degraded: assume the model's static share
    try:
        wave = sweeps["wave"]
        w_slope, w_icpt = theil_sen(wave.xs(), wave.ys())
        kernel_launch = max(w_icpt, 0.0)
        validate_measured("kernel_launch", kernel_launch)
        C = base.total_cores()
        unit_atoms = wave.params["unit_atoms"]
        # The dtype the wave probe actually timed (recorded on the sweep;
        # legacy sweeps without it fall back to the same shared rule).
        ref_dtype = wave.target or reference_dtype(peak)
        static_share = (w_slope * peak[ref_dtype]
                        / (unit_atoms * atom_flops * C))
    except _FIT_ERRORS as e:
        kernel_launch = base.kernel_launch
        _give_up("kernel_launch", e)
    else:
        fitted["kernel_launch"] = kernel_launch
        residuals["kernel_launch"] = _rel_residual(wave, w_slope, w_icpt)

    # -- issue sweep -> dma_fixed ------------------------------------------
    dma_fixed = base.dma_fixed
    try:
        issue = sweeps["issue"]
        i_slope, i_icpt = theil_sen(issue.xs(), issue.ys())
        dma_fixed = max(i_slope, 0.0)
        validate_measured("dma_fixed", dma_fixed)
    except _FIT_ERRORS as e:
        dma_fixed = base.dma_fixed
        _give_up("dma_fixed", e)
    else:
        fitted["dma_fixed"] = dma_fixed
        residuals["dma_fixed"] = _rel_residual(issue, i_slope, i_icpt)

    # -- per-level stream sweeps -> bandwidths ------------------------------
    bandwidths: Dict[str, float] = {}
    for key, sw in sweeps.items():
        if sw.kind != "stream":
            continue
        try:
            slope, icpt = theil_sen(sw.xs(), sw.ys())
            value = 1.0 / slope
            validate_measured(f"levels.{sw.target}.bandwidth", value)
        except _FIT_ERRORS as e:
            _give_up(f"levels.{sw.target}.bandwidth", e)
            continue
        bandwidths[sw.target] = value
        fitted[f"levels.{sw.target}.bandwidth"] = value
        residuals[f"levels.{sw.target}.bandwidth"] = \
            _rel_residual(sw, slope, icpt)

    # -- single-pass latency sweep -> backing first-byte latency -----------
    hbm_latency = base.backing.latency
    try:
        lat = sweeps["latency"]
        l_slope, l_icpt = theil_sen(lat.xs(), lat.ys())
        hbm_latency = max(l_icpt - kernel_launch - dma_fixed, 0.0)
        validate_measured("hbm_latency", hbm_latency)
    except _FIT_ERRORS as e:
        hbm_latency = base.backing.latency
        _give_up("hbm_latency", e)
    else:
        fitted["hbm_latency"] = hbm_latency
        residuals["hbm_latency"] = _rel_residual(lat, l_slope, l_icpt)

    levels = tuple(
        replace(l,
                bandwidth=bandwidths.get(l.name, l.bandwidth),
                latency=hbm_latency if l is base.backing else l.latency)
        for l in base.levels)
    topo = base.with_calibration(levels=levels, peak_flops=peak,
                                 kernel_launch=kernel_launch,
                                 dma_fixed=dma_fixed)
    return CalibrationResult(
        base=base, topology=topo, device_name=device.name,
        fitted=fitted, residuals=residuals, static_share=static_share,
        probes=sweeps, created_unix=_time.time(), degraded=degraded)
