"""Calibration & model-fidelity subsystem (DESIGN.md §8).

Closes the loop between the analytical model and the hardware it claims to
predict, in three layers:

* **probes** (``probes.py``) — microbenchmark sweeps against a
  :class:`~repro.calib.device.Device` (real jax execution, or the event
  simulator wrapped as a deterministic :class:`VirtualDevice` for CI);
* **fit** (``fit.py``) — robust fits from probe measurements to
  :class:`~repro.core.topology.Topology` constants, serialized as
  calibrated-topology JSON artifacts with full provenance;
* **oracle** (``oracle.py``) — the exhaustive-autotune harness measuring
  the paper's headline fidelity number: % of the empirical optimum the
  zero-autotune analytical selection achieves, per preset x shape sweep.

Entry points: ``repro.core.hardware.calibrate(base, device=...)``,
``tools/fit_topology.py`` (CLI), ``benchmarks/model_fidelity.py``.
"""
from repro.calib.device import Device, JaxDevice, VirtualDevice, get_device
from repro.calib.faults import (FaultPlan, FaultyDevice,
                                InjectedCompileError,
                                InjectedTransientError, corrupt_cache_entry,
                                decode_injector, launch_injector,
                                scripted_injector,
                                tamper_artifact_fingerprint, truncate_file)
from repro.calib.fit import CalibrationResult, fit_topology, theil_sen
from repro.calib.oracle import (OracleRow, fidelity_report, fidelity_row,
                                fidelity_sweep, oracle_best,
                                scaled_llama3_shapes)
from repro.calib.probes import (ProbeSweep, ProbeTimeout, level_windows,
                                probe_compute, probe_issue, probe_latency,
                                probe_stream_levels, probe_wave, run_probes)
from repro.calib.residual import (RESIDUAL_SCHEMA, ResidualCorrector,
                                  ResidualRow, fit_residual, load_residual,
                                  load_residual_guarded, residual_pick,
                                  rows_from_drift, rows_from_sweep)

__all__ = [
    "Device", "JaxDevice", "VirtualDevice", "get_device",
    "FaultPlan", "FaultyDevice", "InjectedCompileError",
    "InjectedTransientError", "corrupt_cache_entry", "decode_injector",
    "launch_injector", "scripted_injector", "tamper_artifact_fingerprint",
    "truncate_file",
    "CalibrationResult", "fit_topology", "theil_sen",
    "OracleRow", "fidelity_report", "fidelity_row", "fidelity_sweep",
    "oracle_best", "scaled_llama3_shapes",
    "ProbeSweep", "ProbeTimeout", "level_windows", "probe_compute",
    "probe_issue", "probe_latency", "probe_stream_levels", "probe_wave",
    "run_probes",
    "RESIDUAL_SCHEMA", "ResidualCorrector", "ResidualRow", "fit_residual",
    "load_residual", "load_residual_guarded", "residual_pick",
    "rows_from_drift", "rows_from_sweep",
]
