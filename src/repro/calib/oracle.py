"""Exhaustive-autotune oracle: the model-fidelity harness (DESIGN.md §8).

The paper's headline claim is that analytical selection reaches >95% of
exhaustive-autotune performance with zero tuning time.  This module
measures that number: for every shape of a sweep it prices the FULL
candidate menu on a :class:`~repro.calib.device.Device` (wall clock on real
hardware, the event simulator through :class:`VirtualDevice` in CI),
records the empirical argmin, and reports the fraction of that optimum the
analytical selection achieves — per preset x shape, with the oracle's rank
under the model as the diagnostic for *why* a miss happened (rank 1 with
fidelity < 1 means a pricing gap between model and device, not a ranking
error).

``fidelity_report`` is the Fig.-style artifact entry point: CSV + markdown
+ JSON under ``experiments/calib/``, registered in ``benchmarks/run.py``
(smoke: scaled-down shapes; the full llama3 sweep is the
``calibration-smoke`` CI job's artifact and the slow nightly's assertion).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.calib.device import Device, VirtualDevice
from repro.core.latency import (GemmProblem, TileConfig, grid_shape,
                                score_candidates, step_compute_latency,
                                wave_model)
from repro.core.selector import candidate_tiles, select_gemm_config
from repro.core.hardware import PRESETS, get_hardware
from repro.core.topology import Topology

OUT_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))), "experiments", "calib")


@dataclass(frozen=True)
class OracleRow:
    """One (preset, shape) cell of the fidelity report."""

    hw: str
    gemm: str
    M: int
    N: int
    K: int
    n_candidates: int
    selected: str            # analytical selection
    oracle: str              # empirical argmin over the same space
    selected_s: float
    oracle_s: float
    fidelity: float          # oracle_s / selected_s  (<= 1.0)
    oracle_model_rank: int   # 1 == model also ranked the oracle first
    # Residual-corrected selection (DESIGN.md §12) — populated only when a
    # corrector was passed to fidelity_row; appended at the END of as_list
    # so existing column indices stay valid.
    corrected: str = ""
    corrected_s: float = 0.0
    corrected_fidelity: float = 0.0

    def as_list(self) -> List:
        out = [self.hw, self.gemm, self.M, self.N, self.K,
               self.n_candidates, self.selected, self.oracle,
               f"{self.selected_s:.6e}", f"{self.oracle_s:.6e}",
               f"{self.fidelity:.4f}", self.oracle_model_rank]
        if self.corrected:
            out += [self.corrected, f"{self.corrected_s:.6e}",
                    f"{self.corrected_fidelity:.4f}"]
        return out


def _compute_lower_bound(p: GemmProblem, t: TileConfig,
                         hw: Topology) -> float:
    """Admissible per-candidate lower bound on any execution of this
    config: launch + (grid steps on the fullest core) x the per-step
    compute floor.  Every grid step occupies its core for at least the
    compute time of one staged block (the simulator's per-step
    ``max(ct, fetch)`` respects this by construction; on real hardware it
    is the roofline compute bound), so a candidate whose bound already
    exceeds the incumbent's measured time cannot be the argmin — the
    pruned exhaustive search stays exact while skipping the tiny-tile
    candidates that are both hopeless and slowest to price."""
    mxu_s, vmem_s = step_compute_latency(p, t, hw)
    Tm, Tn, Tk = grid_shape(p, t)
    steps = Tm * Tn * Tk * p.batch
    _, _, occ = wave_model(p, t, hw)
    # fullest core runs steps*occ/C grid steps, each costing at least the
    # per-core compute floor C*max(mxu, vmem) — the C's cancel.
    return (hw.kernel_launch + hw.hbm_latency
            + steps * occ * max(mxu_s, vmem_s))


def oracle_best(p: GemmProblem, hw: Topology, device: Device,
                candidates: Sequence[TileConfig], *,
                prune: bool = True,
                order: Optional[Sequence[int]] = None,
                ) -> Tuple[TileConfig, float, int]:
    """Price candidates on the device; return (argmin config, its seconds,
    number of candidates pruned by the compute lower bound).

    ``prune`` skips candidates whose :func:`_compute_lower_bound` exceeds
    the incumbent best — exact under the simulator's conventions; pass
    ``prune=False`` to force a fully measured sweep (e.g. wall-clock
    devices where even an admissible analytic bound is unwanted).
    ``order`` visits candidates in the given index order (best model rank
    first makes the bound bite immediately).

    Measurements that are non-finite, non-positive (a NaN-poisoned or
    sign-flipped timer would otherwise *win* the argmin), or that raise a
    runtime error are skipped — the oracle reports the best candidate the
    device measured honestly (DESIGN.md §9).

    An unpruned sweep on a device exposing ``gemm_time_batch`` (the
    vectorized simulator behind :class:`VirtualDevice`) prices the whole
    menu in one batched pass — same per-candidate seconds, same
    argmin/tie-break order (first strict improvement in visit order) —
    which is what makes the nightly full-menu sweep affordable.  Fault-
    injecting or wall-clock devices don't expose it and keep the scalar
    loop."""
    if not candidates:
        raise ValueError("oracle_best: empty candidate menu")
    best_t, best_s = None, float("inf")
    pruned = 0
    idxs = order if order is not None else range(len(candidates))
    if not prune and hasattr(device, "gemm_time_batch"):
        try:
            times = device.gemm_time_batch(p, candidates)
        except RuntimeError:
            times = None
        if times is not None:
            for i in idxs:
                s = times[i]
                if not np.isfinite(s) or s <= 0.0:
                    continue
                if s < best_s:
                    best_t, best_s = candidates[i], s
            return best_t, best_s, 0
    for i in idxs:
        t = candidates[i]
        if prune and best_t is not None \
                and _compute_lower_bound(p, t, hw) >= best_s:
            pruned += 1
            continue
        try:
            s = device.gemm_time(p, t)
        except RuntimeError:
            continue
        if not np.isfinite(s) or s <= 0.0:
            continue
        if s < best_s:
            best_t, best_s = t, s
    return best_t, best_s, pruned


def fidelity_row(hw: Topology, name: str, M: int, N: int, K: int,
                 device: Device, prune: bool = True,
                 residual=None) -> OracleRow:
    """One (preset, shape) fidelity cell.  ``residual`` (a
    :class:`~repro.calib.residual.ResidualCorrector`) additionally prices
    the corrector's pick over the same space — the corrected column is
    evaluated WITHOUT installing the corrector process-wide, so the
    analytical columns (and the goldens they pin) are untouched."""
    p = GemmProblem(M=M, N=N, K=K)
    cands = candidate_tiles(p, hw)
    sel = select_gemm_config(M, N, K, hw=hw)
    scores = score_candidates(p, cands, hw)
    order = list(np.argsort(scores, kind="stable"))
    best_t, best_s, _ = oracle_best(p, hw, device, cands,
                                    prune=prune, order=order)
    sel_s = device.gemm_time(p, sel.config)
    if best_t is None:
        # Every candidate measurement was poisoned/raised: degrade to the
        # analytical selection as its own oracle rather than crash.
        best_t, best_s = sel.config, sel_s
    # Where did the model rank the device's true optimum?
    oracle_i = cands.index(best_t)
    rank = 1 + int(np.sum(scores < scores[oracle_i]))
    corrected, corr_s, corr_fid = "", 0.0, 0.0
    if residual is not None:
        from repro.calib.residual import residual_pick
        pick, _ = residual_pick(residual, p, hw)
        corr_s = device.gemm_time(p, pick)
        corrected = str(pick)
        corr_fid = best_s / corr_s if corr_s else 0.0
    return OracleRow(
        hw=hw.name, gemm=name, M=M, N=N, K=K, n_candidates=len(cands),
        selected=str(sel.config), oracle=str(best_t),
        selected_s=sel_s, oracle_s=best_s,
        fidelity=best_s / sel_s if sel_s else 0.0,
        oracle_model_rank=rank,
        corrected=corrected, corrected_s=corr_s,
        corrected_fidelity=corr_fid)


def scaled_llama3_shapes(sizes: Sequence[str] = ("8b",),
                         tokens: Sequence[int] = (1024,),
                         scale: int = 1) -> List[Tuple[str, int, int, int]]:
    """The llama3 key-GEMM sweep, optionally divided by ``scale`` (rounded
    to the 128-lane grain) — the smoke-size knob for CI."""
    from repro.configs.llama3_shapes import llama3_gemms

    def sc(d: int) -> int:
        return max(128, int(round(d / scale / 128)) * 128)

    out = []
    for size in sizes:
        for (name, M, N, K) in llama3_gemms(size, tuple(tokens)):
            out.append((name if scale == 1 else f"{name}/s{scale}",
                        sc(M), sc(N), sc(K)))
    return out


def fidelity_sweep(hw: Topology, device: Device,
                   shapes: Sequence[Tuple[str, int, int, int]],
                   verbose: bool = False,
                   prune: bool = True,
                   residual=None) -> List[OracleRow]:
    rows = []
    for (name, M, N, K) in shapes:
        row = fidelity_row(hw, name, M, N, K, device, prune=prune,
                           residual=residual)
        rows.append(row)
        if verbose:
            print(f"  [{hw.name}] {name}: fidelity {row.fidelity:.4f} "
                  f"(oracle rank {row.oracle_model_rank}/"
                  f"{row.n_candidates})")
    return rows


def fidelity_report(presets: Sequence[str] = tuple(PRESETS),
                    sizes: Sequence[str] = ("8b",),
                    tokens: Sequence[int] = (1024,),
                    scale: int = 1,
                    devices: Optional[Dict[str, Device]] = None,
                    out_dir: str = OUT_DIR,
                    verbose: bool = True,
                    prune: bool = False,
                    residuals: Optional[Dict] = None) -> Dict:
    """The paper-style fidelity table: % of exhaustive-oracle performance
    achieved by analytical selection, per preset over the llama3 sweep.

    ``devices`` maps preset name -> measuring device; omitted presets get
    the simulator-backed virtual device (the CI path).  The default is the
    FULL unpruned sweep — every candidate priced, through the batched
    simulator pass where the device supports it; ``prune=True`` restores
    the lower-bound-pruned search (handy on slow wall-clock devices, where
    the admissible bound skips hopeless candidates).  Artifacts:
    ``fidelity_report.{json,csv,md}`` in ``out_dir``.

    ``residuals`` maps preset name -> fitted
    :class:`~repro.calib.residual.ResidualCorrector`; presets present in
    the map get the residual-corrected columns (and summary stats)
    alongside the analytical ones."""
    devices = devices or {}
    residuals = residuals or {}
    shapes = scaled_llama3_shapes(sizes, tokens, scale)
    report: Dict = {"scale": scale, "sizes": list(sizes),
                    "tokens": list(tokens), "prune": prune,
                    "presets": {}, "rows": []}
    t0 = time.perf_counter()
    for preset in presets:
        hw = get_hardware(preset)
        device = devices.get(preset) or VirtualDevice(hw)
        res = residuals.get(preset)
        rows = fidelity_sweep(hw, device, shapes, verbose=verbose,
                              prune=prune, residual=res)
        fids = [r.fidelity for r in rows]
        report["presets"][preset] = {
            "device": device.name,
            "n": len(rows),
            "mean_fidelity": sum(fids) / len(fids),
            "worst_fidelity": min(fids),
            "at_95pct": sum(f >= 0.95 for f in fids),
            "oracle_rank1": sum(r.oracle_model_rank == 1 for r in rows),
        }
        if res is not None:
            cfids = [r.corrected_fidelity for r in rows]
            report["presets"][preset].update({
                "mean_corrected_fidelity": sum(cfids) / len(cfids),
                "worst_corrected_fidelity": min(cfids),
            })
        report["rows"] += [r.as_list() for r in rows]
        if verbose:
            s = report["presets"][preset]
            print(f"[oracle:{preset}] mean {100*s['mean_fidelity']:.2f}% "
                  f"worst {100*s['worst_fidelity']:.2f}% of oracle, "
                  f"{s['at_95pct']}/{s['n']} shapes >= 95%, "
                  f"model ranked the oracle first on "
                  f"{s['oracle_rank1']}/{s['n']}")
    report["elapsed_s"] = round(time.perf_counter() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    header = ["hw", "gemm", "M", "N", "K", "n_candidates", "selected",
              "oracle", "selected_s", "oracle_s", "fidelity",
              "oracle_model_rank"]
    if residuals:
        header += ["corrected", "corrected_s", "corrected_fidelity"]
    with open(os.path.join(out_dir, "fidelity_report.json"), "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    import csv
    with open(os.path.join(out_dir, "fidelity_report.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(report["rows"])
    md = ["| preset | device | shapes | mean | worst | >=95% | "
          "oracle rank 1 |",
          "|---|---|---|---|---|---|---|"]
    for preset, s in report["presets"].items():
        md.append(f"| {preset} | {s['device']} | {s['n']} "
                  f"| {100*s['mean_fidelity']:.2f}% "
                  f"| {100*s['worst_fidelity']:.2f}% "
                  f"| {s['at_95pct']}/{s['n']} "
                  f"| {s['oracle_rank1']}/{s['n']} |")
    with open(os.path.join(out_dir, "fidelity_report.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    return report
