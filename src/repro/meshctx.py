"""Trace-time mesh context for activation sharding constraints.

GSPMD propagates input/param shardings well, but the remat layer stash is
shaped by the scan-body *boundary* layout.  ``constrain`` lets model code
pin activations (e.g. sequence-sharded residual stream — Megatron-style SP)
when a mesh is installed; it is a no-op otherwise, so models stay runnable
on bare CPU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def constrain(x: jax.Array, *parts) -> jax.Array:
    """with_sharding_constraint with auto-drop: each entry of ``parts`` is a
    mesh-axis name / tuple / None; axes missing from the mesh or not
    dividing the dim are dropped (same policy as sharding.spec_for)."""
    if _MESH is None:
        return x
    used: set = set()
    out = []
    for dim, part in zip(x.shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        sel = [a for a in axes if a in _MESH.shape and a not in used]
        tot = int(np.prod([_MESH.shape[a] for a in sel])) if sel else 1
        if sel and dim % tot == 0:
            out.append(tuple(sel) if len(sel) > 1 else sel[0])
            used.update(sel)
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*out)))
