"""The Llama-3 key-GEMM shape table (paper Fig. 6 sweep).

The projection GEMMs of Llama-3 8B and 70B (qkv, attn-out, gate/up, down,
vocab head) at common token counts — the real inference/training shapes
the paper highlights.  Lives in the library (not ``benchmarks/``) because
the calibration oracle (``repro.calib.oracle``) sweeps these shapes too;
``benchmarks/llama3_shapes.py`` re-exports for its Fig. 6 harness.
"""
from __future__ import annotations

from typing import List, Tuple

# (d_model, kv_dim, d_ff, vocab)
LLAMA3 = {
    "8b": (4096, 1024, 14336, 128256),
    "70b": (8192, 1024, 28672, 128256),
}
TOKENS = (1024, 4096, 8192)


def llama3_gemms(size: str, tokens=TOKENS) -> List[Tuple[str, int, int, int]]:
    d, kv, ff, v = LLAMA3[size]
    out = []
    for t in tokens:
        out += [
            (f"{size}/qkv/t{t}", t, d + 2 * kv, d),
            (f"{size}/attn_out/t{t}", t, d, d),
            (f"{size}/gate_up/t{t}", t, 2 * ff, d),
            (f"{size}/down/t{t}", t, d, ff),
            (f"{size}/lm_head/t{t}", t, v, d),
        ]
    return out
