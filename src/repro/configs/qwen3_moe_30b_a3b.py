"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) per-expert
d_ff=768 vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

head_dim=128 (Qwen3 uses wide heads: H*hd = 4096 != d_model).  128 experts
shard cleanly over the 16-way "model" axis (8 experts/chip — true EP).
"""
from repro.nn.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    rope_theta=1000000.0,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=32,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
    remat=False,
)
