"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 => MHA) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf].  The EnCodec/conditioning frontend is a stub:
``input_specs`` provides precomputed frame embeddings (B, S, D) that are
added to the token embeddings.  MusicGen's backbone is a standard pre-LN
transformer (layernorm + gelu).
"""
from repro.nn.config import ModelConfig

FULL = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    norm="layernorm",
    activation="gelu",
    frontend="audio",
    frontend_tokens=0,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    norm="layernorm",
    activation="gelu",
    frontend="audio",
    frontend_tokens=0,
    remat=False,
)
