"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone = Mistral-7B.  The anyres vision tower is a STUB: ``input_specs``
provides precomputed patch embeddings (base 576 + 4 tiles x 576 = 2880
positions) occupying the start of the sequence.
"""
from repro.nn.config import ModelConfig

FULL = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1000000.0,
    frontend="vision",
    frontend_tokens=2880,
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    frontend="vision",
    frontend_tokens=8,
    remat=False,
)
