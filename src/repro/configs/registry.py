"""Architecture registry: ``--arch <id>`` resolution for all ten assigned
architectures (+ smoke variants)."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.nn.config import SHAPES, ModelConfig, ShapeSpec, shape_applicable

from repro.configs import (  # noqa: F401 (import side: module registry)
    internlm2_20b,
    llava_next_mistral_7b,
    mamba2_370m,
    minitron_8b,
    mixtral_8x22b,
    musicgen_large,
    phi4_mini,
    qwen3_moe_30b_a3b,
    stablelm_12b,
    zamba2_7b,
)

_MODULES = {
    "musicgen-large": musicgen_large,
    "phi4-mini-3.8b": phi4_mini,
    "minitron-8b": minitron_8b,
    "stablelm-12b": stablelm_12b,
    "internlm2-20b": internlm2_20b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "mamba2-370m": mamba2_370m,
    "mixtral-8x22b": mixtral_8x22b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "zamba2-7b": zamba2_7b,
}

ARCH_IDS: List[str] = sorted(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    try:
        mod = _MODULES[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return mod.SMOKE if smoke else mod.FULL


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def all_cells(include_skipped: bool = False
              ) -> List[Tuple[str, str, bool, str]]:
    """Every (arch, shape) cell with its applicability.

    Returns tuples (arch, shape_name, runs, skip_reason)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, sspec in SHAPES.items():
            ok, why = shape_applicable(cfg, sspec)
            if ok or include_skipped:
                cells.append((arch, sname, ok, why))
    return cells
