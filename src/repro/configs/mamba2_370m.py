"""mamba2-370m [ssm] — 48L d_model=1024 (attn-free) vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free: the paper's GEMM selector applies to the SSD chunk GEMMs
(DESIGN.md §5); sub-quadratic, so the long_500k cell runs for this arch.
"""
from repro.nn.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,
    ssm_conv_width=4,
    ssm_chunk=16,
    tie_embeddings=True,
    remat=False,
)
