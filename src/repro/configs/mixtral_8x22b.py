"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088; hf].

~141B total / ~39B active params.  8 experts do not divide the 16-way
"model" axis, so EP shards each expert's d_ff tensor-parallel instead
(sharding rule table, DESIGN.md §7); FSDP over "data" is mandatory to fit
HBM (282 GB of bf16 weights).
"""
from repro.nn.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=16384,
    sliding_window=4096,
    rope_theta=1000000.0,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_experts=4,
    experts_per_token=2,
    moe_d_ff=64,
    sliding_window=32,
    remat=False,
)
