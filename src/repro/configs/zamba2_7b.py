"""zamba2-7b [hybrid] — 81L d_model=3584, shared attn 32H (GQA kv=32)
d_ff=14336, vocab=32000, ssm_state=64 — Mamba2 backbone + ONE shared
attention+MLP block applied every 6 layers (weights reused at every
application — the Zamba trick) [arXiv:2411.15242; unverified].

81 = 13 groups of 6 + a 3-layer tail (handled by the hybrid scan).
Sub-quadratic backbone => the long_500k cell runs for this arch; the shared
attention's KV cache is sharded over the "model" axis at long contexts.
"""
from repro.nn.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    shared_attn_every=6,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke",
    family="hybrid",
    num_layers=5,                 # 2 groups of 2 + tail of 1
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,
    ssm_conv_width=4,
    ssm_chunk=16,
    shared_attn_every=2,
    remat=False,
)
