"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-12b; hf].  head_dim = 5120/32 = 160
(non-128-aligned minor dim; the selector's alignment filter handles it)."""
from repro.nn.config import ModelConfig

FULL = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke",
    family="dense",
    num_layers=2,
    d_model=80,
    num_heads=4,
    num_kv_heads=2,
    head_dim=20,
    d_ff=192,
    vocab_size=512,
    remat=False,
)
