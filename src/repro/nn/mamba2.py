"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

The chunked SSD algorithm is GEMM-rich (intra-chunk quadratic blocks +
inter-chunk state GEMMs), which is exactly where the paper's selector
applies for the attention-free archs (DESIGN.md §5).  Contractions lower to
dot_general on the MXU; the chunk length is the tiling knob and defaults to
the MXU-aligned 256.

Shapes: x (B, S, D); internal heads (B, S, nh, hd); state (B, nh, hd, ns).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn import scanning
from repro.nn.config import ModelConfig
from repro.nn.layers import ParamDef, dense, norm, norm_defs, rmsnorm

NEG_INF = float("-inf")


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., l) -> (..., l, l) with out[i, j] = sum_{j < t <= i} a[t],
    -inf above the diagonal (the 1-semiseparable decay matrix)."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, ss, NEG_INF)


def ssd_chunked(
    x: jax.Array,        # (B, S, nh, hd)  — pre-scaled by dt
    dA: jax.Array,       # (B, S, nh)      — log-decay per step (dt * A <= 0)
    Bm: jax.Array,       # (B, S, ns)
    Cm: jax.Array,       # (B, S, ns)
    chunk: int,
    initial_state=None,  # (B, nh, hd, ns)
) -> Tuple[jax.Array, jax.Array]:
    B, S, nh, hd = x.shape
    ns = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    c, l = S // chunk, chunk

    xc = x.reshape(B, c, l, nh, hd)
    Ac = dA.reshape(B, c, l, nh).transpose(0, 3, 1, 2)        # (B, nh, c, l)
    Bc = Bm.reshape(B, c, l, ns)
    Cc = Cm.reshape(B, c, l, ns)

    A_cs = jnp.cumsum(Ac, axis=-1)                            # (B, nh, c, l)
    L = jnp.exp(_segsum(Ac))                                  # (B, nh, c, l, l)

    # 1) intra-chunk (diagonal blocks): quadratic attention-like GEMMs.
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc,
                        preferred_element_type=jnp.float32)

    # 2) chunk-local final states.
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)             # (B, nh, c, l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc,
                        preferred_element_type=jnp.float32)

    # 3) inter-chunk recurrence (sequential scan over chunks).
    chunk_decay = jnp.exp(A_cs[..., -1])                      # (B, nh, c)
    init = (jnp.zeros((B, nh, hd, ns), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))

    def step(carry, inp):
        s_c, d_c = inp                 # (B, nh, hd, ns), (B, nh)
        new = s_c + d_c[..., None, None] * carry
        return new, carry              # emit the state *entering* the chunk

    final, prev = scanning.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 2, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                           # (B, c, nh, hd, ns)

    # 4) prior-state contribution to each position.
    state_decay = jnp.exp(A_cs)                               # (B, nh, c, l)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev, state_decay,
                       preferred_element_type=jnp.float32)

    y = (Y_diag + Y_off).reshape(B, S, nh, hd)
    return y.astype(x.dtype), final


# ---------------------------------------------------------------------------
# Mamba2 block.
# ---------------------------------------------------------------------------

def mamba_defs(cfg: ModelConfig) -> Dict:
    """Projections are kept as separate weights (not the reference impl's
    fused in_proj) so each output dim shards cleanly: d_inner over the
    "model" axis without slice-across-shard reshards (DESIGN.md §7)."""
    D, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv_width
    return {
        "norm": norm_defs(cfg),
        "in_z": ParamDef((D, di), ("embed", "ssm_inner")),
        "in_x": ParamDef((D, di), ("embed", "ssm_inner")),
        "in_b": ParamDef((D, ns), ("embed", "state")),
        "in_c": ParamDef((D, ns), ("embed", "state")),
        "in_dt": ParamDef((D, nh), ("embed", "ssm_heads")),
        "conv_x": ParamDef((w, di), (None, "ssm_inner"), scale=0.1),
        "conv_xb": ParamDef((di,), ("ssm_inner",), init="zeros"),
        "conv_b": ParamDef((w, ns), (None, "state"), scale=0.1),
        "conv_bb": ParamDef((ns,), ("state",), init="zeros"),
        "conv_c": ParamDef((w, ns), (None, "state"), scale=0.1),
        "conv_cb": ParamDef((ns,), ("state",), init="zeros"),
        "A_log": ParamDef((nh,), ("ssm_heads",), init="ssm_a",
                          dtype=jnp.float32),
        "D": ParamDef((nh,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": ParamDef((nh,), ("ssm_heads",), init="ssm_dt",
                            dtype=jnp.float32),
        "gate_norm": ParamDef((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamDef((di, D), ("ssm_inner", "embed")),
    }


def _project(p: Dict, h: jax.Array, cfg: ModelConfig):
    """h -> (z, x, B, C, dt) via the five separate projections."""
    z = dense(h, p["in_z"])
    xs = dense(h, p["in_x"])
    Bm = dense(h, p["in_b"])
    Cm = dense(h, p["in_c"])
    dt = dense(h, p["in_dt"])
    return z, xs, Bm, Cm, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width w.shape[0]: (B, S, ch) -> (B, S, ch)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    S = x.shape[1]
    windows = jnp.stack([pad[:, k:k + S] for k in range(width)])  # (w,B,S,ch)
    out = jnp.einsum("wbsc,wc->bsc", windows, w.astype(windows.dtype)) + b
    return jax.nn.silu(out)


def mamba_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                  return_cache: bool = False):
    """Block forward. With ``return_cache`` also emits the decode state
    (conv window tail + final SSM state) computed in the same pass."""
    B, S, D = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = norm(x, p["norm"], cfg)
    z, xs, Bm, Cm, dt = _project(p, h, cfg)

    w = cfg.ssm_conv_width
    conv_tail = {
        "conv_x": xs[:, -(w - 1):].astype(jnp.bfloat16),
        "conv_b": Bm[:, -(w - 1):].astype(jnp.bfloat16),
        "conv_c": Cm[:, -(w - 1):].astype(jnp.bfloat16),
    }
    xs = _causal_conv(xs, p["conv_x"], p["conv_xb"])
    Bm = _causal_conv(Bm, p["conv_b"], p["conv_bb"])
    Cm = _causal_conv(Cm, p["conv_c"], p["conv_cb"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, S, nh)
    A = -jnp.exp(p["A_log"])                                      # (nh,)

    # Pad sequence to a chunk multiple (pads contribute x=0, discarded).
    chunk = min(cfg.ssm_chunk, max(16, S))
    pad = (-S) % chunk
    xh = xs.reshape(B, S, nh, hd)
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    else:
        dtp, Bp, Cp = dt, Bm, Cm

    y, final_state = ssd_chunked(
        (xh.astype(jnp.float32) * dtp[..., None]).astype(xh.dtype),
        dtp * A, Bp, Cp, chunk)
    y = y[:, :S]
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh[:, :S]
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"])
    out = dense(y, p["out_proj"])
    if return_cache:
        return out, {**conv_tail, "ssm": final_state}
    return out


# ---------------------------------------------------------------------------
# O(1) recurrent decode step.
# ---------------------------------------------------------------------------

def mamba_cache_defs(cfg: ModelConfig, batch: int) -> Dict:
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    w = cfg.ssm_conv_width
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, w - 1, di), jnp.bfloat16),
        "conv_b": jax.ShapeDtypeStruct((batch, w - 1, ns), jnp.bfloat16),
        "conv_c": jax.ShapeDtypeStruct((batch, w - 1, ns), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct((batch, nh, hd, ns), jnp.float32),
    }


def _conv_step(x_t: jax.Array, state: jax.Array, w: jax.Array, b: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """One-token depthwise conv: state (B, w-1, ch), x_t (B, ch)."""
    window = jnp.concatenate([state.astype(x_t.dtype), x_t[:, None]], axis=1)
    out = jnp.einsum("bwc,wc->bc", window, w.astype(window.dtype)) + b
    return jax.nn.silu(out), window[:, 1:].astype(state.dtype)


def mamba_decode(p: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict]:
    B, _, D = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = norm(x, p["norm"], cfg)
    z, xs, Bm, Cm, dt = _project(p, h, cfg)
    z, xs, Bm, Cm, dt = (t[:, 0] for t in (z, xs, Bm, Cm, dt))

    xs, new_cx = _conv_step(xs, cache["conv_x"], p["conv_x"], p["conv_xb"])
    Bm, new_cb = _conv_step(Bm, cache["conv_b"], p["conv_b"], p["conv_bb"])
    Cm, new_cc = _conv_step(Cm, cache["conv_c"], p["conv_c"], p["conv_cb"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                       # (B, nh)
    xh = xs.reshape(B, nh, hd).astype(jnp.float32) * dt[..., None]
    upd = jnp.einsum("bhp,bn->bhpn", xh, Bm.astype(jnp.float32))
    state = cache["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs.reshape(B, nh, hd).astype(jnp.float32)
    y = y.reshape(B, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"])
    return dense(y, p["out_proj"])[:, None], \
        {"conv_x": new_cx, "conv_b": new_cb, "conv_c": new_cc, "ssm": state}
