"""Model substrate: configs, layers, families, facade."""
from repro.nn.config import SHAPES, ModelConfig, ShapeSpec, shape_applicable
from repro.nn.model import Model

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "shape_applicable", "Model"]
