"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch strategy (MaxText-style, memory-feasible at 128 experts): token
copies are sorted by expert id, placed into a fixed-capacity (E, C, D) buffer
by scatter-add, batched expert GEMMs run on the buffer, and results are
gathered back with gate weighting.  Everything is O(T·k·D + E·C·(D+F)) — no
(T, E, C) one-hot dispatch tensor.

Expert GEMMs are exactly the grouped-GEMM case the paper calls out (its
complexity argument §II-A covers "batched or grouped GEMM dimensions"): the
analytical selector prices the (E·C, D, F) contraction shapes with zero
autotuning.  Expert weights carry the "experts" logical axis so EP sharding
is a rule-table entry (qwen3: 128 experts over the 16-way "model" axis;
mixtral: 8 experts keep d_ff tensor-parallel instead — 8 does not divide 16).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.nn.config import ModelConfig
from repro.nn.layers import ParamDef, norm, norm_defs


def moe_defs(cfg: ModelConfig) -> Dict:
    """Expert weights use dedicated logical axes: the contraction dim D is
    NEVER data-sharded (FSDP'ing it makes every expert einsum a partial
    sum -> f32 (E,C,F) all-reduces over "data", measured at TB/step scale
    on mixtral — EXPERIMENTS.md §Perf iteration 9); the FSDP shard lives on
    the expert-F dim instead (("model","data") when both divide)."""
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    return {
        "norm": norm_defs(cfg),
        "router": ParamDef((D, E), ("embed_novar", "experts_in")),
        "wg": ParamDef((E, D, F), ("experts", "expert_embed", "expert_mlp")),
        "wu": ParamDef((E, D, F), ("experts", "expert_embed", "expert_mlp")),
        "wd": ParamDef((E, F, D), ("experts", "expert_mlp", "expert_embed")),
    }


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)     # round up to 8 for TPU-friendly shapes


def moe_forward(p: Dict, x: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Tokens over capacity are dropped
    (standard Switch/GShard semantics; capacity_factor controls the rate).

    ``cfg.moe_local_dispatch`` (needs an installed mesh): tokens are
    regrouped per data shard and sorted/packed *within* their shard, so the
    scatter into the (E, C, D) dispatch buffer never crosses devices — the
    buffer carries a leading data-sharded group dim and GSPMD emits the
    canonical (B,S,D)-scale combine collective instead of all-reducing the
    full multi-GB dispatch buffer across "data" (EXPERIMENTS.md §Perf,
    mixtral iteration)."""
    from repro import meshctx
    mesh = meshctx.get_mesh()
    if cfg.moe_local_dispatch and mesh is not None:
        dp = 1
        for a in ("pod", "data"):
            dp *= mesh.shape.get(a, 1)
        if (x.shape[0] * x.shape[1]) % dp == 0 and dp > 1:
            return _moe_forward_grouped(p, x, cfg, dp)
    return _moe_forward_flat(p, x, cfg)


def _moe_forward_grouped(p: Dict, x: jax.Array, cfg: ModelConfig, dp: int
                         ) -> Tuple[jax.Array, jax.Array]:
    from repro import meshctx
    B, S, D = x.shape
    h = norm(x, p["norm"], cfg)
    flat = h.reshape(B * S, D)
    g = flat.reshape(dp, (B * S) // dp, D)
    g = meshctx.constrain(g, ("pod", "data"), None, None)
    y, aux = jax.vmap(lambda t: _dispatch_compute(p, t, cfg))(g)
    aux = jnp.mean(aux)
    y = y.reshape(B, S, D).astype(x.dtype)
    return y, aux


def _moe_forward_flat(p: Dict, x: jax.Array, cfg: ModelConfig
                      ) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    h = norm(x, p["norm"], cfg)
    y, aux = _dispatch_compute(p, h.reshape(B * S, D), cfg)
    return y.reshape(B, S, D).astype(x.dtype), aux


def _dispatch_compute(p: Dict, flat: jax.Array, cfg: ModelConfig
                      ) -> Tuple[jax.Array, jax.Array]:
    """Sort-based capacity dispatch + expert GEMMs for (T, D) tokens."""
    T, D = flat.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(cfg, T)

    logits = (flat.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_ids = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch Transformer eq. 4).
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_ids, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ------------------------------------------
    eids = gate_ids.reshape(T * K)                             # (TK,)
    tids = jnp.repeat(jnp.arange(T), K)                        # token of copy
    gvals = gate_vals.reshape(T * K)
    order = jnp.argsort(eids)                                  # stable
    eids_s, tids_s, gvals_s = eids[order], tids[order], gvals[order]
    # position of each copy within its expert's run
    starts = jnp.searchsorted(eids_s, jnp.arange(E))           # (E,)
    pos_in_e = jnp.arange(T * K) - starts[eids_s]
    keep = pos_in_e < C
    slot = jnp.where(keep, eids_s * C + pos_in_e, E * C)       # overflow slot

    buf = jnp.zeros((E * C + 1, D), flat.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], flat[tids_s], 0))
    xe = buf[:-1].reshape(E, C, D)

    # ---- expert GEMMs (grouped; "experts" axis shardable) -------------
    # Selector-driven fused grouped GEMM: the silu-gate runs in the wg
    # GEMM's epilogue, so the (E, C, F) activation makes one HBM round trip.
    u = kops.expert_matmul(xe, p["wu"])
    act = kops.expert_matmul(xe, p["wg"], epilogue="swiglu_gate", gate=u)
    ye = kops.expert_matmul(act, p["wd"])

    # ---- combine -------------------------------------------------------
    y_copies = ye.reshape(E * C, D)
    safe_slot = jnp.where(keep, slot, 0)
    gathered = y_copies[safe_slot] * jnp.where(
        keep, gvals_s, 0.0)[:, None].astype(y_copies.dtype)
    y = jnp.zeros((T, D), flat.dtype).at[tids_s].add(
        gathered.astype(flat.dtype))
    return y, aux


def moe_decode(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Decode-step MoE (B tokens, B small).

    Baseline: gather the K selected experts' weights per token (B·K·D·F
    reads — and, with experts sharded over "model", a multi-GB weight
    all-gather per layer per step).

    ``cfg.moe_dense_decode``: compute EVERY expert on every token instead —
    experts never move (each chip runs its local E/16 experts on the tiny
    (B, D) batch), gates mask the sum, one (B, D) all-reduce combines.
    ~E/K× more MoE flops but decode flops are negligible; kills the
    dominant collective term (EXPERIMENTS.md §Perf)."""
    B, S, D = x.shape      # S == 1
    E, K = cfg.num_experts, cfg.experts_per_token
    h = norm(x, p["norm"], cfg).reshape(B, D)
    logits = h.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gate_vals, gate_ids = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    if cfg.moe_dense_decode:
        gates = jnp.einsum("bke,bk->be",
                           jax.nn.one_hot(gate_ids, E, dtype=jnp.float32),
                           gate_vals)                       # (B, E) dense
        g = jnp.einsum("bd,edf->ebf", h, p["wg"])           # E stays put
        u = jnp.einsum("bd,edf->ebf", h, p["wu"])
        ye = jnp.einsum("ebf,efd->ebd", jax.nn.silu(g) * u, p["wd"])
        y = jnp.einsum("ebd,be->bd", ye, gates.astype(ye.dtype))
        return y.reshape(B, 1, D).astype(x.dtype)

    wg = p["wg"][gate_ids]         # (B, K, D, F) gather
    wu = p["wu"][gate_ids]
    wd = p["wd"][gate_ids]
    g = jnp.einsum("bd,bkdf->bkf", h, wg)
    u = jnp.einsum("bd,bkdf->bkf", h, wu)
    y = jnp.einsum("bkf,bkfd->bkd", jax.nn.silu(g) * u, wd)
    y = jnp.einsum("bkd,bk->bd", y, gate_vals.astype(y.dtype))
    return y.reshape(B, 1, D).astype(x.dtype)
