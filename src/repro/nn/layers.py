"""Building blocks + parameter-definition machinery.

Params are plain nested dicts of arrays.  Every parameter is declared as a
``ParamDef`` carrying its *logical axis names* — the t5x-style indirection the
distributed layer uses to map params onto the mesh (DESIGN.md §7).  The same
def tree yields:

  * ``init_tree``      — materialized params (smoke tests, examples, training)
  * ``abstract_tree``  — ShapeDtypeStructs (multi-pod dry-run, no allocation)
  * ``axes_tree``      — logical axes (sharding rules)

Dense contractions go through ``repro.kernels.ops.matmul`` — the tritonBLAS
selector chooses the kernel tiling at trace time (zero autotuning).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.nn import attention as attn_lib
from repro.nn.config import ModelConfig


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == ndim
    init: str = "normal"              # normal | zeros | ones | ssm_a | ssm_dt
    dtype: Any = jnp.bfloat16
    scale: float = 0.02


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn: Callable[[ParamDef], Any], defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=is_def)


def init_tree(rng: jax.Array, defs) -> Dict:
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    rngs = jax.random.split(rng, len(leaves))

    def make(d: ParamDef, key):
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        if d.init == "ssm_a":     # -exp(U[log 1, log 16]) init for A_log
            u = jax.random.uniform(key, d.shape, jnp.float32)
            return jnp.log(1.0 + u * 15.0).astype(d.dtype)
        if d.init == "ssm_dt":    # dt bias in [1e-3, 1e-1] (softplus-inverse)
            u = jax.random.uniform(key, d.shape, jnp.float32,
                                   minval=-4.6, maxval=-2.3)
            return u.astype(d.dtype)
        return (jax.random.normal(key, d.shape, jnp.float32)
                * d.scale).astype(d.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [make(d, k) for d, k in zip(leaves, rngs)])


def abstract_tree(defs):
    return tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def axes_tree(defs):
    return tree_map_defs(lambda d: d.axes, defs)


# ---------------------------------------------------------------------------
# Primitive layers.
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


def norm(x: jax.Array, p: Dict, cfg: ModelConfig) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def norm_defs(cfg: ModelConfig) -> Dict:
    d = {"scale": ParamDef((cfg.d_model,), ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDef((cfg.d_model,), ("embed",), init="zeros")
    return d


def dense(x: jax.Array, w: jax.Array, out_dtype=None, *,
          epilogue=None, bias=None, gate=None,
          residual=None) -> jax.Array:
    """Selector-driven fused GEMM: epilogue(x (..., K) @ w (K, N)).

    The epilogue (bias / gelu / silu / swiglu-gate / residual) executes
    inside the kernel's flush step — one HBM round trip per layer instead of
    one per post-op (DESIGN.md §3)."""
    return kops.matmul(x, w, out_dtype=out_dtype or x.dtype,
                       epilogue=epilogue, bias=bias, gate=gate,
                       residual=residual)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, d); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
        ang = ang[None, None]                       # (1, 1, S, half)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs
        ang = ang[:, None]                          # (B, 1, S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention block (GQA + RoPE + KV cache).
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "norm": norm_defs(cfg),
        "wq": ParamDef((D, H * hd), ("embed", "heads")),
        "wk": ParamDef((D, Hkv * hd), ("embed", "kv_heads")),
        "wv": ParamDef((D, Hkv * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, D), ("heads", "embed")),
    }


def _repeat_kv_weight(w: jax.Array, hkv: int, hd: int, group: int
                      ) -> jax.Array:
    """(D, Hkv*hd) -> (D, H*hd) by repeating each kv head's columns.

    Repeating the WEIGHT (tiny) instead of the activation kills the
    per-layer K/V all-gather GSPMD inserts when Hkv < "model" axis size
    (Megatron KV duplication; EXPERIMENTS.md §Perf)."""
    D = w.shape[0]
    return jnp.repeat(w.reshape(D, hkv, hd), group, axis=1) \
        .reshape(D, hkv * group * hd)


def attn_forward(
    p: Dict,
    x: jax.Array,                    # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: jax.Array,            # (S,)
    residual: Optional[jax.Array] = None,   # fused into the wo GEMM's flush
) -> jax.Array:
    B, S, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = norm(x, p["norm"], cfg)
    q = dense(h, p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    group = H // Hkv
    if cfg.kv_repeat_weights and group > 1:
        wk = _repeat_kv_weight(p["wk"], Hkv, hd, group)
        wv = _repeat_kv_weight(p["wv"], Hkv, hd, group)
        k = dense(h, wk).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        v = dense(h, wv).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    else:
        k = dense(h, p["wk"]).reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
        v = dense(h, p["wv"]).reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if kops.get_backend() == "pallas" and cfg.sliding_window == 0:
        out = kops.flash_attention(q, k, v, causal=True)
    else:
        out = attn_lib.chunked_attention(
            q, k, v, causal=True, sliding_window=cfg.sliding_window)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return dense(out, p["wo"], residual=residual)


def attn_decode(
    p: Dict,
    x: jax.Array,                    # (B, 1, D)
    cache: Dict,                     # {"k": (B,Hkv,S,d), "v": ...}
    cfg: ModelConfig,
    *,
    pos: jax.Array,                  # scalar int32 — index of this token
) -> Tuple[jax.Array, Dict]:
    B, _, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = norm(x, p["norm"], cfg)
    q = dense(h, p["wq"]).reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
    k = dense(h, p["wk"]).reshape(B, 1, Hkv, hd).transpose(0, 2, 1, 3)
    v = dense(h, p["wv"]).reshape(B, 1, Hkv, hd).transpose(0, 2, 1, 3)
    if jnp.ndim(pos) == 0:
        posv = jnp.reshape(pos, (1,))
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos,
                                                      axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos,
                                                      axis=2)
    else:
        # Per-slot positions (continuous batching): rope per row, and each
        # row's new KV lands at that row's own cache offset.
        posv = jnp.reshape(pos, (B, 1))
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
        upd = jax.vmap(lambda c, blk, i:
                       jax.lax.dynamic_update_slice_in_dim(c, blk, i, axis=1))
        k_cache = upd(cache["k"], k, pos)
        v_cache = upd(cache["v"], v, pos)
    out = attn_lib.decode_attention(
        q, k_cache, v_cache, pos=pos, sliding_window=cfg.sliding_window,
        gqa_packed=cfg.gqa_packed_decode)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
    return dense(out, p["wo"]), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.activation == "swiglu":
        return {
            "norm": norm_defs(cfg),
            "wg": ParamDef((D, F), ("embed", "mlp")),
            "wu": ParamDef((D, F), ("embed", "mlp")),
            "wd": ParamDef((F, D), ("mlp", "embed")),
        }
    return {
        "norm": norm_defs(cfg),
        "w1": ParamDef((D, F), ("embed", "mlp")),
        "w2": ParamDef((F, D), ("mlp", "embed")),
    }


def mlp_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                residual: Optional[jax.Array] = None) -> jax.Array:
    """Fused MLP: activations run in the GEMM epilogues, never as separate
    XLA elementwise passes; the block's residual add (when given) fuses into
    the down-projection's flush."""
    h = norm(x, p["norm"], cfg)
    if cfg.activation == "swiglu":
        u = dense(h, p["wu"])
        a = dense(h, p["wg"], epilogue="swiglu_gate", gate=u)
        return dense(a, p["wd"], residual=residual)
    h1 = dense(h, p["w1"], epilogue="gelu")
    return dense(h1, p["w2"], residual=residual)
