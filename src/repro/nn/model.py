"""Model facade: one object tying config, params, and the three entrypoints
(train loss, prefill, decode) together — the public API used by the
launcher, tests, benchmarks and examples."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import frontends, layers as L, transformer as T
from repro.nn.config import ModelConfig, ShapeSpec


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters -------------------------------------------------------
    def defs(self) -> Dict:
        return T.model_defs(self.cfg)

    def init(self, rng: jax.Array) -> Dict:
        return L.init_tree(rng, self.defs())

    def abstract_params(self) -> Dict:
        return L.abstract_tree(self.defs())

    def param_axes(self) -> Dict:
        return L.axes_tree(self.defs())

    def param_count(self) -> int:
        return self.cfg.param_count()

    # -- entrypoints --------------------------------------------------------
    def loss(self, params: Dict, batch: Dict) -> jax.Array:
        return T.lm_loss(params, batch, self.cfg)

    def forward(self, params: Dict, tokens: jax.Array,
                extras: Optional[Dict] = None) -> jax.Array:
        hidden, _ = T.forward_hidden(params, tokens, self.cfg, extras=extras)
        return jnp.matmul(hidden, T.lm_head_weight(params, self.cfg),
                          preferred_element_type=jnp.float32)

    def prefill(self, params: Dict, tokens: jax.Array,
                extras: Optional[Dict] = None,
                last_pos: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict]:
        return T.prefill_forward(params, tokens, self.cfg, extras=extras,
                                 last_pos=last_pos)

    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Dict]:
        return T.decode_step(params, cache, tokens, pos, self.cfg)

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return T.init_cache(self.cfg, batch, max_len)

    def cache_specs(self, batch: int, max_len: int) -> Dict:
        return T.init_cache_specs(self.cfg, batch, max_len)

    # -- dry-run inputs -----------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        specs.update(frontends.frontend_input_specs(self.cfg, B, S))
        return specs

    def model_flops(self, shape: ShapeSpec) -> float:
        """MODEL_FLOPS for the roofline: 6·N·D per trained token (fwd+bwd),
        2·N·D per inference token; MoE counts active params only."""
        n = self.cfg.active_param_count()
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            return 6.0 * n * tokens
        if shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            return 2.0 * n * tokens
        return 2.0 * n * shape.global_batch       # decode: one token/seq
