"""Attention implementations.

``chunked_attention`` is the jax-native flash equivalent: online softmax over
kv chunks inside a lax.scan — never materializes the (Sq, Skv) score matrix.
It is the dry-run / CPU / GSPMD path; its FLOP and byte profile matches the
Pallas kernel algorithm, which is what the roofline reads.  On TPU runtimes
``repro.kernels.flash_attention`` (selector-tiled Pallas) is used instead.

GQA note (sharding-critical): q stays (B, H, S, d) and KV is broadcast to H
heads with jnp.repeat.  H divides the 16-way "model" axis for every
assigned arch, whereas a (B, Hkv, group, S, d) grouping would leave GSPMD
with two non-dividing head dims (Hkv=8, group=6) and force *full attention
replication* on every chip — a 16x flop/byte blow-up we measured in the
dry-run probes (EXPERIMENTS.md §Perf, iteration 1).

``decode_attention`` scores one query step against a long KV cache; with the
cache's sequence axis sharded over the "model" mesh axis this becomes
flash-decode (partial softmax + cross-chip reduction, inserted by GSPMD).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import scanning

NEG_INF = float("-inf")


def chunked_attention(
    q: jax.Array,                    # (B, H, Sq, d)
    k: jax.Array,                    # (B, Hkv, Skv, d)
    v: jax.Array,                    # (B, Hkv, Skv, d)
    *,
    causal: bool = True,
    sliding_window: int = 0,
    scale: Optional[float] = None,
    chunk_q: int = 512,
    chunk_k: int = 512,
    q_offset: int = 0,               # absolute position of q[0] (for caches)
) -> jax.Array:
    B, H, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    group = H // Hkv
    scale = scale if scale is not None else d ** -0.5
    if group > 1:                    # broadcast KV to H heads (see docstring)
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    cq, ck = min(chunk_q, Sq), min(chunk_k, Skv)
    pq, pk = (-Sq) % cq, (-Skv) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (Sq + pq) // cq, (Skv + pk) // ck

    qc = q.reshape(B, H, nq, cq, d)
    kc = k.reshape(B, H, nk, ck, d)
    vc = v.reshape(B, H, nk, ck, d)

    def q_block(iq, q_blk):
        # q_blk: (B, H, cq, d)
        q32 = q_blk.astype(jnp.float32) * scale
        q_pos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry, inputs):
            m_prev, l_prev, acc = carry
            ik, k_blk, v_blk = inputs
            k_pos = ik * ck + jnp.arange(ck)
            s = jnp.einsum("bhqd,bhkd->bhqk", q32,
                           k_blk.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            mask = (k_pos[None, :] < Skv)
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            if sliding_window > 0:
                mask = mask & (q_pos[:, None] - k_pos[None, :]
                               < sliding_window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - safe[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            alpha = jnp.where(jnp.isfinite(m_prev),
                              jnp.exp(m_prev - safe), 0.0)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bhkd->bhqd", p,
                            v_blk.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        init = (
            jnp.full((B, H, cq), NEG_INF, jnp.float32),
            jnp.zeros((B, H, cq), jnp.float32),
            jnp.zeros((B, H, cq, d), jnp.float32),
        )
        # Checkpoint each kv step: backward recomputes the (cq, ck) score /
        # prob tiles instead of stashing them per step — the flash-attention
        # memory profile (saves O(S^2/ck) residuals per layer).
        (m, l, acc), _ = scanning.scan(
            jax.checkpoint(kv_step), init,
            (jnp.arange(nk), jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0)))
        denom = jnp.where(l > 0, l, 1.0)[..., None]
        return acc / denom

    # Scan over q chunks (keeps peak memory at one (cq, ck) tile per head).
    _, out = scanning.scan(
        lambda _, args: (None, q_block(*args)), None,
        (jnp.arange(nq), jnp.moveaxis(qc, 2, 0)))
    # out: (nq, B, H, cq, d) -> (B, H, Sq, d)
    out = jnp.moveaxis(out, 0, 2).reshape(B, H, nq * cq, d)[:, :, :Sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,                    # (B, H, 1, d) — one new token
    k_cache: jax.Array,              # (B, Hkv, S, d)
    v_cache: jax.Array,              # (B, Hkv, S, d)
    *,
    pos: jax.Array,                  # current length (scalar int32)
    sliding_window: int = 0,
    scale: Optional[float] = None,
    gqa_packed: bool = False,
) -> jax.Array:
    """Flash-decode: one query step against the cache.

    ``gqa_packed=True`` keeps KV un-repeated and scores grouped queries
    against their shared kv head (§Perf iteration: decode is KV-read-bound
    and the repeat multiplies HBM traffic by H/Hkv; packing is legal here
    because the decode cache shards on SEQUENCE, not heads — unlike the
    training path, no dim must divide the "model" axis)."""
    B, H, _, d = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = H // Hkv
    scale = scale if scale is not None else d ** -0.5
    k_pos = jnp.arange(S)
    if jnp.ndim(pos) == 0:
        # Scalar step (step-synchronous batch): mask broadcasts over B.
        mask = k_pos <= pos
        if sliding_window > 0:
            mask = mask & (pos - k_pos < sliding_window)
        mask_packed = mask.reshape(1, 1, 1, S)
        mask_flat = mask.reshape(1, 1, S)
    else:
        # Per-slot positions (continuous batching): each row masks its own
        # prefix, so slots mid-decode coexist with freshly admitted ones.
        mask = k_pos[None, :] <= pos[:, None]                 # (B, S)
        if sliding_window > 0:
            mask = mask & (pos[:, None] - k_pos[None, :] < sliding_window)
        mask_packed = mask[:, None, None, :]
        mask_flat = mask[:, None, :]

    if group > 1 and gqa_packed:
        qg = q[:, :, 0].reshape(B, Hkv, group, d).astype(jnp.float32) * scale
        s = jnp.einsum("bhgd,bhkd->bhgk", qg,
                       k_cache.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        s = jnp.where(mask_packed, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bhgk,bhkd->bhgd", p,
                         v_cache.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        return out.reshape(B, H, 1, d).astype(q.dtype)

    if group > 1:
        k_cache = jnp.repeat(k_cache, group, axis=1)
        v_cache = jnp.repeat(v_cache, group, axis=1)
    qh = q[:, :, 0].astype(jnp.float32) * scale          # (B, H, d)
    s = jnp.einsum("bhd,bhkd->bhk", qh, k_cache.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s = jnp.where(mask_flat, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhk,bhkd->bhd", p, v_cache.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out[:, :, None].astype(q.dtype)
