"""Scan wrapper with a global unroll switch.

XLA's cost_analysis counts a ``while`` body ONCE regardless of trip count,
so scanned-over-layers modules under-report FLOPs/bytes.  The dry-run's
cost probes flip ``set_unroll(True)`` to fully unroll every scan in reduced
(L, S) variants, making cost_analysis exact; production/training keeps
scans rolled (compile time, remat)."""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

_UNROLL = False


def set_unroll(value: bool) -> None:
    global _UNROLL
    _UNROLL = bool(value)


def get_unroll() -> bool:
    return _UNROLL


def scan(body: Callable, init: Any, xs: Any = None,
         length: Optional[int] = None):
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if _UNROLL else 1)
