"""Modality frontend STUBS (per assignment: backbone only).

``[audio]`` (musicgen) and ``[vlm]`` (llava) cells exercise the transformer
backbone; the EnCodec/vision towers are out of scope.  These helpers produce
the precomputed frame/patch embeddings the backbone consumes — as
ShapeDtypeStructs for the dry-run and as synthetic arrays for smoke tests.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.nn.config import ModelConfig


def frontend_input_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    if cfg.frontend == "audio":
        return {"frame_embed": jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), jnp.bfloat16)}
    if cfg.frontend == "vision":
        p = min(cfg.frontend_tokens, seq)
        return {"patch_embed": jax.ShapeDtypeStruct(
            (batch, p, cfg.d_model), jnp.bfloat16)}
    return {}


def synth_frontend_inputs(cfg: ModelConfig, rng: jax.Array, batch: int,
                          seq: int) -> Dict:
    specs = frontend_input_specs(cfg, batch, seq)
    out = {}
    for name, s in specs.items():
        rng, sub = jax.random.split(rng)
        out[name] = (jax.random.normal(sub, s.shape, jnp.float32) * 0.02
                     ).astype(s.dtype)
    return out
