"""Model configuration — one dataclass covers all ten assigned families."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # one of FAMILIES
    num_layers: int
    d_model: int
    vocab_size: int
    # Attention (0 heads => attention-free family).
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    rope_theta: float = 10000.0
    sliding_window: int = 0           # 0 => full causal attention
    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                 # per-expert hidden width
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD).
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # Hybrid (zamba2): one *shared* attention block applied every k layers.
    shared_attn_every: int = 0
    # Modality frontend stub: None | "audio" | "vision".
    frontend: Optional[str] = None
    frontend_tokens: int = 0          # patch/frame positions at seq start
    # Misc.
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    activation: str = "swiglu"        # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Distribution hints (consumed by repro.distributed.sharding).
    fsdp: bool = False                # additionally shard params over "data"
    remat: bool = True
    # Sequence-shard the residual stream at scan-body boundaries (SP):
    # divides the remat stash by the "model" axis size at the cost of
    # gather/scatter collectives around attention (EXPERIMENTS.md §Perf).
    sp_stash: bool = False
    # Grouped-query decode attention (no KV repeat): divides decode KV HBM
    # traffic by H/Hkv (EXPERIMENTS.md §Perf).
    gqa_packed_decode: bool = False
    # Repeat KV projection *weights* to H heads at trace time (Megatron's
    # KV duplication for TP > Hkv): kills the per-layer all-gather of K/V
    # activations that GSPMD inserts when Hkv doesn't divide the "model"
    # axis, for ~8% extra projection flops (EXPERIMENTS.md §Perf).
    kv_repeat_weights: bool = False
    # Decode-time MoE: run every (local) expert on the tiny decode batch
    # instead of gathering selected experts' weights (EXPERIMENTS.md §Perf).
    moe_dense_decode: bool = False
    # Train/prefill MoE: sort/pack tokens within each data shard so the
    # dispatch-buffer scatter never crosses devices (EXPERIMENTS.md §Perf).
    moe_local_dispatch: bool = False

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.family not in ("ssm",):
            assert self.num_heads > 0 and self.head_dim > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameters (analytic; used for 6*N*D MODEL_FLOPS)."""
        D, L, V = self.d_model, self.num_layers, self.vocab_size
        n = V * D                                        # embed
        if not self.tie_embeddings:
            n += D * V                                   # lm head
        n += D                                           # final norm

        def attn_block() -> int:
            h = self.num_heads * self.head_dim
            kv = self.num_kv_heads * self.head_dim
            return D * h + 2 * D * kv + h * D + D        # qkv, o, norm

        def mlp_block(ff: int) -> int:
            mult = 3 if self.activation == "swiglu" else 2
            return mult * D * ff + D                     # (gate,)up,down, norm

        def ssm_block() -> int:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = D * (2 * di + 2 * ns + nh)         # x,z,B,C,dt
            conv = (di + 2 * ns) * self.ssm_conv_width
            out = di * D
            return in_proj + conv + out + 2 * nh + D     # + A,D params, norm

        if self.family == "ssm":
            n += L * ssm_block()
        elif self.family == "hybrid":
            n += L * ssm_block()
            n += attn_block() + mlp_block(self.d_ff)     # ONE shared block
        elif self.is_moe:
            per = attn_block() + D * self.num_experts    # router
            per += self.num_experts * (3 * D * self.moe_d_ff) + D
            n += L * per
        else:
            n += L * (attn_block() + mlp_block(self.d_ff))
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        all_experts = self.num_layers * self.num_experts * 3 * self.d_model \
            * self.moe_d_ff
        active = self.num_layers * self.experts_per_token * 3 * self.d_model \
            * self.moe_d_ff
        return full - all_experts + active


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell: (kind, seq_len, global_batch)."""
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs — DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.has_ssm:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (skip by design)")
    return True, ""
