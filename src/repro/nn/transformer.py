"""Decoder-LM assembly for all ten families: scan-over-layers + remat.

Layer parameters are stacked on a leading "layers" axis so XLA compiles ONE
layer body regardless of depth (compile-time and remat friendly; mandatory
for the 512-device dry-run).  The hybrid (zamba2) family is scanned in
groups of ``shared_attn_every`` mamba layers followed by one application of
the *shared* attention+MLP block (single weight set reused at every
application — the Zamba trick), with a ragged tail handled outside the scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import layers as L
from repro.nn import mamba2, moe
from repro.nn import scanning
from repro.nn.config import ModelConfig
from repro import meshctx as dist_ctx


def _sp(h, cfg):
    """Sequence-sharded residual stream at the scan boundary (SP stash)."""
    if cfg.sp_stash:
        h = dist_ctx.constrain(h, ("pod", "data"), "model", None)
    return h


# ---------------------------------------------------------------------------
# Parameter definitions.
# ---------------------------------------------------------------------------

def layer_defs(cfg: ModelConfig) -> Dict:
    if cfg.family in ("ssm", "hybrid"):
        return {"mamba": mamba2.mamba_defs(cfg)}
    if cfg.is_moe:
        return {"attn": L.attn_defs(cfg), "moe": moe.moe_defs(cfg)}
    return {"attn": L.attn_defs(cfg), "mlp": L.mlp_defs(cfg)}


def _stack(defs, n: int):
    return L.tree_map_defs(
        lambda d: L.ParamDef((n, *d.shape), ("layers", *d.axes),
                             d.init, d.dtype, d.scale), defs)


def model_defs(cfg: ModelConfig) -> Dict:
    D, V = cfg.d_model, cfg.vocab_size
    # NB: the d_model axis of embed/lm_head uses the "embed_novar" logical
    # axis (mapped to None even under FSDP): sharding it over "data" while
    # the batch is also data-sharded makes GSPMD all-reduce full (B,S,V)
    # f32 logits across "data" — a multi-GB collective per loss chunk
    # (found in the dry-run probes; EXPERIMENTS.md §Perf).
    defs: Dict[str, Any] = {
        "embed": L.ParamDef((V, D), ("vocab", "embed_novar"), scale=0.02),
        "layers": _stack(layer_defs(cfg), cfg.num_layers),
        "final_norm": L.norm_defs(cfg),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = L.ParamDef((D, V), ("embed_novar", "vocab"),
                                     scale=0.02)
    if cfg.family == "hybrid":
        defs["shared"] = {"attn": L.attn_defs(cfg), "mlp": L.mlp_defs(cfg)}
    return defs


def _hybrid_split(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, group_size, tail) for the hybrid scan structure."""
    g = cfg.shared_attn_every
    n_groups, tail = divmod(cfg.num_layers, g)
    return n_groups, g, tail


def _tree_take(tree, lo, hi, reshape=None):
    def f(a):
        s = a[lo:hi]
        return s.reshape(reshape + s.shape[1:]) if reshape else s
    return jax.tree_util.tree_map(f, tree)


# ---------------------------------------------------------------------------
# Embedding & frontend stubs.
# ---------------------------------------------------------------------------

def embed_tokens(params: Dict, tokens: jax.Array, cfg: ModelConfig,
                 extras: Optional[Dict] = None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    extras = extras or {}
    if cfg.frontend == "audio" and "frame_embed" in extras:
        # Stub audio conditioning: precomputed frame embeddings added in.
        x = x + extras["frame_embed"].astype(x.dtype)
    if cfg.frontend == "vision" and "patch_embed" in extras:
        # Stub anyres vision tower: patch embeddings occupy the first
        # frontend_tokens positions of the sequence.
        pe = extras["patch_embed"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    return x


def lm_head_weight(params: Dict, cfg: ModelConfig) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# Attention-layer helpers shared by forward/prefill.
# ---------------------------------------------------------------------------

def _kv_for_cache(attn_p, h, positions, cfg):
    B, S, _ = h.shape
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    hn = L.norm(h, attn_p["norm"], cfg)
    k = L.dense(hn, attn_p["wk"]).reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    v = L.dense(hn, attn_p["wv"]).reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    k = L.rope(k, positions, cfg.rope_theta)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Forward (training) — no cache.
# ---------------------------------------------------------------------------

def forward_hidden(
    params: Dict,
    tokens: jax.Array,                   # (B, S)
    cfg: ModelConfig,
    *,
    extras: Optional[Dict] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (final_hidden (B,S,D), moe_aux_loss)."""
    x = embed_tokens(params, tokens, cfg, extras)
    S = tokens.shape[1]
    positions = jnp.arange(S)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        def body(h, lp):
            h = _sp(h, cfg)
            return h + mamba2.mamba_forward(lp["mamba"], h, cfg), None
        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = scanning.scan(body, x, params["layers"])
        return L.norm(x, params["final_norm"], cfg), aux0

    if cfg.family == "hybrid":
        x = _hybrid_stack(params, x, positions, cfg)
        return L.norm(x, params["final_norm"], cfg), aux0

    def body(carry, lp):
        h, aux = carry
        h = _sp(h, cfg)
        # Residual adds fuse into the wo / wd GEMM flushes (f32 accumulator).
        h = L.attn_forward(lp["attn"], h, cfg, positions=positions,
                           residual=h)
        if cfg.is_moe:
            y, a = moe.moe_forward(lp["moe"], h, cfg)
            h, aux = h + y, aux + a
        else:
            h = L.mlp_forward(lp["mlp"], h, cfg, residual=h)
        return (h, aux), None

    body = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = scanning.scan(body, (x, aux0), params["layers"])
    return L.norm(x, params["final_norm"], cfg), aux


def _hybrid_stack(params, x, positions, cfg):
    n_groups, g, tail = _hybrid_split(cfg)
    shared = params["shared"]

    def mamba_body(h, lp):
        return h + mamba2.mamba_forward(lp["mamba"], h, cfg), None

    def group_body(h, gp):
        h = _sp(h, cfg)
        h, _ = scanning.scan(mamba_body, h, gp)
        h = L.attn_forward(shared["attn"], h, cfg, positions=positions,
                           residual=h)
        h = L.mlp_forward(shared["mlp"], h, cfg, residual=h)
        return h, None

    gb = jax.checkpoint(group_body) if cfg.remat else group_body
    head = _tree_take(params["layers"], 0, n_groups * g, (n_groups, g))
    x, _ = scanning.scan(gb, x, head)
    if tail:
        mb = jax.checkpoint(mamba_body) if cfg.remat else mamba_body
        x, _ = scanning.scan(mb, x,
                            _tree_take(params["layers"], n_groups * g,
                                       cfg.num_layers))
    return x


# ---------------------------------------------------------------------------
# Prefill — forward that also emits the decode cache (single pass).
# ---------------------------------------------------------------------------

def prefill_forward(
    params: Dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    extras: Optional[Dict] = None,
    last_pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Returns (last-position logits (B, V), decode cache).

    ``last_pos`` (B,) gathers each row's logits at its own final *real*
    position instead of column -1 — the ragged-admission path: prompts
    right-padded to a bucket edge still read out at their true last token
    (causal attention makes the padded tail invisible to that position)."""
    x = embed_tokens(params, tokens, cfg, extras)
    S = tokens.shape[1]
    positions = jnp.arange(S)

    if cfg.family == "ssm":
        def body(h, lp):
            y, c = mamba2.mamba_forward(lp["mamba"], h, cfg,
                                        return_cache=True)
            return h + y, c
        x, caches = scanning.scan(body, x, params["layers"])
        cache = {"mamba": caches}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_prefill(params, x, positions, cfg)
    else:
        def body(carry, lp):
            h = carry
            kv = _kv_for_cache(lp["attn"], h, positions, cfg)
            h = L.attn_forward(lp["attn"], h, cfg, positions=positions,
                               residual=h)
            if cfg.is_moe:
                y, _ = moe.moe_forward(lp["moe"], h, cfg)
                h = h + y
            else:
                h = L.mlp_forward(lp["mlp"], h, cfg, residual=h)
            return h, kv
        x, cache = scanning.scan(body, x, params["layers"])

    x = L.norm(x, params["final_norm"], cfg)
    last = (x[:, -1] if last_pos is None
            else x[jnp.arange(x.shape[0]), last_pos])
    logits = jnp.matmul(last, lm_head_weight(params, cfg),
                        preferred_element_type=jnp.float32)
    return logits, cache


def _hybrid_prefill(params, x, positions, cfg):
    n_groups, g, tail = _hybrid_split(cfg)
    shared = params["shared"]

    def mamba_body(h, lp):
        y, c = mamba2.mamba_forward(lp["mamba"], h, cfg, return_cache=True)
        return h + y, c

    def group_body(h, gp):
        h, mc = scanning.scan(mamba_body, h, gp)
        kv = _kv_for_cache(shared["attn"], h, positions, cfg)
        h = L.attn_forward(shared["attn"], h, cfg, positions=positions,
                           residual=h)
        h = L.mlp_forward(shared["mlp"], h, cfg, residual=h)
        return h, (mc, kv)

    head = _tree_take(params["layers"], 0, n_groups * g, (n_groups, g))
    x, (head_mc, attn_kv) = scanning.scan(group_body, x, head)
    head_mc = jax.tree_util.tree_map(
        lambda a: a.reshape(n_groups * g, *a.shape[2:]), head_mc)
    if tail:
        x, tail_mc = scanning.scan(
            mamba_body, x,
            _tree_take(params["layers"], n_groups * g, cfg.num_layers))
        mc = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), head_mc, tail_mc)
    else:
        mc = head_mc
    return x, {"mamba": mc, "attn": attn_kv}


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy — never materializes (B, S, V) at once).
# ---------------------------------------------------------------------------

def lm_loss(
    params: Dict,
    batch: Dict,
    cfg: ModelConfig,
    *,
    loss_chunk: int = 1024,
    aux_weight: float = 0.01,
) -> jax.Array:
    tokens = batch["tokens"]
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    hidden, aux = forward_hidden(params, tokens, cfg, extras=extras)
    B, S, D = hidden.shape
    w = lm_head_weight(params, cfg)
    h = hidden[:, :-1]
    t = tokens[:, 1:]
    n = S - 1
    c = min(loss_chunk, n)
    pad = (-n) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        t = jnp.pad(t, ((0, 0), (0, pad)))
    nc = (n + pad) // c
    h = jnp.moveaxis(h.reshape(B, nc, c, D), 1, 0)      # (nc, B, c, D)
    t = jnp.moveaxis(t.reshape(B, nc, c), 1, 0)         # (nc, B, c)
    valid = (jnp.arange(nc * c).reshape(nc, c)[:, None, :]
             < n) & jnp.ones((nc, B, c), bool)

    def chunk_nll(carry, inp):
        hc, tc, vc = inp
        logits = jnp.matmul(hc, w, preferred_element_type=jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = jnp.where(vc, logz - gold, 0.0)
        return carry + jnp.sum(nll), None

    total, _ = scanning.scan(chunk_nll, jnp.zeros((), jnp.float32),
                            (h, t, valid))
    loss = total / (B * n)
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Decode.
# ---------------------------------------------------------------------------

def init_cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """ShapeDtypeStruct tree for the decode cache (dry-run: no allocation)."""
    Lc = cfg.num_layers
    if cfg.family == "ssm":
        per = mamba2.mamba_cache_defs(cfg, batch)
        return {"mamba": jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((Lc, *s.shape), s.dtype), per)}
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim

    def kv(n):
        return {
            "k": jax.ShapeDtypeStruct((n, batch, Hkv, max_len, hd),
                                      jnp.bfloat16),
            "v": jax.ShapeDtypeStruct((n, batch, Hkv, max_len, hd),
                                      jnp.bfloat16),
        }

    if cfg.family == "hybrid":
        n_groups, _, _ = _hybrid_split(cfg)
        per = mamba2.mamba_cache_defs(cfg, batch)
        return {
            "mamba": jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((Lc, *s.shape), s.dtype), per),
            "attn": kv(n_groups),
        }
    return kv(Lc)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        init_cache_specs(cfg, batch, max_len))


def decode_step(
    params: Dict,
    cache: Dict,
    tokens: jax.Array,        # (B,) int32 — the newly sampled tokens
    pos: jax.Array,           # scalar int32 — their position
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict]:
    """One serving step: logits for the next token + updated cache."""
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :]   # (B, 1, D)

    if cfg.family == "ssm":
        def body(h, xs):
            lp, c = xs
            y, nc = mamba2.mamba_decode(lp["mamba"], h, c, cfg)
            return h + y, nc
        x, new_m = scanning.scan(body, x, (params["layers"], cache["mamba"]))
        new_cache = {"mamba": new_m}
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, x, cache, pos, cfg)
    else:
        def body(h, xs):
            lp, c = xs
            y, nc = L.attn_decode(lp["attn"], h, c, cfg, pos=pos)
            h = h + y
            if cfg.is_moe:
                h = h + moe.moe_decode(lp["moe"], h, cfg)
            else:
                h = h + L.mlp_forward(lp["mlp"], h, cfg)
            return h, nc
        x, new_cache = scanning.scan(body, x, (params["layers"], cache))

    x = L.norm(x, params["final_norm"], cfg)
    logits = jnp.matmul(x[:, 0], lm_head_weight(params, cfg),
                        preferred_element_type=jnp.float32)
    return logits, new_cache


def _hybrid_decode(params, x, cache, pos, cfg):
    n_groups, g, tail = _hybrid_split(cfg)
    shared = params["shared"]

    head_p = _tree_take(params["layers"], 0, n_groups * g, (n_groups, g))
    head_c = _tree_take(cache["mamba"], 0, n_groups * g, (n_groups, g))

    def mamba_body(h, xs):
        lp, c = xs
        y, nc = mamba2.mamba_decode(lp["mamba"], h, c, cfg)
        return h + y, nc

    def group_body(h, xs):
        gp, gc, ac = xs
        h, nmc = scanning.scan(mamba_body, h, (gp, gc))
        y, nac = L.attn_decode(shared["attn"], h, ac, cfg, pos=pos)
        h = h + y
        h = h + L.mlp_forward(shared["mlp"], h, cfg)
        return h, (nmc, nac)

    x, (new_head_c, new_attn_c) = scanning.scan(
        group_body, x, (head_p, head_c, cache["attn"]))
    new_head_c = jax.tree_util.tree_map(
        lambda a: a.reshape(n_groups * g, *a.shape[2:]), new_head_c)
    if tail:
        tail_p = _tree_take(params["layers"], n_groups * g, cfg.num_layers)
        tail_c = _tree_take(cache["mamba"], n_groups * g, cfg.num_layers)
        x, new_tail_c = scanning.scan(mamba_body, x, (tail_p, tail_c))
        new_m = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0),
            new_head_c, new_tail_c)
    else:
        new_m = new_head_c
    return x, {"mamba": new_m, "attn": new_attn_c}
