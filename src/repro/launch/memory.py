"""Analytic per-device memory estimates (no compile, no devices).

The CPU XLA backend inflates ``memory_analysis().temp_size_in_bytes`` for
remat-under-scan modules (it materializes f32 copies of the bf16 residual
stash — jaxpr-level residuals are bf16; see EXPERIMENTS.md §Dry-run).  This
module computes the exact JAX-level per-device footprint from the sharding
rules alone:

    params + optimizer (m, v) + gradient transient + remat layer stash
    + decode/prefill caches

so the "does it fit 16 GB HBM" question is answered from ground truth.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH_AXES, SEQ_AXES, rules_for, spec_for
from repro.nn.config import ModelConfig, ShapeSpec
from repro.nn.model import Model


@dataclass(frozen=True)
class FakeMesh:
    """Duck-typed stand-in for jax Mesh: only .shape is consulted by
    spec_for, so memory estimation needs no devices at all."""
    shape: Dict[str, int]


def _bytes(shape, dtype) -> int:
    return int(np.prod(shape)) * jnp.dtype(dtype).itemsize


def _shard_factor(spec, mesh: FakeMesh) -> int:
    f = 1
    for part in spec:
        if part is None:
            continue
        for ax in (part if isinstance(part, tuple) else (part,)):
            f *= mesh.shape[ax]
    return f


def _tree_device_bytes(abstract, axes, rules, mesh: FakeMesh) -> int:
    total = 0
    leaves = jax.tree_util.tree_leaves(abstract)
    axleaves = jax.tree_util.tree_flatten(
        axes, is_leaf=lambda x: isinstance(x, tuple))[0]
    for a, ax in zip(leaves, axleaves):
        spec = spec_for(a.shape, ax, rules, mesh)
        total += _bytes(a.shape, a.dtype) // _shard_factor(spec, mesh)
    return total


def estimate_cell_memory(cfg: ModelConfig, shape: ShapeSpec,
                         mesh_shape: Optional[Dict[str, int]] = None
                         ) -> Dict[str, float]:
    """Per-device GiB by category for one (arch, shape, mesh) cell."""
    mesh = FakeMesh(mesh_shape or {"data": 16, "model": 16})
    chips = int(np.prod(list(mesh.shape.values())))
    model = Model(cfg)
    rules = rules_for(cfg)
    abst = model.abstract_params()
    axes = model.param_axes()

    out: Dict[str, float] = {}
    params_dev = _tree_device_bytes(abst, axes, rules, mesh)
    out["params"] = params_dev

    batch_axes = [a for a in BATCH_AXES if a in mesh.shape]
    bt = int(np.prod([mesh.shape[a] for a in batch_axes])) or 1
    b_loc = shape.global_batch // bt if shape.global_batch % bt == 0 \
        else shape.global_batch

    if shape.kind == "train":
        out["optimizer_m_v"] = 2 * params_dev * 2      # f32 vs bf16 params
        out["gradients"] = params_dev
        # remat stash: one carry per scanned layer (bf16 hidden state)
        n_iters = cfg.num_layers
        if cfg.family == "hybrid":
            n_iters = cfg.num_layers // cfg.shared_attn_every \
                + cfg.num_layers % cfg.shared_attn_every
        out["remat_stash"] = n_iters * b_loc * shape.seq_len \
            * cfg.d_model * 2
        # largest transient: one layer's activations (~4x hidden) + loss chunk
        out["transient_est"] = 8 * b_loc * shape.seq_len * cfg.d_model * 2
    else:
        cache = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_dev = 0
        for path, s in jax.tree_util.tree_flatten_with_path(cache)[0]:
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            # mirror distributed.sharding.cache_shardings factors
            f = 1
            B = s.shape[1]
            used = []
            if batch_axes and B % bt == 0:
                f *= bt
                used = list(batch_axes)
            if name in ("k", "v"):
                seq_axes = [a for a in SEQ_AXES
                            if a in mesh.shape and a not in used]
                st = int(np.prod([mesh.shape[a] for a in seq_axes])) or 1
                if seq_axes and s.shape[3] % st == 0:
                    f *= st
            elif "model" in mesh.shape and "model" not in used:
                m = mesh.shape["model"]
                if any(d % m == 0 for d in s.shape[2:]):
                    f *= m
            cache_dev += _bytes(s.shape, s.dtype) // f
        out["kv_or_state_cache"] = cache_dev
        out["transient_est"] = 4 * b_loc * max(1, shape.seq_len
                                               if shape.kind == "prefill"
                                               else 1) * cfg.d_model * 2

    out = {k: v / 2**30 for k, v in out.items()}
    out["total_gib"] = sum(out.values())
    out["chips"] = chips
    out["fits_16gib_hbm"] = out["total_gib"] <= 16.0
    return out


def estimate_step_hbm_bytes(cfg: ModelConfig, shape: ShapeSpec,
                            mesh_shape: Optional[Dict[str, int]] = None,
                            microbatches: int = 1) -> Dict[str, float]:
    """Fusion-aware per-device HBM traffic model for one step (roofline
    memory term).

    XLA:CPU's `cost_analysis()["bytes accessed"]` sums operand/result bytes
    of every *instruction*; the CPU pipeline barely fuses, so elementwise
    chains (norms, softmax, rope) count 10-30x the traffic a fused TPU
    program moves.  This model counts only fusion-boundary traffic:
      weights (x3: fwd + remat + bwd), remat stash (write + read),
      per-layer activation materializations (~8 hidden-sized tensors x3
      passes), flash-attention KV refetch (nq x (K+V)), loss logits chunks,
      optimizer state (m,v read+write f32 + param update), caches.
    Returns a breakdown dict with "total" in bytes.
    """
    mesh = FakeMesh(mesh_shape or {"data": 16, "model": 16})
    model = Model(cfg)
    rules = rules_for(cfg)
    params_dev = _tree_device_bytes(model.abstract_params(),
                                    model.param_axes(), rules, mesh)
    batch_axes = [a for a in BATCH_AXES if a in mesh.shape]
    bt = int(np.prod([mesh.shape[a] for a in batch_axes])) or 1
    b_loc = shape.global_batch // bt if shape.global_batch % bt == 0 \
        else shape.global_batch
    tp = mesh.shape.get("model", 1)
    D, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    S = shape.seq_len
    hid = b_loc * S * D * 2                       # one bf16 hidden tensor

    out: Dict[str, float] = {}
    if shape.kind == "train":
        # gathered weights are read fwd + remat + bwd (bf16)
        out["weights"] = 3.0 * params_dev * (2 if cfg.fsdp else 1)
        n_iters = L if cfg.family != "hybrid" else \
            L // cfg.shared_attn_every + L % cfg.shared_attn_every
        out["remat_stash"] = 2.0 * n_iters * hid   # write + read (sum over
        # microbatches: per-microstep stash is hid/mb, times mb steps)
        out["layer_activations"] = 8.0 * n_iters * hid * 3
        if cfg.num_heads:
            Hl = max(1, cfg.num_heads // tp)
            nq = max(1, S // 512)
            kv = b_loc * S * Hl * cfg.head_dim * 2
            out["attention_kv_refetch"] = 3.0 * L * nq * 2 * kv \
                if cfg.family not in ("ssm",) else 0.0
        out["logits"] = 3.0 * b_loc * S * (V // tp) * 4
        out["optimizer"] = 2 * (params_dev * 2) * 2 + 4 * params_dev
        out["gradients"] = 2.0 * params_dev
    elif shape.kind == "prefill":
        out["weights"] = params_dev * (2 if cfg.fsdp else 1)
        out["layer_activations"] = 8.0 * L * hid
        if cfg.num_heads:
            Hl = max(1, cfg.num_heads // tp)
            nq = max(1, S // 512)
            kv = b_loc * S * Hl * cfg.head_dim * 2
            out["attention_kv_refetch"] = L * nq * 2 * kv \
                if cfg.family not in ("ssm",) else 0.0
        est = estimate_cell_memory(cfg, shape, mesh_shape)
        out["cache_write"] = est["kv_or_state_cache"] * 2**30
        out["logits"] = b_loc * (V // tp) * 4
    else:  # decode
        out["weights"] = params_dev * (2 if cfg.fsdp else 1)
        est = estimate_cell_memory(cfg, shape, mesh_shape)
        out["cache_read"] = est["kv_or_state_cache"] * 2**30
        out["activations"] = 20.0 * b_loc * D * 2 * L
        out["logits"] = b_loc * (V // tp) * 4
    out["total"] = sum(out.values())
    return out


def select_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                        mesh_shape: Optional[Dict[str, int]] = None,
                        hbm_budget_gib: float = 14.0) -> int:
    """Zero-autotuning microbatch selection — the paper's philosophy applied
    one level up: choose the smallest gradient-accumulation factor whose
    predicted per-device footprint fits the HBM budget.

    Footprint(mb) = fixed (params + m/v + grads + f32 grad accumulator for
    mb>1) + (stash + transients)/mb.  Deterministic, model-driven, O(#mb)."""
    if shape.kind != "train":
        return 1
    est = estimate_cell_memory(cfg, shape, mesh_shape)
    fixed = est["params"] + est["optimizer_m_v"] + est["gradients"]
    act = est["remat_stash"] + est["transient_est"]
    for mb in (1, 2, 4, 8, 16, 32):
        if shape.global_batch % mb:
            continue
        accum = 0.0 if mb == 1 else 2 * est["params"]  # f32 accumulator
        if fixed + accum + act / mb <= hbm_budget_gib:
            return mb
    return 32
