"""Continuous-batching serving engine: request queue -> priced buckets ->
slot-reuse decode.

The serving hot path the bucketing model prices (DESIGN.md §10):

* **Admission** pops queued requests into free *slots* of a fixed-size
  decode batch.  With a :class:`~repro.core.bucketing.BucketPlan`, prompts
  are right-padded to their bucket edge — one prefill executable per edge,
  not per ragged length — and each row reads its logits out at its true
  last token (``last_pos``; causal attention makes the padded tail
  invisible).  Padding is only exact for attention families: SSM/hybrid
  state would integrate the pad tokens, so those run unpadded (exact,
  per-length compiles).
* **Decode** is one step-synchronous jitted call over all slots with a
  *per-slot position vector* — freshly admitted rows coexist with rows
  deep into generation; each row masks its own prefix and writes KV at its
  own offset.  Finished rows free their slot mid-flight and the next
  request is admitted without stopping the batch.
* **Warm-up**: every bucket edge's step GEMMs are selected in ONE
  ``select_gemm_config_batch`` call before serving, so the cold selection
  cost is paid once, vectorized, instead of per-shape on the first request.

Fail-soft semantics are PR 5's, unchanged: every prefill/decode is
transient-retried (the fault hook fires BEFORE the donated-cache decode,
so a retried step replays an intact cache), a
:class:`~repro.runtime.fault_tolerance.PreemptionGuard` drains cleanly at
the loop top, and a faulted run's emitted tokens are a bit-exact prefix of
the clean run's (sampling keys are pre-split per global step, so a retry
or drain never shifts the key stream).

The decode loop never round-trips to the host: sampled tokens stay on
device (one stack at end of run), RNG keys are pre-split in chunks, and
the loop blocks only at ``sync_every`` boundaries — where the
:class:`~repro.runtime.fault_tolerance.StragglerMonitor` records the pure
device-step time alongside the host dispatch time.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.bucketing import BucketPlan, step_gemms
from repro.core.selector import (get_residual_corrector,
                                 select_gemm_config_batch)
from repro.core.simulator import simulate_gemm
from repro.core.topology import topology_fingerprint
from repro.kernels import ops
from repro.nn.model import Model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.drift import get_drift_monitor, record_step_drift
from repro.obs.metrics import MetricsRegistry
from repro.runtime.fault_tolerance import (PreemptionGuard, StragglerMonitor,
                                           retry)

_STEP_RETRIES = 2
_STEP_BASE_DELAY = 0.01
_STEP_MAX_DELAY = 0.1
_KEY_CHUNK = 64


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32 token ids
    max_new_tokens: int                 # tokens to emit (incl. prefill's)
    extras: Optional[Dict] = None


@dataclass
class RequestResult:
    rid: int
    prompt_len: int
    padded_len: int                     # == prompt_len when unpadded
    tokens: np.ndarray                  # (n,) generated ids, n<=max_new
    admit_step: int                     # global step of first decode
    finish_step: int                    # global step after last decode
    finished: bool                      # False when drained mid-flight


@dataclass
class _Slot:
    rid: int = -1
    pos: int = 0                        # next KV write offset for this row
    remaining: int = 0
    admit_step: int = 0

    @property
    def active(self) -> bool:
        return self.rid >= 0


class ServingEngine:
    """One model, one decode batch of ``max_batch`` slots, FIFO admission.

    ``plan`` (optional) buckets ragged prompt lengths; without it every
    distinct length prefills at its exact shape.  ``decode_fault`` is the
    fault-injection hook: called as ``decode_fault(step, guard)`` at the
    top of every decode attempt, before the cache is donated."""

    def __init__(self, model: Model, params: Dict, *,
                 max_batch: int, max_len: int,
                 plan: Optional[BucketPlan] = None,
                 temperature: float = 0.0, seed: int = 0,
                 sync_every: int = 8,
                 decode_fault: Optional[Callable[..., None]] = None,
                 straggler_window: int = 16, straggler_min_steps: int = 4,
                 quiet: bool = False):
        cfg = model.cfg
        if plan is not None and cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"bucketed (padded) admission is not exact for family "
                f"{cfg.family!r}: recurrent state integrates pad tokens. "
                f"Run without a plan (exact, per-length compiles).")
        self.model = model
        self.params = params
        self.plan = plan
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.temperature = float(temperature)
        self.sync_every = max(int(sync_every), 1)
        self.decode_fault = decode_fault
        self._queue: List[Request] = []
        self._next_rid = 0
        self._base_key = jax.random.PRNGKey(seed)
        self._key_chunks: Dict[int, jax.Array] = {}
        self.straggler = StragglerMonitor(window=straggler_window,
                                          min_steps=straggler_min_steps)
        self.retries = 0
        self.quiet = bool(quiet)
        # Per-run metrics registry (DESIGN.md §11): ``run()`` rebuilds it,
        # backs the integer stats counters with it, and merge-publishes it
        # into the process-global registry when metrics are enabled.  Kept
        # as an attribute so ``launch/serve.py`` can export it afterwards.
        self.run_registry: MetricsRegistry = MetricsRegistry()
        # Modeled one-decode-step latency at M = max_batch (the drift
        # monitor's prediction for each sync window); filled by warm_start.
        self.predicted_step_s: Optional[float] = None

        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        if self.temperature > 0:
            t = self.temperature

            def _sample(logits, key):
                return jax.random.categorical(key, logits / t, axis=-1)
        else:
            def _sample(logits, key):
                return jnp.argmax(logits, axis=-1)
        self._sample = jax.jit(_sample)

        def _insert(full, part, b):
            def one(dst, src):
                start = (jnp.int32(0), b) + (jnp.int32(0),) * (dst.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    dst, src.astype(dst.dtype), start)
            return jax.tree_util.tree_map(one, full, part)
        self._insert = jax.jit(_insert, donate_argnums=(0,))

    # -- queue -------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               extras: Optional[Dict] = None) -> int:
        """Enqueue one request; returns its rid.  Validates against the
        engine's KV budget up front so admission can't overflow the cache."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        padded = (self.plan.bucket_for(prompt.size) if self.plan
                  else prompt.size)
        if padded + max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"request needs {padded}+{max_new_tokens - 1} cache rows "
                f"> max_len {self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, prompt=prompt,
                                   max_new_tokens=int(max_new_tokens),
                                   extras=extras))
        return rid

    # -- warm-up -----------------------------------------------------------

    def warm_start(self) -> int:
        """Prime the selector for every shape the serving path will launch:
        each bucket edge's (or queued length's) step GEMMs plus the decode
        batch's, in ONE batched selection call.  Returns shapes primed."""
        cfg = self.model.cfg
        if cfg.family == "ssm":
            return 0                          # no attention-step GEMM grid
        gemms = step_gemms(
            cfg.d_model, cfg.d_ff,
            kv_dim=cfg.num_kv_heads * cfg.head_dim,
            vocab=cfg.vocab_size,
            swiglu=cfg.activation == "swiglu")
        ms = set(self.plan.edges if self.plan
                 else {int(r.prompt.size) for r in self._queue})
        ms.add(self.max_batch)                # the decode step's M extent
        shapes = [(m, n, k) for m in sorted(ms) for (n, k) in gemms]
        hw = ops.get_default_hardware()
        with obs_trace.span("warm_start", cat="engine", track="engine",
                            args={"n_shapes": len(shapes)}):
            sels = select_gemm_config_batch(shapes, hw=hw)
        # The decode step's modeled latency: the summed priced latency of
        # its step GEMMs at M = max_batch — the drift monitor's prediction
        # for every measured sync window.
        self.predicted_step_s = sum(
            s.predicted.total for s, (m, _n, _k) in zip(sels, shapes)
            if m == self.max_batch)
        # Per-GEMM drift rows (site "warm_gemm"): when a drift monitor is
        # installed, check every warm selection's priced latency against
        # the event simulator.  Unlike the whole-step decode rows (config
        # None), these carry a config AND the topology fingerprint — the
        # residual corrector's training set (DESIGN.md §12), emitted for
        # free on every traced serving run.
        mon = get_drift_monitor()
        if mon is not None:
            for s in sels:
                try:
                    meas = simulate_gemm(s.problem, s.config, hw).time
                except (ValueError, RuntimeError):
                    continue
                mon.record_selection(s, meas, site="warm_gemm")
        return len(shapes)

    # -- serving loop ------------------------------------------------------

    def _key(self, step: int) -> jax.Array:
        c, r = divmod(step, _KEY_CHUNK)
        chunk = self._key_chunks.get(c)
        if chunk is None:
            chunk = self._key_chunks[c] = jax.random.split(
                jax.random.fold_in(self._base_key, c), _KEY_CHUNK)
        return chunk[r]

    def _status(self, msg: str) -> None:
        obs_trace.event("status", cat="engine", track="engine",
                        args={"msg": msg})
        if not self.quiet:
            print(f"[engine] {msg}")

    def _count_retry(self, attempt: int, err: Exception) -> None:
        self.retries += 1
        self.run_registry.counter("engine_retries").inc()
        obs_metrics.inc("engine_retries")
        obs_trace.event("step_retry", cat="fault", track="engine",
                        args={"attempt": attempt + 1, "error": repr(err)})
        self._status(f"transient fault absorbed "
                     f"(attempt {attempt + 1}): {err!r}")

    def run(self) -> Dict:
        """Serve the queue to completion (or preemption drain); returns the
        stats dict (see DESIGN.md §10 for the schema)."""
        cfg = self.model.cfg
        B = self.max_batch
        slots = [_Slot() for _ in range(B)]
        cache = self.model.init_cache(B, self.max_len)
        tokens = jnp.zeros((B,), jnp.int32)
        pos_host = [0] * B
        tok_log: List[jax.Array] = []        # per-step (B,) device arrays
        owners: List[Tuple[int, ...]] = []   # per-step slot->rid snapshot
        first_tok: Dict[int, jax.Array] = {}  # rid -> (1,) prefill token
        meta: Dict[int, Tuple[int, int, int]] = {}  # rid -> (plen,padded,adm)
        finished: Dict[int, int] = {}        # rid -> finish_step
        # Per-run metrics registry: the integer stats accumulators ARE
        # registry counters now (same arithmetic, so the public stats dict
        # stays bit-identical); merged into the process-global registry at
        # run end when metrics are enabled.
        reg = self.run_registry = MetricsRegistry()
        c_real = reg.counter("engine_real_rows")
        c_padded = reg.counter("engine_padded_rows")
        tr = obs_trace.get_tracer()
        drift_on = (self.predicted_step_s is not None
                    and get_drift_monitor() is not None)
        topo_fp = (topology_fingerprint(ops.get_default_hardware())
                   if drift_on else "")
        t_prefill = 0.0
        dispatch_acc: List[float] = []
        drained = False
        step = 0
        t_sync = None

        def admit(b: int) -> None:
            nonlocal t_prefill, tokens
            nonlocal cache
            req = self._queue.pop(0)
            plen = int(req.prompt.size)
            padded = (self.plan.bucket_for(plen) if self.plan else plen)
            prompt = np.zeros((1, padded), np.int32)
            prompt[0, :plen] = req.prompt
            last_pos = (jnp.asarray([plen - 1], jnp.int32)
                        if padded != plen else None)
            t0 = time.perf_counter()
            with (tr.span("prefill", cat="engine", track="engine",
                          args={"rid": req.rid, "slot": b,
                                "prompt_len": plen, "padded_len": padded})
                  if tr is not None else obs_trace.NULL_SPAN):
                logits, pc = retry(
                    lambda: self._prefill(self.params, jnp.asarray(prompt),
                                          req.extras or None, last_pos),
                    retries=_STEP_RETRIES, base_delay=_STEP_BASE_DELAY,
                    max_delay=_STEP_MAX_DELAY, on_retry=self._count_retry)
                cache = self._insert(cache, pc, jnp.int32(b))
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (1,)
                tokens = tokens.at[b].set(tok[0])
            t_prefill += time.perf_counter() - t0
            first_tok[req.rid] = tok
            slots[b].rid = req.rid
            slots[b].pos = plen
            slots[b].remaining = req.max_new_tokens - 1
            slots[b].admit_step = step
            pos_host[b] = plen
            meta[req.rid] = (plen, padded, step)
            reg.counter("engine_bucket_hits",
                        labels={"edge": str(padded)}).inc()
            c_real.inc(plen)
            c_padded.inc(padded)
            if slots[b].remaining == 0:       # single-token request
                finished[req.rid] = step
                slots[b].rid = -1

        t_run0 = time.perf_counter()
        with PreemptionGuard() as guard:
            while True:
                if guard.should_stop:
                    if any(s.active for s in slots) or self._queue:
                        drained = True
                        self._status(f"preemption requested; draining "
                                     f"after {step} decode steps")
                    break
                for b in range(B):
                    if not slots[b].active and self._queue:
                        admit(b)
                if not any(s.active for s in slots):
                    break
                pos_dev = jnp.asarray(pos_host, jnp.int32)
                this_step = step

                def body():
                    # Fault hook fires BEFORE decode: a retried step
                    # replays an intact (not-yet-donated) cache.
                    if self.decode_fault is not None:
                        self.decode_fault(this_step, guard)
                    return self._decode(self.params, cache, tokens, pos_dev)

                td0 = time.perf_counter()
                with (tr.span("decode_step", cat="engine", track="engine",
                              args={"step": this_step,
                                    "active": sum(1 for s in slots
                                                  if s.active)})
                      if tr is not None else obs_trace.NULL_SPAN):
                    logits, cache = retry(
                        body, retries=_STEP_RETRIES,
                        base_delay=_STEP_BASE_DELAY,
                        max_delay=_STEP_MAX_DELAY,
                        on_retry=self._count_retry)
                    tokens = self._sample(logits, self._key(step)
                                          ).astype(jnp.int32)
                dispatch_acc.append(time.perf_counter() - td0)
                tok_log.append(tokens)
                owners.append(tuple(s.rid for s in slots))
                for b in range(B):
                    s = slots[b]
                    if not s.active:
                        continue
                    s.pos += 1
                    pos_host[b] = s.pos
                    s.remaining -= 1
                    if s.remaining == 0:
                        finished[s.rid] = step + 1
                        s.rid = -1            # slot free: reused next admit
                step += 1
                if step % self.sync_every == 0:
                    tokens.block_until_ready()
                    now = time.perf_counter()
                    window = now - (t_sync if t_sync is not None else t_run0)
                    t_sync = now
                    n = min(self.sync_every, len(dispatch_acc))
                    device_s = window / max(n, 1)
                    dispatch_s = sum(dispatch_acc[-n:]) / max(n, 1)
                    msg = self.straggler.record(device_s,
                                                dispatch_s=dispatch_s)
                    if msg:
                        reg.counter("engine_straggler_flags").inc()
                        obs_metrics.inc("engine_straggler_flags")
                        obs_trace.event(
                            "straggler_flag", cat="engine", track="engine",
                            args={"step": step, "device_step_s": device_s,
                                  "dispatch_s": dispatch_s, "msg": msg})
                        self._status(msg)
                    if obs_metrics.metrics_enabled():
                        obs_metrics.set_gauge("engine_queue_depth",
                                              len(self._queue))
                        obs_metrics.set_gauge(
                            "engine_slot_occupancy",
                            sum(1 for s in slots if s.active) / B)
                    if drift_on:
                        record_step_drift(
                            site="decode_step", shape=(B,),
                            predicted_s=self.predicted_step_s,
                            measured_s=device_s, topo=topo_fp,
                            step=step, dispatch_s=dispatch_s)
        jax.block_until_ready(tokens)
        t_decode = time.perf_counter() - t_run0
        rem = step % self.sync_every
        if rem:                   # tail window shorter than sync_every:
            window = time.perf_counter() \
                - (t_sync if t_sync is not None else t_run0)
            self.straggler.record(
                window / rem,
                dispatch_s=sum(dispatch_acc[-rem:]) / rem)
            if drift_on:
                record_step_drift(
                    site="decode_step", shape=(B,),
                    predicted_s=self.predicted_step_s,
                    measured_s=window / rem, topo=topo_fp,
                    step=step, dispatch_s=sum(dispatch_acc[-rem:]) / rem)

        # One transfer for the whole run: stack the device-side step log.
        decoded = (np.asarray(jnp.stack(tok_log)) if tok_log
                   else np.zeros((0, B), np.int32))
        firsts = {r: int(np.asarray(t)[0]) for r, t in first_tok.items()}
        results: Dict[int, RequestResult] = {}
        emitted = 0
        for rid, (plen, padded, adm) in meta.items():
            fin = finished.get(rid, step)
            cols = [firsts[rid]]
            for s_ in range(adm, fin):
                b = owners[s_].index(rid) if rid in owners[s_] else -1
                if b >= 0:
                    cols.append(int(decoded[s_, b]))
            results[rid] = RequestResult(
                rid=rid, prompt_len=plen, padded_len=padded,
                tokens=np.asarray(cols, np.int32), admit_step=adm,
                finish_step=fin, finished=rid in finished)
            emitted += len(cols)
        # Stats come off the per-run registry where the accumulator was a
        # counter (same integer arithmetic as the old hand-rolled dicts, so
        # the public schema AND values are unchanged).
        real_rows, padded_rows = c_real.value, c_padded.value
        pad_frac = (1.0 - real_rows / padded_rows) if padded_rows else 0.0
        bucket_hits = {int(dict(m.labels)["edge"]): m.value
                       for m in reg.metrics()
                       if m.name == "engine_bucket_hits"}
        tokens_per_s = emitted / max(t_decode + t_prefill, 1e-9)
        reg.counter("engine_steps").inc(step)
        reg.counter("engine_tokens_emitted").inc(emitted)
        reg.gauge("engine_tokens_per_s").set(tokens_per_s)
        reg.gauge("engine_pad_fraction").set(pad_frac)
        if obs_metrics.metrics_enabled():
            obs_metrics.get_registry().merge(reg)
        return {
            "results": results,
            "steps": step,
            "drained": drained,
            "retries": self.retries,
            "stragglers": list(self.straggler.flagged),
            "t_prefill_s": t_prefill,
            "t_decode_s": t_decode,
            "tokens_emitted": emitted,
            "tokens_per_s": tokens_per_s,
            "bucket_hits": dict(sorted(bucket_hits.items())),
            "pad_fraction": pad_frac,
            "dispatch_s_mean": (sum(dispatch_acc) / len(dispatch_acc)
                                if dispatch_acc else 0.0),
            "device_step_s_mean": (sum(self.straggler.times)
                                   / len(self.straggler.times)
                                   if self.straggler.times else 0.0),
            "queued_left": len(self._queue),
            "residual_active": get_residual_corrector() is not None,
        }
