"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production features wired in:
  * sharded state on a (data, model) mesh (TP/FSDP/EP per sharding rules)
  * checkpoint/restart (atomic, hashed, elastic restore onto a new mesh)
  * preemption hook (SIGTERM -> checkpoint -> clean exit)
  * straggler monitor (z-score step times), bounded retry on transients
  * deterministic restart-safe data stream + background prefetch
  * optional int8 error-feedback gradient compression on the DP axis
    (--compress-dp; shard_map path, see optim.compression)
"""
from __future__ import annotations

import argparse
import os
import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.configs.registry import ARCH_IDS, get_config
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.distributed import (batch_shardings, opt_shardings,
                               param_shardings, replicated)
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import TrainState, make_train_step
from repro.nn.frontends import synth_frontend_inputs
from repro.nn.model import Model
from repro.optim import AdamW, warmup_cosine
from repro.runtime import (MetricLogger, PreemptionGuard, StragglerMonitor,
                           retry)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--tp", type=int, default=1, help="model-axis size")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log", default=None, help="JSONL metrics path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    mesh = make_local_mesh(tp=args.tp)
    print(f"arch={cfg.name} params={model.param_count():,} "
          f"mesh={dict(mesh.shape)} devices={jax.device_count()}")

    opt = AdamW(lr=warmup_cosine(args.lr, args.warmup, args.steps))
    train_step = make_train_step(model, opt)

    p_sh = param_shardings(model, mesh)
    state_sh = TrainState(params=p_sh, opt=opt_shardings(p_sh, mesh),
                          step=replicated(mesh))

    # ---- init or restore (elastic: re-shards onto this mesh) -----------
    start_step = 0
    if args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir) is not None:
        from repro.launch.steps import abstract_train_state
        template = abstract_train_state(model, opt)
        start_step, state = ckpt_lib.restore(
            args.ckpt_dir, template, shardings=state_sh)
        print(f"restored checkpoint at step {start_step}")
    else:
        rng = jax.random.PRNGKey(args.seed)
        params = jax.jit(model.init, out_shardings=p_sh)(rng)
        state = TrainState(params=params, opt=opt.init(params),
                           step=jnp.zeros((), jnp.int32))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch,
                                  seed=args.seed))
    stream = Prefetcher(data.iterate(start_step), depth=2)

    in_specs = {"tokens": jax.ShapeDtypeStruct(
        (args.batch, args.seq), jnp.int32)}
    extras = synth_frontend_inputs(cfg, jax.random.PRNGKey(1),
                                   args.batch, args.seq)
    for k, v in extras.items():
        in_specs[k] = jax.ShapeDtypeStruct(v.shape, v.dtype)
    b_sh = batch_shardings(in_specs, mesh)

    jitted = jax.jit(train_step,
                     in_shardings=(state_sh, b_sh),
                     out_shardings=(state_sh, replicated(mesh)),
                     donate_argnums=(0,))

    guard = PreemptionGuard()
    monitor = StragglerMonitor()
    logger = MetricLogger(args.log)

    def save(step):
        if args.ckpt_dir:
            path = ckpt_lib.save(args.ckpt_dir, step, state,
                                 extra_meta={"arch": cfg.name})
            print(f"checkpointed step {step} -> {path}")

    step = start_step
    try:
        for step in range(start_step, args.steps):
            if guard.should_stop:
                print("preemption signal: checkpointing and exiting")
                save(step)
                return 0
            batch_np = next(stream)
            batch = {"tokens": jnp.asarray(batch_np["tokens"]), **extras}
            t0 = time.time()
            state, metrics = retry(jitted, state, batch, retries=2)
            metrics = jax.device_get(metrics)
            dt = time.time() - t0
            warn = monitor.record(dt)
            if warn:
                print(warn)
            rec = logger.log(step + 1, loss=metrics["loss"],
                             grad_norm=metrics["grad_norm"],
                             lr=metrics["lr"], step_time=dt)
            if (step + 1) % 10 == 0 or step == start_step:
                print(f"step {step+1:5d} loss {rec['loss']:.4f} "
                      f"gnorm {rec['grad_norm']:.3f} {dt*1e3:.0f}ms")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save(step + 1)
    finally:
        stream.close()
        logger.close()
    save(args.steps)
    print(f"done: {args.steps - start_step} steps, "
          f"{len(monitor.flagged)} straggler events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
