import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
``.lower().compile()`` must succeed on the 16x16 single-pod mesh AND the
2x16x16 multi-pod mesh for every applicable cell;
``compiled.memory_analysis()`` proves per-device fit and
``compiled.cost_analysis()`` + the HLO collective parse feed §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes]
"""
import argparse    # noqa: E402
import dataclasses  # noqa: E402
import json        # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import numpy as np                # noqa: E402
import jax                        # noqa: E402
import jax.numpy as jnp           # noqa: E402

from repro.configs.registry import (  # noqa: E402
    ARCH_IDS, all_cells, get_config, get_shape)
from repro.core.hardware import TPU_V5E  # noqa: E402
from repro.core.roofline import (     # noqa: E402
    cost_analysis_terms, parse_collective_bytes, roofline)
from repro.core.topology import (     # noqa: E402
    HardwareSpec, topology_fingerprint)
from repro.distributed import (       # noqa: E402
    batch_shardings, cache_shardings, opt_shardings, param_shardings,
    replicated)
from repro.kernels import set_backend  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (       # noqa: E402
    TrainState, abstract_train_state, make_prefill_step, make_serve_step,
    make_train_step)
from repro.nn.model import Model       # noqa: E402
from repro.optim import AdamW          # noqa: E402


def _mem_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:                               # noqa: BLE001
        return {"error": repr(e)}


# ---------------------------------------------------------------------------
# Cost probes.
#
# XLA cost_analysis counts a `while` body once, so scanned modules
# under-report FLOPs/bytes.  We compile reduced (L, S) variants with every
# scan UNROLLED (repro.nn.scanning) — there cost_analysis is exact — and
# reconstruct the full cell through the exact structural model
#     f(L, S) = a0 + a1*S + L*(b0 + b1*S + b2*S^2)
# (embedding/loss terms linear in S; per-layer work with linear and, for
# attention, quadratic S terms; optimizer work per layer S-independent).
# Six probes (2 depths x 3 sequence points) solve it exactly.
# ---------------------------------------------------------------------------

_PROBE_S = {"train": (512, 1024, 2048),
            "prefill": (512, 1024, 2048),
            "decode": (2048, 4096, 8192)}


def _probe_depths(cfg):
    """Two reduced-depth variants + the linear depth variable (layers, or
    groups for the hybrid family) with its full-scale value."""
    if cfg.family == "hybrid":
        g = cfg.shared_attn_every
        tail = cfg.num_layers % g
        mk = lambda k: dataclasses.replace(  # noqa: E731
            cfg, num_layers=k * g + tail)
        full_x = (cfg.num_layers - tail) // g
    else:
        mk = lambda k: dataclasses.replace(cfg, num_layers=k)  # noqa: E731
        full_x = cfg.num_layers
    return [(2, mk(2)), (4, mk(4))], full_x


def _fit_and_eval(samples, X_full, S_full):
    """samples: {(x, s): value}. Fit f = a0+a1*s+x*(b0+b1*s+b2*s^2)."""
    xs = sorted({x for x, _ in samples})
    ss = sorted({s for _, s in samples})
    x1, x2 = xs
    dL = {s: (samples[(x2, s)] - samples[(x1, s)]) / (x2 - x1) for s in ss}
    A = np.array([[1.0, s, s * s] for s in ss])
    b = np.linalg.solve(A, np.array([dL[s] for s in ss]))
    a_vals = np.array([samples[(x1, s)] - x1 * dL[s] for s in ss])
    a_coef, _res, _rk, _sv = np.linalg.lstsq(
        np.array([[1.0, s] for s in ss]), a_vals, rcond=None)
    return float(a_coef[0] + a_coef[1] * S_full
                 + X_full * (b[0] + b[1] * S_full + b[2] * S_full ** 2))


def _lower_cell(model, cfg, shape, mesh, microbatches: int = 1):
    """Build (jitted, args) for one cell — shared by full run and probes."""
    from repro import meshctx
    meshctx.set_mesh(mesh)        # enables cfg.sp_stash constraints
    p_sh = param_shardings(model, mesh)
    in_specs = model.input_specs(shape)
    if shape.kind == "train":
        opt = AdamW()
        step_fn = make_train_step(model, opt, microbatches=microbatches)
        state = abstract_train_state(model, opt)
        state_sh = TrainState(params=p_sh, opt=opt_shardings(p_sh, mesh),
                              step=replicated(mesh))
        b_sh = batch_shardings(in_specs, mesh)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, b_sh),
                         out_shardings=(state_sh, replicated(mesh)),
                         donate_argnums=(0,))
        return jitted, (state, in_specs)
    if shape.kind == "prefill":
        step_fn = make_prefill_step(model)
        b_sh = batch_shardings(in_specs, mesh)
        cache_abs = model.cache_specs(shape.global_batch, shape.seq_len)
        c_sh = cache_shardings(cache_abs, mesh, cfg)
        jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh),
                         out_shardings=(replicated(mesh), c_sh))
        return jitted, (model.abstract_params(), in_specs)
    step_fn = make_serve_step(model)
    cache_abs = model.cache_specs(shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(cache_abs, mesh, cfg)
    b_sh = batch_shardings(in_specs, mesh)
    jitted = jax.jit(step_fn,
                     in_shardings=(p_sh, c_sh, b_sh["tokens"], b_sh["pos"]),
                     out_shardings=(replicated(mesh), c_sh),
                     donate_argnums=(1,))
    return jitted, (model.abstract_params(), cache_abs,
                    in_specs["tokens"], in_specs["pos"])


def run_probes(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True, microbatches: int = 1,
               sp_stash: bool = False, gqa_packed_decode: bool = False,
               kv_repeat_weights: bool = False,
               moe_dense_decode: bool = False,
               moe_local_dispatch: bool = False) -> dict:
    """Reconstruct exact per-device flops/bytes/collective-bytes via
    unrolled reduced-(L,S) compiles + structural extrapolation."""
    from repro.nn import scanning
    base_cfg = get_config(arch)
    if sp_stash:
        base_cfg = dataclasses.replace(base_cfg, sp_stash=True)
    if gqa_packed_decode:
        base_cfg = dataclasses.replace(base_cfg, gqa_packed_decode=True)
    if kv_repeat_weights:
        base_cfg = dataclasses.replace(base_cfg, kv_repeat_weights=True)
    if moe_dense_decode:
        base_cfg = dataclasses.replace(base_cfg, moe_dense_decode=True)
    if moe_local_dispatch:
        base_cfg = dataclasses.replace(base_cfg, moe_local_dispatch=True)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_backend("reference")
    depths, X_full = _probe_depths(base_cfg)
    s_points = _PROBE_S[shape.kind]

    flops_s, bytes_s, coll_s = {}, {}, {}
    scanning.set_unroll(True)
    try:
        for x, cfgv in depths:
            for s in s_points:
                shp = dataclasses.replace(shape, seq_len=s)
                model = Model(cfgv)
                jitted, args = _lower_cell(model, cfgv, shp, mesh,
                                           microbatches=microbatches)
                compiled = jitted.lower(*args).compile()
                fl, by = cost_analysis_terms(compiled)
                co = parse_collective_bytes(compiled.as_text())
                flops_s[(x, s)] = fl
                bytes_s[(x, s)] = by
                coll_s[(x, s)] = co["total"]
                if verbose:
                    print(f"    probe x={x} S={s}: flops={fl:.3e} "
                          f"bytes={by:.3e} coll={co['total']:.3e}")
    finally:
        scanning.set_unroll(False)
    S_full = shape.seq_len
    return {
        "flops": _fit_and_eval(flops_s, X_full, S_full),
        "bytes": _fit_and_eval(bytes_s, X_full, S_full),
        "collective_bytes": _fit_and_eval(coll_s, X_full, S_full),
        "probe_points": {f"x{x}_s{s}": {"flops": flops_s[(x, s)],
                                        "bytes": bytes_s[(x, s)],
                                        "coll": coll_s[(x, s)]}
                         for (x, s) in flops_s},
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "experiments/dryrun", verbose: bool = True,
             with_probes: bool = False, microbatches: int = 1,
             sp_stash: bool = False, gqa_packed_decode: bool = False,
             kv_repeat_weights: bool = False,
             moe_dense_decode: bool = False,
             moe_local_dispatch: bool = False,
             hw: HardwareSpec = TPU_V5E) -> dict:
    cfg = get_config(arch)
    if sp_stash:
        cfg = dataclasses.replace(cfg, sp_stash=True)
    if gqa_packed_decode:
        cfg = dataclasses.replace(cfg, gqa_packed_decode=True)
    if kv_repeat_weights:
        cfg = dataclasses.replace(cfg, kv_repeat_weights=True)
    if moe_dense_decode:
        cfg = dataclasses.replace(cfg, moe_dense_decode=True)
    if moe_local_dispatch:
        cfg = dataclasses.replace(cfg, moe_local_dispatch=True)
    shape = get_shape(shape_name)
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    # Mosaic cannot lower for the CPU platform: the dry-run uses the
    # reference backend, whose FLOP/byte profile matches the kernels.
    set_backend("reference")

    if microbatches == 0:          # 0 => analytic auto-selection
        from repro.launch.memory import select_microbatches
        microbatches = select_microbatches(cfg, shape, dict(mesh.shape))
    t0 = time.time()
    jitted, args = _lower_cell(model, cfg, shape, mesh,
                               microbatches=microbatches)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    flops, bytes_ = cost_analysis_terms(compiled)
    colls = parse_collective_bytes(compiled.as_text())
    from repro.launch.memory import (estimate_cell_memory,
                                     estimate_step_hbm_bytes)
    mem_analytic = estimate_cell_memory(cfg, shape, dict(mesh.shape))
    hbm_analytic = estimate_step_hbm_bytes(cfg, shape, dict(mesh.shape),
                                           microbatches=microbatches)
    # The serving topology the roofline terms below are priced against
    # (the same ``hw`` handed to ``roofline``) — recorded per artifact so
    # benchmarks/roofline_table can derive per-level port columns without
    # guessing the preset, and so passing a calibrated topology through
    # ``run_cell(hw=...)`` is visible in the artifact itself.
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "kind": shape.kind,
        "topology": {
            "name": hw.name,
            "fingerprint": topology_fingerprint(hw),
            "levels": [{"name": lvl.name, "bandwidth": lvl.bandwidth,
                        "capacity": lvl.capacity, "scope": lvl.scope}
                       for lvl in hw.levels],
        },
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "microbatches": microbatches,
        "sp_stash": sp_stash,
        "kv_repeat_weights": kv_repeat_weights,
        "gqa_packed_decode": gqa_packed_decode,
        "moe_dense_decode": moe_dense_decode,
        "moe_local_dispatch": moe_local_dispatch,
        "memory": _mem_stats(compiled),
        "memory_analytic_gib": {k: round(v, 3) if isinstance(v, float)
                                else v for k, v in mem_analytic.items()},
        "hbm_bytes_analytic": {k: float(v) for k, v in hbm_analytic.items()},
        "cost_module": {"flops": flops, "bytes": bytes_,
                        "note": "scan bodies counted once by XLA"},
        "collectives_module": {k: v for k, v in colls.items() if v},
        "params": model.param_count(),
    }
    # Reconstructed exact per-device costs (probe extrapolation).
    if with_probes:
        probes = run_probes(arch, shape_name, multi_pod, verbose=verbose,
                            microbatches=microbatches, sp_stash=sp_stash,
                            gqa_packed_decode=gqa_packed_decode,
                            kv_repeat_weights=kv_repeat_weights,
                            moe_dense_decode=moe_dense_decode,
                            moe_local_dispatch=moe_local_dispatch)
        record["cost_reconstructed"] = {k: probes[k] for k in
                                        ("flops", "bytes",
                                         "collective_bytes")}
        record["probe_points"] = probes["probe_points"]
        rep = roofline(
            arch=arch, shape_name=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=probes["flops"], hlo_bytes=hbm_analytic["total"],
            collectives={"total": probes["collective_bytes"],
                         "all-reduce": probes["collective_bytes"]},
            model_flops=model.model_flops(shape), hw=hw)
        record["roofline"] = rep.as_dict()
    else:
        rep = roofline(arch=arch, shape_name=shape_name, mesh=mesh_name,
                       chips=chips, hlo_flops=flops,
                       hlo_bytes=hbm_analytic["total"], collectives=colls,
                       model_flops=model.model_flops(shape), hw=hw)
        record["roofline"] = rep.as_dict()

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        mem = record["memory"]
        fl = record.get("cost_reconstructed", record["cost_module"])["flops"]
        print(f"[OK] {arch} x {shape_name} x {mesh_name}: "
              f"compile {t_compile:.1f}s  "
              f"args {mem.get('argument_bytes', 0)/2**30:.2f}GiB/dev  "
              f"temp {mem.get('temp_bytes', 0)/2**30:.2f}GiB/dev  "
              f"flops/dev {fl:.3e}  bound={rep.bottleneck}")
        print(f"     memory_analysis: {mem}")
        print(f"     cost_analysis(module): flops={flops:.4e} "
              f"bytes={bytes_:.4e}  collectives={record['collectives_module']}")
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + ["all"],
                    help="architecture id (or 'all')")
    ap.add_argument("--shape", default=None,
                    help="shape cell name (omit for all applicable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all applicable (arch x shape) cells")
    ap.add_argument("--with-probes", action="store_true",
                    help="also reconstruct exact costs via unrolled probes")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation factor for train cells "
                         "(0 = analytic auto-selection from memory model)")
    ap.add_argument("--sp-stash", action="store_true",
                    help="sequence-shard the residual stream at scan "
                         "boundaries (SP remat stash)")
    ap.add_argument("--gqa-packed-decode", action="store_true",
                    help="grouped-query decode attention (no KV repeat)")
    ap.add_argument("--kv-repeat-weights", action="store_true",
                    help="Megatron KV-weight duplication (TP > Hkv)")
    ap.add_argument("--moe-dense-decode", action="store_true",
                    help="decode MoE: all local experts, no weight gather")
    ap.add_argument("--moe-local-dispatch", action="store_true",
                    help="MoE dispatch packed within each data shard")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all or args.arch == "all":
        cells = [(a, s) for a, s, ok, _ in all_cells() if ok]
    else:
        assert args.arch, "--arch or --all required"
        if args.shape:
            cells = [(args.arch, args.shape)]
        else:
            cells = [(a, s) for a, s, ok, _ in all_cells()
                     if ok and a == args.arch]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, out_dir=args.out,
                         with_probes=args.with_probes,
                         microbatches=args.microbatches,
                         sp_stash=args.sp_stash,
                         gqa_packed_decode=args.gqa_packed_decode,
                         kv_repeat_weights=args.kv_repeat_weights,
                         moe_dense_decode=args.moe_dense_decode,
                         moe_local_dispatch=args.moe_local_dispatch)
            except Exception as e:                     # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"[FAIL] {arch} x {shape} x "
                      f"{'multi' if mp else 'single'}: {e}")
                traceback.print_exc()
    print(f"\n{len(cells)*len(meshes)-len(failures)} passed, "
          f"{len(failures)} failed")
    for f in failures:
        print("  FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
