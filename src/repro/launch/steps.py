"""Step functions shared by the trainer, the server and the dry-run."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.nn.model import Model
from repro.optim.adamw import AdamW, OptState


class TrainState(NamedTuple):
    params: Dict
    opt: OptState
    step: jax.Array


def make_train_step(model: Model, optimizer: AdamW, microbatches: int = 1
                    ) -> Callable[[TrainState, Dict],
                                  Tuple[TrainState, Dict]]:
    """Build the jittable train step.

    ``microbatches > 1`` enables gradient accumulation: the global batch is
    processed in N sequential micro-steps, dividing the remat layer-stash
    footprint by N at the cost of an f32 gradient accumulator.  The count is
    *selected analytically* from the memory model (launch.memory.
    select_microbatches) — the tritonBLAS philosophy applied to memory."""

    def train_step(state: TrainState, batch: Dict
                   ) -> Tuple[TrainState, Dict]:
        if microbatches == 1:
            loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        else:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches,
                                    x.shape[0] // microbatches,
                                    *x.shape[1:]),
                batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc(carry, micro):
                loss_sum, g_sum = carry
                l, g = jax.value_and_grad(model.loss)(state.params, micro)
                g_sum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (loss_sum + l, g_sum), None

            from repro.nn import scanning
            (loss, gacc), _ = scanning.scan(
                acc, (jnp.zeros((), jnp.float32), g0), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(
                lambda g, p: (g / microbatches).astype(p.dtype),
                gacc, state.params)
        new_params, new_opt, om = optimizer.update(
            grads, state.opt, state.params)
        metrics = {"loss": loss, **om}
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics
    return train_step


def make_serve_step(model: Model) -> Callable:
    def serve_step(params: Dict, cache: Dict, tokens: jax.Array,
                   pos: jax.Array) -> Tuple[jax.Array, Dict]:
        return model.decode_step(params, cache, tokens, pos)
    return serve_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        tokens = batch["tokens"]
        extras = {k: v for k, v in batch.items() if k != "tokens"}
        return model.prefill(params, tokens, extras or None)
    return prefill_step


def abstract_train_state(model: Model, optimizer: AdamW) -> TrainState:
    p = model.abstract_params()
    return TrainState(params=p, opt=optimizer.abstract_state(p),
                      step=jax.ShapeDtypeStruct((), jnp.int32))
