"""Mesh construction.  A FUNCTION, not a module constant — importing this
module never touches jax device state (dry-run sets XLA_FLAGS first)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(tp: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests, CPU examples)."""
    n = jax.device_count()
    assert n % tp == 0, (n, tp)
    return jax.make_mesh((n // tp, tp), ("data", "model"))
