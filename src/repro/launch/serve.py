"""Serving driver: continuous-batching engine over ragged or uniform
requests.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --batch 4 --prompt-len 32 --gen 32

    # ragged prompts admitted into model-priced buckets:
    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --batch 4 --prompt-len 32 --gen 32 --ragged --requests 12

Requests flow through :class:`repro.launch.engine.ServingEngine`: a FIFO
queue admits prompts into free decode slots (per-request prefill, generic
slot insert), ragged lengths are right-padded to the edges of a
model-priced :class:`~repro.core.bucketing.BucketPlan` (attention
families; exact by causality), finished sequences free their slot
mid-decode, and every bucket edge's step GEMMs are warm-selected in one
batched call before serving.  The decode loop is host-round-trip free:
tokens stay on device until one end-of-run stack, RNG keys are pre-split
per global step, and the StragglerMonitor reports pure device-step time
next to host dispatch time.

Set ``REPRO_SELECTION_CACHE=/path/to/selections.json`` to persist GEMM
config selections across server processes: a warm restart replays every
previously selected shape from disk with zero cold-path scoring.

Fail-soft serving (DESIGN.md §9) is unchanged from the engine's side:
``--topology`` loads a calibrated-topology artifact through the *guarded*
loader (corrupt artifacts quarantine, serving continues on the stock
preset); prefill and every decode step are transient-retried; a
:class:`~repro.runtime.fault_tolerance.PreemptionGuard` drains the batch
cleanly on SIGTERM/SIGINT.  ``run_serving`` is the library entry point the
fault-injection suite drives directly (``decode_fault`` hook); ``main``
is the CLI shim.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Callable, Dict, Optional

import numpy as np

import jax

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.bucketing import plan_buckets, step_gemms
from repro.core.hardware import TPU_V5E
from repro.core.selector import (get_residual_corrector,
                                 load_selection_cache, select_gemm_config,
                                 set_residual_corrector)
from repro.core.simulator import simulate_gemm
from repro.core.topology import load_calibrated_topology_guarded
from repro.distributed import param_shardings
from repro.kernels import ops
from repro.launch.engine import ServingEngine
from repro.launch.mesh import make_local_mesh
from repro.nn.frontends import synth_frontend_inputs
from repro.nn.model import Model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.drift import DriftMonitor, set_drift_monitor
from repro.obs.perfetto import export_chrome_trace


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (max concurrent sequences)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", default=None, metavar="PATH",
                    help="calibrated-topology artifact to select against "
                         "(guarded load: corrupt artifacts quarantine and "
                         "fall back to the stock preset)")
    ap.add_argument("--residual", default=None, metavar="PATH",
                    help="residual-corrector artifact (repro/residual/v1) "
                         "to re-price top-ranked candidates with (guarded "
                         "load: corrupt artifacts quarantine, stale "
                         "fingerprints are ignored; serving falls back to "
                         "the pure analytical model)")
    ap.add_argument("--ragged", action="store_true",
                    help="draw ragged prompt lengths in "
                         "[prompt-len/2, prompt-len] and admit them into "
                         "model-priced buckets (attention families)")
    ap.add_argument("--requests", type=int, default=None,
                    help="number of requests to serve "
                         "(default: --batch; ragged default: 2x)")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode steps between device syncs (straggler "
                         "sampling granularity)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress stdout status lines (they still flow "
                         "through the trace/metrics layer)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="enable telemetry and write trace.json (Perfetto), "
                         "metrics.prom, metrics.jsonl and drift.jsonl "
                         "under DIR")
    return ap


def run_serving(args: argparse.Namespace, *,
                decode_fault: Optional[Callable[..., None]] = None,
                ) -> Dict:
    """Serve one request queue end to end; returns the serving stats.

    ``decode_fault(step, guard)``, when given, runs at the top of every
    decode step's retried body — *before* the donated-cache decode
    executes, so a raise is retried against an intact cache.  This is the
    fault-injection suite's hook (``repro.calib.faults.decode_injector``);
    production never sets it.

    Returns a dict with ``tokens`` (uniform mode: the (batch, steps+1)
    generated array including the prefill token; ragged mode: a list of
    per-request arrays), ``drained`` (True when a preemption request
    stopped decode early), ``steps`` (decode steps completed), ``retries``
    (transient retries absorbed), ``stragglers``, timings, engine stats
    (``pad_fraction``, ``bucket_hits``, ``dispatch_s_mean``,
    ``device_step_s_mean``, ``tokens_per_s``), and the topology served
    against (plus ``degraded`` when the artifact was rejected).

    ``--quiet`` suppresses the stdout status lines (they still flow
    through the trace layer as events); ``--trace-dir DIR`` installs the
    telemetry subsystem for the run and writes ``trace.json`` (Perfetto,
    with the decode-step GEMMs' simulator timelines), ``metrics.prom``,
    ``metrics.jsonl`` and ``drift.jsonl`` under DIR.  The stats dict is
    identical either way.
    """
    quiet = bool(getattr(args, "quiet", False))
    trace_dir = getattr(args, "trace_dir", None)

    def _say(msg: str) -> None:
        obs_trace.event("status", cat="serve", track="serve",
                        args={"msg": msg})
        if not quiet:
            print(msg)

    prev_tracer = prev_mon = drift_mon = None
    prev_metrics = False
    # _run_serving installs the --residual corrector after the topology is
    # known; restore whatever was there before, success or raise.
    prev_res = get_residual_corrector()
    if trace_dir:
        prev_tracer = obs_trace.set_tracer(obs_trace.Tracer())
        prev_metrics = obs_metrics.enable_metrics(True)
        obs_metrics.get_registry().clear()
        drift_mon = DriftMonitor(path=os.path.join(trace_dir,
                                                   "drift.jsonl"))
        prev_mon = set_drift_monitor(drift_mon)
    try:
        out = _run_serving(args, decode_fault=decode_fault, say=_say,
                           quiet=quiet)
        if trace_dir:
            _export_telemetry(trace_dir, args)
        return out
    finally:
        set_residual_corrector(prev_res)
        if trace_dir:
            obs_trace.set_tracer(prev_tracer)
            set_drift_monitor(prev_mon)
            drift_mon.close()
            obs_metrics.enable_metrics(prev_metrics)


def _export_telemetry(trace_dir: str, args: argparse.Namespace) -> None:
    """Write the run's telemetry artifacts: the Perfetto trace (measured
    tracer spans + the decode-step GEMMs' modeled simulator timelines),
    the Prometheus textfile, and a metrics JSONL snapshot.  The drift
    JSONL streams during the run (``DriftMonitor``)."""
    cfg = get_config(args.arch, smoke=args.smoke)
    hw = ops.get_default_hardware()
    sim_timelines = []
    if cfg.family != "ssm":
        gemms = step_gemms(cfg.d_model, cfg.d_ff,
                           kv_dim=cfg.num_kv_heads * cfg.head_dim,
                           vocab=cfg.vocab_size,
                           swiglu=cfg.activation == "swiglu")[:3]
        for (n, k) in gemms:
            sel = select_gemm_config(args.batch, n, k, hw=hw)
            ev: list = []
            simulate_gemm(sel.problem, sel.config, hw, events=ev)
            sim_timelines.append((f"gemm {args.batch}x{n}x{k}", ev))
    tracer = obs_trace.get_tracer()
    export_chrome_trace(os.path.join(trace_dir, "trace.json"),
                        tracer.spans if tracer is not None else [],
                        sim_timelines)
    reg = obs_metrics.get_registry()
    reg.write_prometheus(os.path.join(trace_dir, "metrics.prom"))
    reg.write_jsonl(os.path.join(trace_dir, "metrics.jsonl"),
                    kind="serving", arch=args.arch)


def _run_serving(args: argparse.Namespace, *,
                 decode_fault: Optional[Callable[..., None]],
                 say: Callable[[str], None], quiet: bool) -> Dict:
    n_warm = load_selection_cache()            # $REPRO_SELECTION_CACHE
    if n_warm:
        say(f"[selector] warm-started {n_warm} persisted GEMM selections")

    topo_info: Dict = {"topology": TPU_V5E.name, "degraded": None}
    if getattr(args, "topology", None):
        topo, prov = load_calibrated_topology_guarded(args.topology, TPU_V5E)
        ops.set_default_hardware(topo)
        topo_info = {"topology": topo.name,
                     "degraded": prov.get("degraded"),
                     "quarantined": prov.get("quarantined")}
        if prov.get("degraded"):
            say(f"[serve] topology artifact rejected "
                f"({prov['degraded']}); serving on stock "
                f"preset {topo.name}")
        else:
            say(f"[serve] serving against calibrated topology "
                f"{topo.name}")

    res_info: Dict = {"residual": None, "residual_degraded": None}
    if getattr(args, "residual", None):
        # Guarded load against the topology actually served (which the
        # --topology block above may have just swapped in); run_serving's
        # finally restores the previous corrector.
        from repro.calib.residual import load_residual_guarded
        corr, rprov = load_residual_guarded(
            args.residual, expect=ops.get_default_hardware())
        if corr is None:
            res_info["residual_degraded"] = rprov.get("degraded")
            say(f"[serve] residual artifact rejected "
                f"({rprov.get('degraded')}); serving on the pure "
                f"analytical model")
        else:
            set_residual_corrector(corr)
            res_info["residual"] = corr.content_fingerprint()
            say(f"[serve] residual corrector active (digest "
                f"{corr.content_fingerprint()}, top-{corr.top_f} "
                f"re-pricing, fit on {corr.provenance.get('n_rows', '?')} "
                f"drift rows)")

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    max_len = args.prompt_len + args.gen
    ragged = bool(getattr(args, "ragged", False))
    n_req = getattr(args, "requests", None) or (
        2 * args.batch if ragged else args.batch)

    rng = jax.random.PRNGKey(args.seed)
    mesh = make_local_mesh(tp=args.tp)
    p_sh = param_shardings(model, mesh)
    params = jax.jit(model.init, out_shardings=p_sh)(rng)

    # Request prompts: uniform rows of prompt-len, or ragged truncations.
    prompts = np.asarray(jax.random.randint(
        rng, (n_req, args.prompt_len), 0, cfg.vocab_size), np.int32)
    extras = synth_frontend_inputs(cfg, rng, n_req, args.prompt_len)
    if ragged:
        lo = max(args.prompt_len // 2, 4)
        lens = np.random.default_rng(args.seed).integers(
            lo, args.prompt_len + 1, size=n_req).tolist()
    else:
        lens = [args.prompt_len] * n_req

    plan = None
    if ragged and cfg.family not in ("ssm", "hybrid"):
        plan = plan_buckets(
            lens,
            gemms=step_gemms(cfg.d_model, cfg.d_ff,
                             kv_dim=cfg.num_kv_heads * cfg.head_dim,
                             vocab=cfg.vocab_size,
                             swiglu=cfg.activation == "swiglu"),
            hw=ops.get_default_hardware(), max_buckets=4)
        say(f"[serve] priced bucket edges: {list(plan.edges)} "
            f"(modeled step {plan.modeled_total_s * 1e3:.2f}ms, "
            f"pad {plan.pad_fraction * 100:.1f}%)")

    engine = ServingEngine(
        model, params, max_batch=args.batch, max_len=max_len, plan=plan,
        temperature=args.temperature, seed=args.seed,
        sync_every=getattr(args, "sync_every", 8),
        decode_fault=decode_fault,
        straggler_window=16, straggler_min_steps=4, quiet=quiet)

    def _extras(i):
        if not extras:
            return None
        return jax.tree_util.tree_map(lambda x: x[i:i + 1], extras)

    for i in range(n_req):
        engine.submit(prompts[i, :lens[i]], max_new_tokens=args.gen,
                      extras=_extras(i))

    t0 = time.time()
    warmed = engine.warm_start()
    if warmed:
        say(f"[serve] warm-started {warmed} serving GEMM shapes in one "
            f"batched selection pass ({(time.time() - t0) * 1e3:.0f}ms)")

    stats = engine.run()
    results = stats["results"]
    n_steps = stats["steps"]

    rows = [results[r].tokens for r in sorted(results)]
    if (not ragged and n_req == args.batch
            and len({len(r) for r in rows}) <= 1):
        # Uniform mode: all requests admitted together and same length —
        # the legacy (batch, steps+1) matrix, prefill token first.
        tokens = (np.stack(rows) if rows else np.zeros((0, 0), np.int32))
    else:
        tokens = rows

    toks_per_s = stats["tokens_per_s"]
    say(f"arch={cfg.name} batch={args.batch} requests={n_req} "
        f"prefill {args.prompt_len} tok in "
        f"{stats['t_prefill_s'] * 1e3:.0f}ms; "
        f"decoded {n_steps} steps at {toks_per_s:.1f} tok/s total")
    say(f"[serve] dispatch {stats['dispatch_s_mean'] * 1e3:.2f}ms/step "
        f"vs device {stats['device_step_s_mean'] * 1e3:.2f}ms/step; "
        f"padding {stats['pad_fraction'] * 100:.1f}%; "
        f"bucket hits {stats['bucket_hits']}")
    show = tokens if ragged else tokens[:2]
    say("sample generations (first 2 rows, first 16 tokens):")
    for row in list(show)[:2]:
        say(f"   {np.asarray(row)[:16].tolist()}")
    return {
        "tokens": tokens,
        "steps": n_steps,
        "drained": stats["drained"],
        "retries": stats["retries"],
        "stragglers": stats["stragglers"],
        "t_prefill_s": stats["t_prefill_s"],
        "t_decode_s": stats["t_decode_s"],
        "tokens_per_s": toks_per_s,
        "pad_fraction": stats["pad_fraction"],
        "bucket_hits": stats["bucket_hits"],
        "dispatch_s_mean": stats["dispatch_s_mean"],
        "device_step_s_mean": stats["device_step_s_mean"],
        "residual_active": stats["residual_active"],
        "results": results,
        **topo_info,
        **res_info,
    }


def main() -> int:
    args = build_parser().parse_args()
    run_serving(args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
