"""Batched serving driver: prefill + decode with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --batch 4 --prompt-len 32 --gen 32

Requests are processed as a continuous batch: one prefill (returns the
decode cache), then step-synchronous decode with temperature sampling.

Set ``REPRO_SELECTION_CACHE=/path/to/selections.json`` to persist GEMM
config selections across server processes: a warm restart replays every
previously selected shape from disk with zero cold-path scoring.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.selector import load_selection_cache
from repro.distributed import (batch_shardings, cache_shardings,
                               param_shardings, replicated)
from repro.launch.mesh import make_local_mesh
from repro.nn.frontends import synth_frontend_inputs
from repro.nn.model import Model


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_warm = load_selection_cache()            # $REPRO_SELECTION_CACHE
    if n_warm:
        print(f"[selector] warm-started {n_warm} persisted GEMM selections")

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    mesh = make_local_mesh(tp=args.tp)
    max_len = args.prompt_len + args.gen

    rng = jax.random.PRNGKey(args.seed)
    p_sh = param_shardings(model, mesh)
    params = jax.jit(model.init, out_shardings=p_sh)(rng)

    prompts = jax.random.randint(rng, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    extras = synth_frontend_inputs(cfg, rng, args.batch, args.prompt_len)

    # Prefill: logits for the last prompt position + the decode cache.
    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, prompts, extras or None)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # Pad / place the cache for max_len decoding.
    full_cache = model.init_cache(args.batch, max_len)

    def place(dst, src):
        if dst.ndim >= 4 and dst.shape != src.shape:   # KV: (L,B,H,S,d)
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=3)
        return src.astype(dst.dtype)

    cache = jax.tree_util.tree_map(place, full_cache, cache)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    sample_rng = rng
    tokens = jnp.argmax(logits, axis=-1)
    out = [np.asarray(tokens)]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, tokens, pos)
        sample_rng, sub = jax.random.split(sample_rng)
        if args.temperature > 0:
            tokens = jax.random.categorical(
                sub, logits / args.temperature, axis=-1)
        else:
            tokens = jnp.argmax(logits, axis=-1)
        out.append(np.asarray(tokens))
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    toks_per_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {t_prefill*1e3:.0f}ms; "
          f"decoded {args.gen-1} steps at {toks_per_s:.1f} tok/s total")
    print("sample generations (first 2 rows, first 16 tokens):")
    for row in gen[:2]:
        print("  ", row[:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
