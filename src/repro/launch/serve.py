"""Batched serving driver: prefill + decode with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --batch 4 --prompt-len 32 --gen 32

Requests are processed as a continuous batch: one prefill (returns the
decode cache), then step-synchronous decode with temperature sampling.

Set ``REPRO_SELECTION_CACHE=/path/to/selections.json`` to persist GEMM
config selections across server processes: a warm restart replays every
previously selected shape from disk with zero cold-path scoring.

Fail-soft serving (DESIGN.md §9): ``--topology`` loads a
calibrated-topology artifact through the *guarded* loader — a corrupt or
out-of-tolerance artifact is quarantined and serving continues on the
stock preset; prefill and every decode step are transient-retried; a
:class:`~repro.runtime.fault_tolerance.PreemptionGuard` drains the batch
cleanly on SIGTERM/SIGINT (tokens decoded so far are returned, the guard's
handlers are restored on exit); a
:class:`~repro.runtime.fault_tolerance.StragglerMonitor` flags slow decode
steps.  ``run_serving`` is the library entry point the fault-injection
suite drives directly (``decode_fault`` hook); ``main`` is the CLI shim.
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.hardware import TPU_V5E
from repro.core.selector import load_selection_cache
from repro.core.topology import load_calibrated_topology_guarded
from repro.distributed import (batch_shardings, cache_shardings,
                               param_shardings, replicated)
from repro.kernels import ops
from repro.launch.mesh import make_local_mesh
from repro.nn.frontends import synth_frontend_inputs
from repro.nn.model import Model
from repro.runtime.fault_tolerance import (PreemptionGuard, StragglerMonitor,
                                           retry)

# Transient-retry policy for serving steps: short backoff — a decode step
# retry covers injected/driver transients, not sustained outages.
_STEP_RETRIES = 2
_STEP_BASE_DELAY = 0.01
_STEP_MAX_DELAY = 0.1


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", default=None, metavar="PATH",
                    help="calibrated-topology artifact to select against "
                         "(guarded load: corrupt artifacts quarantine and "
                         "fall back to the stock preset)")
    return ap


def run_serving(args: argparse.Namespace, *,
                decode_fault: Optional[Callable[..., None]] = None,
                ) -> Dict:
    """Run one continuous batch end to end; returns the serving stats.

    ``decode_fault(step, guard)``, when given, runs at the top of every
    decode step's retried body — *before* the donated-cache decode
    executes, so a raise is retried against an intact cache.  This is the
    fault-injection suite's hook (``repro.calib.faults.decode_injector``);
    production never sets it.

    Returns a dict with ``tokens`` (the (batch, steps) generated array),
    ``drained`` (True when a preemption request stopped decode early),
    ``steps`` (decode steps completed), ``retries`` (transient retries
    absorbed), ``stragglers``, timings, and the topology served against
    (plus ``degraded`` when the artifact was rejected).
    """
    n_warm = load_selection_cache()            # $REPRO_SELECTION_CACHE
    if n_warm:
        print(f"[selector] warm-started {n_warm} persisted GEMM selections")

    topo_info: Dict = {"topology": TPU_V5E.name, "degraded": None}
    if getattr(args, "topology", None):
        topo, prov = load_calibrated_topology_guarded(args.topology, TPU_V5E)
        ops.set_default_hardware(topo)
        topo_info = {"topology": topo.name,
                     "degraded": prov.get("degraded"),
                     "quarantined": prov.get("quarantined")}
        if prov.get("degraded"):
            print(f"[serve] topology artifact rejected "
                  f"({prov['degraded']}); serving on stock "
                  f"preset {topo.name}")
        else:
            print(f"[serve] serving against calibrated topology "
                  f"{topo.name}")

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    mesh = make_local_mesh(tp=args.tp)
    max_len = args.prompt_len + args.gen

    rng = jax.random.PRNGKey(args.seed)
    p_sh = param_shardings(model, mesh)
    params = jax.jit(model.init, out_shardings=p_sh)(rng)

    prompts = jax.random.randint(rng, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    extras = synth_frontend_inputs(cfg, rng, args.batch, args.prompt_len)

    retries = 0

    def _count_retry(attempt: int, err: Exception) -> None:
        nonlocal retries
        retries += 1
        print(f"[serve] transient fault absorbed "
              f"(attempt {attempt + 1}): {err!r}")

    # Prefill: logits for the last prompt position + the decode cache.
    prefill = jax.jit(model.prefill)
    t0 = time.time()
    logits, cache = retry(
        lambda: prefill(params, prompts, extras or None),
        retries=_STEP_RETRIES, base_delay=_STEP_BASE_DELAY,
        max_delay=_STEP_MAX_DELAY, on_retry=_count_retry)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # Pad / place the cache for max_len decoding.
    full_cache = model.init_cache(args.batch, max_len)

    def place(dst, src):
        if dst.ndim >= 4 and dst.shape != src.shape:   # KV: (L,B,H,S,d)
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=3)
        return src.astype(dst.dtype)

    cache = jax.tree_util.tree_map(place, full_cache, cache)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    straggler = StragglerMonitor(window=16, min_steps=4)
    sample_rng = rng
    tokens = jnp.argmax(logits, axis=-1)
    out = [np.asarray(tokens)]
    drained = False
    t0 = time.time()
    with PreemptionGuard() as guard:
        for i in range(args.gen - 1):
            if guard.should_stop:
                # Clean drain: stop issuing steps, keep what is decoded.
                drained = True
                print(f"[serve] preemption requested; draining after "
                      f"{i} decode steps")
                break
            pos = jnp.int32(args.prompt_len + i)

            def step():
                # The fault hook fires BEFORE decode so a retried step
                # replays an intact (not-yet-donated) cache.
                if decode_fault is not None:
                    decode_fault(i, guard)
                return decode(params, cache, tokens, pos)

            ts = time.time()
            logits, cache = retry(
                step, retries=_STEP_RETRIES, base_delay=_STEP_BASE_DELAY,
                max_delay=_STEP_MAX_DELAY, on_retry=_count_retry)
            sample_rng, sub = jax.random.split(sample_rng)
            if args.temperature > 0:
                tokens = jax.random.categorical(
                    sub, logits / args.temperature, axis=-1)
            else:
                tokens = jnp.argmax(logits, axis=-1)
            out.append(np.asarray(tokens))
            msg = straggler.record(time.time() - ts)
            if msg:
                print(f"[serve] {msg}")
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    n_steps = gen.shape[1] - 1                 # decode steps completed
    toks_per_s = args.batch * n_steps / max(t_decode, 1e-9)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {t_prefill*1e3:.0f}ms; "
          f"decoded {n_steps} steps at {toks_per_s:.1f} tok/s total")
    print("sample generations (first 2 rows, first 16 tokens):")
    for row in gen[:2]:
        print("  ", row[:16].tolist())
    return {
        "tokens": gen,
        "steps": n_steps,
        "drained": drained,
        "retries": retries,
        "stragglers": list(straggler.flagged),
        "t_prefill_s": t_prefill,
        "t_decode_s": t_decode,
        **topo_info,
    }


def main() -> int:
    args = build_parser().parse_args()
    run_serving(args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
