"""The single dtype byte-width table (satellite of the topology refactor).

Before this module the byte widths lived in three drifting copies —
``core/hardware.DTYPE_BYTES`` (numpy-style names), ``core/roofline``'s
private HLO-short-name table, and literal ``4``s for the f32 accumulator
sprinkled through the latency model and simulator.  Everything now reads
from here; ``core.hardware`` re-exports ``DTYPE_BYTES`` for compatibility.
"""
from __future__ import annotations

from typing import Dict

# Canonical (numpy-style) dtype names -> bytes per element.
DTYPE_BYTES: Dict[str, int] = {
    "float64": 8,
    "float32": 4,
    "float16": 2,
    "bfloat16": 2,
    "float8_e4m3fn": 1,
    "float8_e5m2": 1,
    "int64": 8,
    "int32": 4,
    "int16": 2,
    "int8": 1,
    "uint64": 8,
    "uint32": 4,
    "uint16": 2,
    "uint8": 1,
    "bool": 1,
}

# HLO shape-literal short names (as printed in HLO text dumps) -> canonical.
HLO_ALIASES: Dict[str, str] = {
    "f64": "float64", "f32": "float32", "f16": "float16", "bf16": "bfloat16",
    "f8e4m3fn": "float8_e4m3fn", "f8e5m2": "float8_e5m2",
    "s64": "int64", "s32": "int32", "s16": "int16", "s8": "int8",
    "u64": "uint64", "u32": "uint32", "u16": "uint16", "u8": "uint8",
    "pred": "bool",
}

# HLO short name -> bytes, derived (the table roofline.py parses shapes with).
HLO_DTYPE_BYTES: Dict[str, int] = {
    short: DTYPE_BYTES[canon] for short, canon in HLO_ALIASES.items()
}

# The kernels accumulate in f32 scratch; every accumulator byte term in the
# model and the simulator prices this width.
ACC_DTYPE = "float32"
ACC_BYTES = DTYPE_BYTES[ACC_DTYPE]


def canonical_dtype(name: str) -> str:
    """Resolve an HLO short name or canonical name to the canonical name."""
    if name in DTYPE_BYTES:
        return name
    if name in HLO_ALIASES:
        return HLO_ALIASES[name]
    raise KeyError(
        f"unknown dtype {name!r}; known: {sorted(DTYPE_BYTES)} "
        f"(HLO aliases: {sorted(HLO_ALIASES)})")


def dtype_bytes(name: str) -> int:
    """Bytes per element for a canonical or HLO-short dtype name."""
    return DTYPE_BYTES[canonical_dtype(name)]
