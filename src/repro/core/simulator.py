"""Event-level Pallas-grid simulator — independent ground truth for Fig. 3.

The selection-efficiency benchmark (paper §V-A) needs a "measured" latency per
candidate that the selector did NOT use to rank.  On GPU the paper measures
wall clock; in this CPU container we substitute this simulator, which models
the machine at a strictly finer granularity than the closed-form model in
``latency.py``:

* exact edge-block DMA bytes (Pallas fetches the real slice; compute always
  runs the full padded block),
* exact revisit skips at tile boundaries in the true grid iteration order
  (grouped or row-major, k innermost),
* an explicit two-stage max-plus pipeline recurrence with finite buffer depth
  (``hw.pipeline_depth``), not a steady-state max(),
* output writebacks consume the same DMA-engine port capacity as input
  fetches but do not stall queued fetches behind the tile's compute (a
  reordering DMA queue never idles with work pending; completion is
  tracked separately and carries the accumulate data dependency),
* in-kernel split-K: the grid is ``(tiles, sk, Tk)`` and the f32 accumulator
  carries across all of a tile's k-shards, so there is no HBM partial buffer
  and no combine pass — only the per-shard K padding,
* fused epilogue operands (bias / gate / residual) fetched once per output
  tile at the flush,
* per-level byte counters on multi-level topologies: each re-fetched
  operand panel's *measured* reuse distance decides which cache level
  serves it — event-by-event, not the latency model's closed-form windows
  — and the fetch is timed at that level's bandwidth (single-core: bytes
  streamed since last use, an upper-bound stack-distance proxy;
  multi-core: the exact LRU stack distance over distinct panels),
* multi-core topologies (``Topology.total_cores() > 1``): work units are
  scheduled round-robin over the cores — one (tile, k-shard) per unit under
  ``data_parallel``, contiguous k-step strips under ``stream_k`` — so the
  measured wave count (max units on any core) cross-checks the closed-form
  Alg. 4 wave model; reuse distances are measured against a chip-wide LRU
  for device-scoped caches and per-partition LRUs for partition-scoped
  ones (cores are blocked per partition within a wave); each memory port's
  bandwidth is shared over the cores actually fetching from it within a
  wave (fetch-stream population — the uniform-mixing limit of which is the
  closed-form model's per-level convention); data-parallel split-K shards
  write block partials that a per-tile combine re-reads, and stream-K
  strips pay a partial fixup at every strip boundary that is not
  tile-aligned — mirroring the schedules the model prices.

It shares nothing with ``latency.py`` but the Topology constants.

Per-tile O(1) fast path: within one output tile's k-loop, fetch and compute
times are constant (edges depend on (m, n) only; no revisit while k varies),
so the pipeline recurrence settles to a linear regime after a few steps.  We
simulate the first ``_EXPLICIT`` steps of each tile exactly and extend by the
settled slope — this keeps the simulator exact while making whole-sweep
benchmarks tractable on CPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from itertools import chain
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.dtypes import ACC_BYTES, DTYPE_BYTES
from repro.core.latency import GemmProblem, TileConfig, cdiv
from repro.core.topology import HardwareSpec, MemoryLevel, reference_dtype

_EXPLICIT = 3  # pipeline steps simulated exactly at each tile start


class _LruStack:
    """Exact LRU stack distances over a stream of (key, bytes) uses.

    The stack distance of a key is the summed size of the DISTINCT keys
    touched since its last use — the residency criterion of an ideal
    fully-associative LRU cache (repeat fetches of the same panel do not
    grow the working set).  Implemented as a Fenwick tree over the use
    order so both ``use`` and ``distance`` are O(log n): each key holds
    one live slot at its last-use position; moving a key re-zeroes its old
    slot and appends a new one."""

    __slots__ = ("tree", "n", "cursor", "total", "pos", "size")

    def __init__(self, n_slots: int = 1024):
        self.n = max(n_slots, 16)
        self.tree = [0.0] * (self.n + 1)
        self.cursor = 0
        self.total = 0.0
        self.pos: Dict = {}
        self.size: Dict = {}

    def _add(self, i: int, v: float) -> None:
        while i <= self.n:
            self.tree[i] += v
            i += i & -i

    def _prefix(self, i: int) -> float:
        s = 0.0
        while i > 0:
            s += self.tree[i]
            i -= i & -i
        return s

    def distance(self, key):
        """Bytes of distinct keys used strictly after ``key``'s last use,
        or None if the key was never used."""
        p = self.pos.get(key)
        if p is None:
            return None
        return self.total - self._prefix(p)

    def use(self, key, bytes_: float) -> None:
        p = self.pos.pop(key, None)
        if p is not None:
            old = self.size.pop(key)
            self._add(p, -old)
            self.total -= old
        if self.cursor >= self.n:             # grow: rebuild compacted
            live = sorted(self.pos, key=self.pos.get)
            self.n = max(2 * self.n, 2 * len(live) + 16)
            self.tree = [0.0] * (self.n + 1)
            self.cursor = 0
            for k in live:
                self.cursor += 1
                self.pos[k] = self.cursor
                self._add(self.cursor, self.size[k])
        self.cursor += 1
        self._add(self.cursor, bytes_)
        self.total += bytes_
        self.pos[key] = self.cursor
        self.size[key] = bytes_


@dataclass(frozen=True)
class SimResult:
    time: float          # seconds, end-to-end kernel latency
    hbm_bytes: float     # bytes moved, all levels + writebacks (legacy view)
    mxu_busy: float      # seconds the MXU was computing (chip-equivalent)
    steps: int
    # Bytes served from each memory level (backing + caches).  On a 1-level
    # chain the single entry equals hbm_bytes.
    level_bytes: Mapping[str, float] = field(default_factory=dict)
    # Occupancy cross-check (Alg. 4): schedulable work units, the measured
    # wave count (max units landed on any core by the round-robin
    # scheduler), and the core count they were spread over.  Single-core
    # chains report units == waves, cores == 1.
    units: int = 0
    waves: int = 0
    cores: int = 1

    @property
    def tflops(self) -> float:          # filled by caller via problem
        raise AttributeError("use problem.flops / result.time")


def _tile_order(Tm: int, Tn: int, group_m: int) -> Iterator[Tuple[int, int]]:
    """The kernel's (m, n) iteration order: row-major, or grouped rows with m
    innermost inside each group (Triton's grouped ordering)."""
    if group_m <= 1:
        for i in range(Tm):
            for j in range(Tn):
                yield i, j
        return
    g = group_m
    for i0 in range(0, Tm, g):
        hi = min(i0 + g, Tm)
        for j in range(Tn):
            for i in range(i0, hi):
                yield i, j


def simulate_gemm(p: GemmProblem, t: TileConfig, hw: HardwareSpec,
                  events: Optional[List[Tuple]] = None) -> SimResult:
    """Dispatch: the event-level single-core pipeline (bit-identical to the
    PR 2 simulator) on 1-core chains; the round-robin multi-core scheduler
    otherwise.

    ``events`` (optional) collects the priced timeline as
    ``(track, name, t_start, t_end, args)`` tuples — one span per DMA
    fetch / compute step / writeback on the single-core pipeline (bulk
    fast-path regions appear as one aggregated span), one span per
    fetch/write event per core on multi-core chains.  Capture is append-
    only: the priced ``SimResult`` is bit-identical with or without it
    (``repro.obs.perfetto`` renders the list as Perfetto tracks)."""
    if hw.total_cores() > 1:
        return _simulate_multicore(p, t, hw, events)
    return _simulate_single_core(p, t, hw, events)


def _simulate_single_core(p: GemmProblem, t: TileConfig,
                          hw: HardwareSpec,
                          events: Optional[List[Tuple]] = None) -> SimResult:
    bi = DTYPE_BYTES[p.in_dtype]
    bo = DTYPE_BYTES[p.out_dtype]
    mm, mn, mk = hw.mxu_shape
    bw = hw.hbm_bandwidth

    k_extent = cdiv(p.K, t.split_k)           # k span per split
    Tm, Tn = cdiv(p.M, t.bm), cdiv(p.N, t.bn)
    Tk = cdiv(k_extent, t.bk)

    # Full-block compute time: Pallas pads edge blocks in VMEM, the MXU always
    # chews the full (bm, bn, bk) block; VMEM port moves block + accumulator.
    atoms = cdiv(t.bm, mm) * cdiv(t.bn, mn) * cdiv(t.bk, mk)
    ct_mxu = atoms * (2.0 * mm * mn * mk) / hw.flops(p.in_dtype)
    ct_vmem = ((t.bm * t.bk + t.bk * t.bn) * bi
               + 2 * t.bm * t.bn * ACC_BYTES) / hw.vmem_bandwidth
    ct = max(ct_mxu, ct_vmem)

    # Multi-level state: measured reuse distances decide the serving level.
    # ``clock`` counts bytes streamed into staging (an LRU stack-distance
    # proxy); a panel re-fetched after fewer bytes than a cache level's
    # budget is served from that level at its bandwidth.
    caches = hw.cache_levels
    backing = hw.backing
    level_bytes = {lvl.name: 0.0 for lvl in hw.levels[:-1]}
    clock = 0.0
    last_a = {}                                # (batch, i, s) -> clock
    last_b = {}                                # (batch, j, s) -> clock

    def panel_level(last, key):
        prev = last.get(key)
        if prev is not None:
            dist = clock - prev
            for lvl in reversed(caches):
                if dist <= lvl.budget():
                    return lvl
        return backing

    # Pipeline state.
    depth = hw.pipeline_depth
    dma_cursor = hw.kernel_launch + hw.hbm_latency   # DMA engine free-time
    out_cursor = 0.0                                 # last flush completion
    comp_hist: List[float] = []                      # compute end times (ring)
    comp_cursor = 0.0
    total_bytes = 0.0
    mxu_busy = 0.0
    n_steps = 0

    def run_step(fetch_bytes: float, fetch_seconds: float) -> None:
        nonlocal dma_cursor, comp_cursor, total_bytes, mxu_busy, n_steps, clock
        # DMA may start once its target buffer was drained `depth` steps ago.
        gate = comp_hist[-depth] if len(comp_hist) >= depth else 0.0
        if fetch_bytes > 0:
            dma_start = max(dma_cursor, gate)
            dma_cursor = dma_start + fetch_seconds + hw.dma_fixed
            ready = dma_cursor
            if events is not None:
                events.append(("dma", "fetch", dma_start, dma_cursor,
                               {"bytes": fetch_bytes}))
        else:
            ready = gate                              # fully revisited step
        comp_cursor = max(comp_cursor, ready) + ct
        if events is not None:
            events.append(("core0", "compute", comp_cursor - ct,
                           comp_cursor, None))
        comp_hist.append(comp_cursor)
        if len(comp_hist) > depth + 1:
            del comp_hist[0]
        total_bytes += fetch_bytes
        clock += fetch_bytes
        mxu_busy += ct
        n_steps += 1

    def write_back(bytes_: float) -> None:
        # The flush waits for the tile's accumulate (data dependency) and
        # consumes port bandwidth, but does NOT stall the next tile's
        # already-queued input fetches: a reordering DMA queue never idles
        # with fetch work pending (Pallas double-buffers output windows on
        # the outbound stream).  Port capacity is reserved order-free
        # (``dma_cursor += port_s``, same engine total as before); only
        # the completion time ``out_cursor`` carries the data dependency.
        # The old ``start = max(dma_cursor, comp_cursor)`` convention put
        # an engine-idle bubble + refill in front of EVERY output tile —
        # the oracle fidelity harness exposed it as a per-tile straggler
        # artifact the continuous grid pipeline does not have.
        nonlocal dma_cursor, out_cursor, total_bytes, clock
        port_s = bytes_ / bw + hw.dma_fixed
        dma_cursor += port_s
        out_cursor = max(out_cursor, comp_cursor + port_s, dma_cursor)
        if events is not None:
            events.append(("dma", "write_back", dma_cursor - port_s,
                           dma_cursor, {"bytes": bytes_}))
        total_bytes += bytes_
        clock += bytes_                               # writes evict too
        level_bytes[backing.name] += bytes_

    ep = p.epilogue
    for e in range(p.batch):
        prev_a = prev_b = None
        for (i, j) in _tile_order(Tm, Tn, t.group_m):
            em = min(t.bm, p.M - i * t.bm)            # real edge extents
            en = min(t.bn, p.N - j * t.bn)
            # k-shards run back-to-back inside the tile (grid (tiles, sk, Tk),
            # s middle, k inner); the accumulator carries across all of them.
            for s in range(t.split_k):
                if caches:
                    lvl_a = panel_level(last_a, (e, i, s))
                    lvl_b = panel_level(last_b, (e, j, s))
                    bw_a, bw_b = lvl_a.bandwidth, lvl_b.bandwidth
                else:
                    lvl_a = lvl_b = backing
                    bw_a = bw_b = bw
                k_lo = s * k_extent
                k_hi = min(p.K, (s + 1) * k_extent)
                # Per-step fetch bytes within this shard (constant over k).
                steps_here = Tk
                first_fetches: List[Tuple[float, float]] = []
                for kk in range(min(steps_here, _EXPLICIT)):
                    ek = max(0, min(t.bk, (k_hi - k_lo) - kk * t.bk))
                    a_idx, b_idx = (i, s, kk), (s, kk, j)
                    fa = 0.0 if a_idx == prev_a else em * ek * bi
                    fb = 0.0 if b_idx == prev_b else ek * en * bi
                    prev_a, prev_b = a_idx, b_idx
                    first_fetches.append((fa, fb))
                for fa, fb in first_fetches:
                    level_bytes[lvl_a.name] += fa
                    level_bytes[lvl_b.name] += fb
                    secs = ((fa + fb) / bw if not caches
                            else fa / bw_a + fb / bw_b)
                    run_step(fa + fb, secs)
                rest = steps_here - len(first_fetches)
                if rest > 0:
                    # Settled linear regime: constant fetch (interior k) and
                    # constant compute -> both cursors advance by the slope.
                    fa = em * t.bk * bi
                    fb = t.bk * en * bi
                    f = (em * t.bk + t.bk * en) * bi
                    sf = f / bw if not caches else fa / bw_a + fb / bw_b
                    # last k block may be ragged; simulate it explicitly
                    ragged = (k_hi - k_lo) % t.bk
                    bulk = rest - (1 if ragged else 0)
                    if bulk > 0:
                        slope = max(sf + hw.dma_fixed, ct)
                        if events is not None:
                            dma0, comp0 = dma_cursor, comp_cursor
                        dma_cursor += bulk * (sf + hw.dma_fixed)
                        comp_cursor = max(comp_cursor + bulk * ct,
                                          dma_cursor + ct)
                        comp_cursor = max(comp_cursor,
                                          (comp_hist[-1] if comp_hist else 0)
                                          + bulk * slope)
                        if events is not None:
                            events.append(("dma", "fetch[bulk]", dma0,
                                           dma_cursor,
                                           {"steps": bulk,
                                            "bytes": bulk * f}))
                            events.append(("core0", "compute[bulk]", comp0,
                                           comp_cursor, {"steps": bulk}))
                        comp_hist.append(comp_cursor)
                        if len(comp_hist) > depth + 1:
                            del comp_hist[0]
                        total_bytes += bulk * f
                        clock += bulk * f
                        level_bytes[lvl_a.name] += bulk * fa
                        level_bytes[lvl_b.name] += bulk * fb
                        mxu_busy += bulk * ct
                        n_steps += bulk
                        prev_a = (i, s, steps_here - (2 if ragged else 1))
                        prev_b = (s, steps_here - (2 if ragged else 1), j)
                    if ragged:
                        ek = ragged
                        a_idx = (i, s, steps_here - 1)
                        b_idx = (s, steps_here - 1, j)
                        fa = em * ek * bi
                        fb = ek * en * bi
                        prev_a, prev_b = a_idx, b_idx
                        level_bytes[lvl_a.name] += fa
                        level_bytes[lvl_b.name] += fb
                        secs = ((fa + fb) / bw if not caches
                                else fa / bw_a + fb / bw_b)
                        run_step(fa + fb, secs)
                if caches:
                    last_a[(e, i, s)] = clock
                    last_b[(e, j, s)] = clock
            # Epilogue operand fetch + single accumulator flush per tile
            # (split-K included: no HBM partials, no combine pass).
            e_fetch = (ep.n_mn_operands * em * en
                       + (en if ep.bias else 0)) * bi
            write_back(em * en * bo + e_fetch)

    end = max(comp_cursor, dma_cursor, out_cursor)
    units = Tm * Tn * p.batch * t.split_k
    return SimResult(time=end, hbm_bytes=total_bytes,
                     mxu_busy=mxu_busy, steps=n_steps,
                     level_bytes=level_bytes,
                     units=units, waves=units, cores=1)


@dataclass
class _PlacedGrid:
    """Pass-1 (placement) record for one candidate on a multi-core chain:
    the priced-event streams plus every counter the pricing convention
    leaves untouched (``tests/test_wave_model.py`` pins the counters).
    Fetch spans carry level INDICES into ``hw.levels`` so the batched
    pricer can stack candidates into flat numpy columns."""

    ct: float                    # per-core full-block step compute seconds
    fetch_events: List[Tuple]    # (core, wave, n_empty, nfull, fa_full,
                                 #  fb_full, fa_rag, fb_rag, ia, ib)
    write_events: List[Tuple]    # (core, wave, bytes, level index)
    level_bytes: Dict[str, float]
    total_bytes: float
    mxu_busy: float
    n_steps: int
    units: int
    waves: int


def _simulate_multicore(p: GemmProblem, t: TileConfig,
                        hw: HardwareSpec,
                        events: Optional[List[Tuple]] = None) -> SimResult:
    """Round-robin multi-core scheduler over the chip's cores.

    Compute rates are the chip aggregates shared evenly (MXU: peak/C,
    staging port: bandwidth/C — cores own their compute, static share is
    physical).  Memory-port bandwidth is shared over the cores *actually
    fetching from that level within the same wave* (fetch-stream
    population): a lone core streaming compulsory panels from HBM while
    the rest of the wave hits cache gets (nearly) the full HBM rate, not a
    1/C sliver.  The calibration subsystem's oracle harness exposed the
    older all-C static share as a straggler artifact — one first-touch
    unit per wave priced at C x the HBM time dominated every wall clock —
    and in the uniform-mixing limit the population share reduces exactly
    to the closed-form model's per-level convention (wave wall = max over
    ports of wave-bytes/bandwidth).  Reuse distances are measured in bytes
    against a chip-wide clock for device-scoped caches and per-partition
    clocks for partition-scoped ones; cores are blocked per partition
    (cores [p*core_count, (p+1)*core_count) form partition p), so within a
    wave consecutive units stream through the same partition cache.

    Schedules: ``data_parallel`` — one unit per (tile, k-shard); shards of
    a split tile land on different cores, write a full-block f32 partial
    each, and the tile's last shard runs the combine (reads all split_k
    partials).  The wave index is the round-robin pass (unit_index // C).
    ``stream_k`` — the flattened k-step space is cut into
    ``ceil(steps / C)``-step strips, one per core; every strip boundary
    not on a tile edge costs one partial write + read (fixup).  Strips
    start together and advance span-by-span, so the wave index is the span
    ordinal within the strip.  Partials are consumed as soon as they are
    complete, so their footprint is deterministic: the serving level is
    the nearest cache whose budget covers it at the cache's partition
    share — the one placement decision shared with the model's
    formulation, since a never-idle buffer has no measurable reuse
    distance.

    Placement runs in a first pass in deterministic clock order (byte
    counters, serving levels, waves/units/steps are untouched by the
    pricing convention — ``tests/test_wave_model.py`` pins them); the
    second pass prices every recorded event with its wave's populations.
    """
    return _price_multicore(_place_multicore(p, t, hw), hw, events)


def _place_multicore(p: GemmProblem, t: TileConfig,
                     hw: HardwareSpec) -> _PlacedGrid:
    """Pass 1: deterministic-clock placement — serving levels from the LRU
    stacks, byte/step/wave counters, and the priced-event record (see
    :func:`_simulate_multicore` for the conventions)."""
    bi = DTYPE_BYTES[p.in_dtype]
    bo = DTYPE_BYTES[p.out_dtype]
    mm, mn, mk = hw.mxu_shape
    C = hw.total_cores()

    k_extent = cdiv(p.K, t.split_k)           # k span per split
    Tm, Tn = cdiv(p.M, t.bm), cdiv(p.N, t.bn)
    Tk = cdiv(k_extent, t.bk)                 # k blocks per shard

    # Per-core step compute time: chip rates shared evenly over C cores.
    atoms = cdiv(t.bm, mm) * cdiv(t.bn, mn) * cdiv(t.bk, mk)
    ct_mxu = atoms * (2.0 * mm * mn * mk) * C / hw.flops(p.in_dtype)
    ct_vmem = ((t.bm * t.bk + t.bk * t.bn) * bi
               + 2 * t.bm * t.bn * ACC_BYTES) * C / hw.vmem_bandwidth
    ct = max(ct_mxu, ct_vmem)

    caches = hw.cache_levels
    backing = hw.backing
    level_bytes = {lvl.name: 0.0 for lvl in hw.levels[:-1]}
    # Exact LRU stack distance per scope (``_LruStack``): the reuse
    # distance of a panel is the summed size of the DISTINCT keys touched
    # since its last use.  (The single-core simulator keeps the cheaper
    # streamed-bytes proxy — an upper bound on stack distance — because
    # its consecutive-step revisit structure rarely puts a reuse window
    # near a budget boundary; here the oracle harness showed the proxy's
    # double-counted repeat fetches spilling classes an ideal-LRU cache,
    # and the closed-form model's unique-byte windows, keep resident.)
    chip_lru = _LruStack()
    part_lru = [_LruStack() for _ in range(hw.partitions)]
    # A scope's stack is only ever read by a cache level OF that scope —
    # skip maintaining clocks no level will consult (the Fenwick updates
    # are the placement pass's hottest loop; H100-like chains have no
    # partition-scoped cache, halving their LRU cost).
    need_chip = any(lvl.scope != "partition" for lvl in caches)
    need_part = any(lvl.scope == "partition" for lvl in caches)

    def serving_level(kind, key, part) -> MemoryLevel:
        """Measured-reuse-distance placement: nearest cache whose budget
        covers the LRU stack distance since this panel's last use, in the
        cache's scope (chip-wide, or this core's partition)."""
        d_chip = d_part = None                # lazy, computed on demand
        for lvl in reversed(caches):
            if lvl.scope == "partition":
                if d_part is None:
                    d = part_lru[part].distance((kind, key))
                    d_part = float("inf") if d is None else d
                dist = d_part
            else:
                if d_chip is None:
                    d = chip_lru.distance((kind, key))
                    d_chip = float("inf") if d is None else d
                dist = d_chip
            if dist <= lvl.budget():
                return lvl
        return backing

    def record_use(kind, key, part, bytes_) -> None:
        if need_chip:
            chip_lru.use((kind, key), bytes_)
        if need_part:
            part_lru[part].use((kind, key), bytes_)

    def fixup_level() -> MemoryLevel:
        """Serving level for block partials (combine / stream-K fixup):
        produced-then-immediately-consumed, footprint = the outstanding
        partials of one tile."""
        footprint = (t.split_k if t.schedule != "stream_k" else 1) \
            * t.bm * t.bn * ACC_BYTES
        for lvl in reversed(caches):
            scale = 1.0 / hw.partitions if lvl.scope == "partition" else 1.0
            if footprint * scale <= lvl.budget():
                return lvl
        return backing

    total_bytes = 0.0
    mxu_busy = 0.0
    n_steps = 0
    block_acc = t.bm * t.bn * ACC_BYTES
    idx_of = {lvl.name: i for i, lvl in enumerate(hw.levels)}
    fix_lvl = fixup_level()
    fix_i = idx_of[fix_lvl.name]
    back_i = idx_of[backing.name]
    ep = p.epilogue

    # Pass-1 event records.  Fetch spans:
    #   (core, wave, n_empty, nfull, fa_full, fb_full, fa_rag, fb_rag,
    #    ia, ib)   [serving-level indices into hw.levels]
    # writes (partials / combines / output flushes):
    #   (core, wave, bytes, level index)
    fetch_events: List[Tuple] = []
    write_events: List[Tuple] = []

    def span_place(e, i, j, s, blk_lo, n_blk, core, wave) -> None:
        """Placement for ``n_blk`` k-blocks (starting at block ``blk_lo``)
        of k-shard ``s`` of tile (i, j) on ``core``: serving levels from
        the clocks, byte/step counters, and the priced-event record.  O(1)
        via the constant interior step (full blocks) + the ragged final k
        block of the shard."""
        nonlocal total_bytes, mxu_busy, n_steps
        part = core // hw.core_count
        em = min(t.bm, p.M - i * t.bm)
        en = min(t.bn, p.N - j * t.bn)
        k_lo = s * k_extent + blk_lo * t.bk
        k_hi = min(p.K, (s + 1) * k_extent)
        span = max(0, min(n_blk * t.bk, k_hi - k_lo))  # real (unpadded) k
        lvl_a = serving_level("a", (e, i, s), part)
        lvl_b = serving_level("b", (e, j, s), part)
        ragged = span % t.bk
        nfull = span // t.bk
        # ALL n_blk padded grid steps run (compute chews full blocks); only
        # the real span moves bytes — exactly the single-core accounting.
        n_empty = n_blk - nfull - (1 if ragged else 0)
        a_total = em * span * bi
        b_total = span * en * bi
        fetch_events.append(
            (core, wave, n_empty, nfull,
             em * t.bk * bi, t.bk * en * bi,
             em * ragged * bi, ragged * en * bi,
             idx_of[lvl_a.name], idx_of[lvl_b.name]))
        level_bytes[lvl_a.name] += a_total
        level_bytes[lvl_b.name] += b_total
        total_bytes += a_total + b_total
        mxu_busy += n_blk * ct / C
        n_steps += n_blk
        record_use("a", (e, i, s), part, a_total)
        record_use("b", (e, j, s), part, b_total)

    def writeback_place(e, i, j, core, wave) -> None:
        """Output flush + epilogue operand fetch for tile (i, j)."""
        nonlocal total_bytes
        em = min(t.bm, p.M - i * t.bm)
        en = min(t.bn, p.N - j * t.bn)
        wb = em * en * bo + (ep.n_mn_operands * em * en
                             + (en if ep.bias else 0)) * bi
        level_bytes[backing.name] += wb
        total_bytes += wb
        part = core // hw.core_count
        record_use("wb", (e, i, j), part, wb)
        write_events.append((core, wave, wb, back_i))

    tiles = [(e, i, j) for e in range(p.batch)
             for (i, j) in _tile_order(Tm, Tn, t.group_m)]

    if t.schedule == "stream_k":
        steps_per_tile = t.split_k * Tk
        total_steps = len(tiles) * steps_per_tile
        q = cdiv(total_steps, C)              # strip length (k-steps)
        units = total_steps
        waves = q                             # max k-steps on any core
        st = 0
        for core in range(cdiv(total_steps, q)):
            hi = min(st + q, total_steps)
            wave = 0                          # span ordinal within strip
            if st % steps_per_tile:
                # strip boundary inside a tile: the previous core wrote a
                # block partial, this one reads it back (fixup).
                fix = 2.0 * block_acc
                level_bytes[fix_lvl.name] += fix
                total_bytes += fix
                write_events.append((core, 0, fix, fix_i))
            while st < hi:
                ti, off = divmod(st, steps_per_tile)
                e, i, j = tiles[ti]
                s, blk = divmod(off, Tk)
                n_sub = min(hi - st, Tk - blk)
                span_place(e, i, j, s, blk, n_sub, core, wave)
                st += n_sub
                if st % steps_per_tile == 0:
                    writeback_place(e, i, j, core, wave)
                wave += 1
    else:
        unit_list = [(e, i, j, s) for (e, i, j) in tiles
                     for s in range(t.split_k)]
        units = len(unit_list)
        for q_i, (e, i, j, s) in enumerate(unit_list):
            core = q_i % C
            wave = q_i // C
            span_place(e, i, j, s, 0, Tk, core, wave)
            if t.split_k > 1:
                # shard writes its block partial; last shard combines.
                level_bytes[fix_lvl.name] += block_acc
                total_bytes += block_acc
                write_events.append((core, wave, block_acc, fix_i))
                if s == t.split_k - 1:
                    rd = t.split_k * block_acc
                    level_bytes[fix_lvl.name] += rd
                    total_bytes += rd
                    write_events.append((core, wave, rd, fix_i))
                    writeback_place(e, i, j, core, wave)
            else:
                writeback_place(e, i, j, core, wave)
        waves = cdiv(units, C)

    return _PlacedGrid(ct=ct, fetch_events=fetch_events,
                       write_events=write_events, level_bytes=level_bytes,
                       total_bytes=total_bytes, mxu_busy=mxu_busy,
                       n_steps=n_steps, units=units, waves=waves)


def _price_multicore(g: _PlacedGrid, hw: HardwareSpec,
                     events: Optional[List[Tuple]] = None) -> SimResult:
    """Pass 2 — fetch-stream populations per (wave, level): the cores of a
    wave that fetch from a level share its port; everyone else does not
    occupy it.  Writes/partials are priced at their wave's population
    (min 1 — a lone writer gets the full port)."""
    C = hw.total_cores()
    bw = [lvl.bandwidth for lvl in hw.levels]
    ct = g.ct
    core_time = [0.0] * C
    launch = hw.kernel_launch + hw.hbm_latency

    pop: Dict[Tuple[int, int], set] = {}
    for (core, wave, _, _, _, _, _, _, ia, ib) in g.fetch_events:
        pop.setdefault((wave, ia), set()).add(core)
        pop.setdefault((wave, ib), set()).add(core)
    n_pop = {k: len(v) for k, v in pop.items()}

    for (core, wave, n_empty, nfull, fa, fb, fa_r, fb_r,
         ia, ib) in g.fetch_events:
        na = n_pop[(wave, ia)]
        nb = n_pop[(wave, ib)]
        secs = n_empty * ct
        if nfull:
            secs += nfull * max(ct, (fa * na / bw[ia]
                                     + fb * nb / bw[ib])
                                + hw.dma_fixed)
        if fa_r or fb_r:
            secs += max(ct, (fa_r * na / bw[ia]
                             + fb_r * nb / bw[ib]) + hw.dma_fixed)
        if events is not None:
            t0 = launch + core_time[core]
            events.append((f"core{core}", f"unit w{wave}", t0, t0 + secs,
                           {"wave": wave,
                            "bytes": (nfull * (fa + fb) + fa_r + fb_r)}))
        core_time[core] += secs
    for (core, wave, bytes_, il) in g.write_events:
        n = n_pop.get((wave, il), 1)
        secs = bytes_ * n / bw[il]
        if events is not None:
            t0 = launch + core_time[core]
            events.append((f"core{core}", f"write w{wave}", t0, t0 + secs,
                           {"wave": wave, "bytes": bytes_,
                            "level": hw.levels[il].name}))
        core_time[core] += secs
    end = launch + max(core_time)
    return SimResult(time=end, hbm_bytes=g.total_bytes,
                     mxu_busy=g.mxu_busy, steps=g.n_steps,
                     level_bytes=g.level_bytes,
                     units=g.units, waves=g.waves, cores=C)


def _price_multicore_batch(grids: Sequence[_PlacedGrid],
                           hw: HardwareSpec) -> List[SimResult]:
    """Pass 2 across the candidate axis: :func:`_price_multicore` with the
    per-event Python loops replaced by flat numpy columns over ALL
    candidates' events at once.

    Bit-identity with the scalar pricer is by construction, not tolerance
    (``tests/test_simulator_batch.py`` hex-compares every field):

    * populations are distinct-core counts per (candidate, wave, level)
      key — integer set cardinalities, computed exactly by ``np.unique``;
    * each event's seconds evaluate the same IEEE-754 float64 operations
      in the same association order as the scalar expressions (numpy
      elementwise ops are the same C doubles);
    * per-(candidate, core) times accumulate through ONE ``np.bincount``
      over the concatenated [fetch spans, then writes] stream — bincount
      adds weights in input order, reproducing the scalar loops'
      fetch-then-write accumulation order bin by bin.
    """
    C = hw.total_cores()
    L = len(hw.levels)
    n_grids = len(grids)
    bw = np.array([lvl.bandwidth for lvl in hw.levels])
    ct = np.array([g.ct for g in grids])
    launch = hw.kernel_launch + hw.hbm_latency

    fe = np.fromiter(
        chain.from_iterable(chain.from_iterable(
            g.fetch_events for g in grids)),
        dtype=np.float64).reshape(-1, 10)
    f_cand = np.repeat(np.arange(n_grids, dtype=np.int64),
                       [len(g.fetch_events) for g in grids])
    f_core = fe[:, 0].astype(np.int64)
    f_wave = fe[:, 1].astype(np.int64)
    n_empty, nfull = fe[:, 2], fe[:, 3]
    fa, fb, fa_r, fb_r = fe[:, 4], fe[:, 5], fe[:, 6], fe[:, 7]
    ia = fe[:, 8].astype(np.int64)
    ib = fe[:, 9].astype(np.int64)

    we = np.fromiter(
        chain.from_iterable(chain.from_iterable(
            g.write_events for g in grids)),
        dtype=np.float64).reshape(-1, 4)
    w_cand = np.repeat(np.arange(n_grids, dtype=np.int64),
                       [len(g.write_events) for g in grids])
    w_core = we[:, 0].astype(np.int64)
    w_wave = we[:, 1].astype(np.int64)
    w_bytes = we[:, 2]
    w_il = we[:, 3].astype(np.int64)

    # Populations: distinct cores per (candidate, wave, level) over the A
    # and B fetch streams.  Keys are packed into one int64 (W bounds every
    # wave index, fetch and write alike, so write-side lookups share the
    # encoding).
    W = 1 + max(int(f_wave.max(initial=-1)), int(w_wave.max(initial=-1)))
    ka = (f_cand * W + f_wave) * L + ia
    kb = (f_cand * W + f_wave) * L + ib
    upairs = np.unique(np.concatenate([ka, kb]) * C
                       + np.concatenate([f_core, f_core]))
    uk, cnt = np.unique(upairs // C, return_counts=True)
    na = cnt[np.searchsorted(uk, ka)]
    nb = cnt[np.searchsorted(uk, kb)]

    ctf = ct[f_cand]
    secs = n_empty * ctf
    full = nfull * np.maximum(ctf, (fa * na / bw[ia]
                                    + fb * nb / bw[ib])
                              + hw.dma_fixed)
    secs = secs + np.where(nfull > 0, full, 0.0)
    rag = np.maximum(ctf, (fa_r * na / bw[ia]
                           + fb_r * nb / bw[ib]) + hw.dma_fixed)
    secs = secs + np.where((fa_r > 0) | (fb_r > 0), rag, 0.0)

    # Writes price at their wave's fetch population, default 1.
    kw = (w_cand * W + w_wave) * L + w_il
    pos = np.minimum(np.searchsorted(uk, kw), max(len(uk) - 1, 0))
    wn = np.where(uk[pos] == kw, cnt[pos], 1) if len(uk) else \
        np.ones(len(kw), dtype=np.int64)
    w_secs = w_bytes * wn / bw[w_il]

    core_time = np.bincount(
        np.concatenate([f_cand * C + f_core, w_cand * C + w_core]),
        weights=np.concatenate([secs, w_secs]),
        minlength=n_grids * C)
    end = launch + core_time.reshape(n_grids, C).max(axis=1)

    return [SimResult(time=float(end[i]), hbm_bytes=g.total_bytes,
                      mxu_busy=g.mxu_busy, steps=g.n_steps,
                      level_bytes=g.level_bytes,
                      units=g.units, waves=g.waves, cores=C)
            for i, g in enumerate(grids)]


def simulate_gemm_batch(p: GemmProblem, candidates: Sequence[TileConfig],
                        hw: HardwareSpec) -> List[SimResult]:
    """Simulate every candidate of one problem, batching the pricing pass
    (populations + per-core byte clocks) across the candidate axis.

    Bit-identical to ``[simulate_gemm(p, t, hw) for t in candidates]`` —
    placement (pass 1) is the same per-candidate code path as the scalar
    simulator; only pricing (pass 2) is stacked, and
    :func:`_price_multicore_batch` documents why that stacking is exact.
    The exhaustive-autotune oracle uses this to price a FULL candidate
    menu per shape without the compute-lower-bound pruning."""
    if hw.total_cores() == 1:
        return [_simulate_single_core(p, t, hw) for t in candidates]
    if not candidates:
        return []
    return _price_multicore_batch(
        [_place_multicore(p, t, hw) for t in candidates], hw)


# ---------------------------------------------------------------------------
# Virtual-device adapter (DESIGN.md §8).
#
# The calibration subsystem (repro.calib) probes a Device with three
# microbenchmark primitives — a strided stream, a resident compute loop, and
# a wave-occupancy grid — and fits Topology constants from the timings.  On
# real hardware those primitives are measured; in CI they run against these
# deterministic simulated implementations, which share the GEMM simulators'
# conventions (reuse-distance serving levels, static 1/C bandwidth and
# compute shares, per-fetch dma_fixed, kernel_launch + first-byte latency)
# so the fit pipeline can be validated end-to-end: the fitted topology must
# recover the planted constants.
# ---------------------------------------------------------------------------

def simulate_stream(hw: HardwareSpec, nbytes: float, window: int,
                    n_chunks: int = 64) -> float:
    """Seconds to stream ``nbytes`` cyclically through a working set of
    ``window`` bytes, issued as ``n_chunks`` DMA fetches.

    Serving-level rule shared with the GEMM simulators' measured
    reuse-distance placement: after the compulsory first pass (served from
    backing memory), every re-touch of the window has reuse distance ==
    ``window`` bytes, so it is served from the nearest level — staging
    included, a pure copy stream pins nothing else there — whose budget
    covers the window, else from backing memory."""
    backing = hw.backing
    serving = backing
    for lvl in reversed(hw.levels[1:]):       # innermost (staging) first
        if window <= lvl.budget():
            serving = lvl
            break
    first_pass = min(float(window), nbytes)
    return (hw.kernel_launch + hw.hbm_latency
            + first_pass / backing.bandwidth
            + (nbytes - first_pass) / serving.bandwidth
            + n_chunks * hw.dma_fixed)


def simulate_compute(hw: HardwareSpec, dtype: Optional[str],
                     n_atoms: int) -> float:
    """Seconds for ``n_atoms`` back-to-back MXU macro-atoms on resident
    operands (the issue-rate microbenchmark: no memory traffic).

    ``dtype`` falls back to the shared :func:`reference_dtype` rule — the
    same default its sibling :func:`simulate_wave` applies — so
    calibration probes run on bf16-less topologies instead of raising
    ``KeyError``."""
    if dtype is None:
        dtype = reference_dtype(hw.peak_flops)
    mm, mn, mk = hw.mxu_shape
    return hw.kernel_launch + n_atoms * (2.0 * mm * mn * mk) / hw.flops(dtype)


def simulate_wave(hw: HardwareSpec, n_units: int, unit_atoms: int,
                  dtype: Optional[str] = None) -> float:
    """Seconds for ``n_units`` identical compute-only work units scheduled
    round-robin over the chip's cores — the wave-latency microbenchmark.

    Each core gets the static 1/C share of the chip's peak (the same
    simplification ``_simulate_multicore`` and the closed-form occupancy
    stage apply), so the time staircase steps once per wave; the probe fits
    exactly that static-share slope plus ``kernel_launch`` as intercept.
    ``dtype`` defaults to the shared :func:`reference_dtype` rule, so
    bf16-less topologies probe their first known dtype instead of
    crashing."""
    if dtype is None:
        dtype = reference_dtype(hw.peak_flops)
    C = hw.total_cores()
    mm, mn, mk = hw.mxu_shape
    unit_s = unit_atoms * (2.0 * mm * mn * mk) * C / hw.flops(dtype)
    waves = cdiv(n_units, C)
    return hw.kernel_launch + waves * unit_s


def exhaustive_best(p: GemmProblem, hw: HardwareSpec,
                    candidates) -> Tuple[TileConfig, SimResult]:
    """The autotuner stand-in: simulate every candidate, return the argmin.

    An empty menu is a caller bug (a menu filter over-pruned, or a shape
    defeated every placement constraint) — raise a ``ValueError`` naming
    the problem shape instead of returning ``(None, None)`` and crashing
    the caller with an opaque unpack/attribute error downstream.  Ties
    keep the first candidate in menu order, matching the scalar loop this
    replaced."""
    candidates = list(candidates)
    if not candidates:
        raise ValueError(
            f"exhaustive_best: empty candidate list for GEMM "
            f"M={p.M} N={p.N} K={p.K} batch={p.batch} on {hw.name}")
    best_t, best_r = None, None
    for t, r in zip(candidates, simulate_gemm_batch(p, candidates, hw)):
        if best_r is None or r.time < best_r.time:
            best_t, best_r = t, r
    return best_t, best_r
