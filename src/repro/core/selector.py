"""Deterministic, zero-autotuning GEMM config selection (paper contribution #1).

``select_gemm_config`` enumerates the candidate tiling space — the same space
an autotuner would compile-and-benchmark — scores every candidate with the
closed-form latency model (O(1) each, so O(P) total), and returns the argmin.
Results are memoised exactly like the paper's cached selections (§V-B):
first call ~tens of µs, repeat calls ~1 µs.

The candidate space is TPU-shaped (DESIGN.md §2): block dims are MXU/lane
aligned, capped by the VMEM capacity filter (the paper's LDS filter), with
power-of-two sizes mirroring Triton's constraint noted in paper §V-C.
"""
from __future__ import annotations

import atexit
import hashlib
import itertools
import json
import os
import tempfile
import warnings
from dataclasses import dataclass
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.dtypes import ACC_BYTES, DTYPE_BYTES
from repro.core.hardware import TPU_V5E
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.core.topology import (SCHEDULES, HardwareSpec, _is_pow2,
                                 topology_fingerprint)
from repro.core.latency import (
    EPILOGUE_NONE,
    Epilogue,
    GemmProblem,
    LatencyBreakdown,
    ShapeBatch,
    TileConfig,
    _schedule_extra_arrays,
    cdiv,
    fits_placement,
    gemm_latency,
    gemm_latency_batch,
    grid_shape,
    memory_step_seconds_arrays,
    occupancy_arrays,
    overlap_pipeline_arrays,
    round_up,
    score_candidate,
    score_candidates,
    staging_working_set,
)
from repro.core.topology import (
    DEFAULT_BK_MENU as _BK_MENU,
    DEFAULT_BM_MENU as _BM_MENU,
    DEFAULT_BN_MENU as _BN_MENU,
    DEFAULT_GROUP_M_MENU as _GROUP_M_MENU,
    DEFAULT_SPLIT_K_MENU as _SPLIT_K_MENU,
)

# Candidate block-dimension menus are per-topology (Topology.*_menu; the
# defaults above are the TPU-shaped space): bn/bk live on the lane axis; bm
# may drop to the sublane granularity for skinny-M problems (padding waste
# would otherwise dominate — the paper's tile-quantization discussion, §V-C).
# GPU-shaped presets carry finer menus sized to KB-scale staging memory.


@dataclass(frozen=True)
class Selection:
    problem: GemmProblem
    config: TileConfig
    predicted: LatencyBreakdown
    hardware: str
    n_candidates: int
    # Content fingerprint of the topology the prediction was priced against
    # (``topology_fingerprint(hw)``).  Downstream consumers — the drift
    # monitor's JSONL rows, the residual corrector's training-set grouping —
    # key on this, never on ``hardware`` (a preset *name* survives
    # recalibration unchanged and can't be validated against the live
    # topology).  Empty only for hand-built Selections in old tests.
    topo_fingerprint: str = ""

    @property
    def predicted_tflops(self) -> float:
        return self.problem.flops / self.predicted.total / 1e12

    def __str__(self) -> str:
        p, c = self.problem, self.config
        return (f"[{p.M}x{p.N}x{p.K} {p.in_dtype}] -> {c} "
                f"({self.predicted.total*1e6:.1f}us, "
                f"{self.predicted_tflops:.1f} TF/s, "
                f"bound={self.predicted.bottleneck})")


def candidate_tiles(
    p: GemmProblem,
    hw: HardwareSpec = TPU_V5E,
    *,
    allow_split_k: bool = True,
    allow_grouping: bool = True,
) -> List[TileConfig]:
    """Enumerate the legal candidate space for one problem.

    Filters (in order):
      1. alignment — bm multiple of the dtype sublane, bn/bk of the lane width;
      2. usefulness — a block dim at most one menu step beyond the padded
         problem dim (bigger is pure padding waste);
      3. per-level capacity — the pipeline-buffered working set fits the
         budget of every placement level of the topology's chain (the
         paper's LDS filter; on TPU this is the seed's VMEM filter);
      4. model-equivalence pruning — on 1-level chains group_m only changes
         behaviour when the revisit model can trigger (Tk == 1); on
         multi-level chains grouped swizzle is priced via L2 residency, so
         it stays in the space for any Tk.  split_k only while the chip is
         under-occupied: the wave model prices sk>1 as pure combine cost
         once base tiles exceed ~2x total cores (on single-core chains the
         seed's fill/drain threshold of 16), keeping P near the paper's
         50-150.  stream_k enters only on multi-core chains with sk == 1
         (it subsumes split-K; on one core it is the identical twin of the
         sequential grid).

    NB: on a single-core chain (TPU) the in-kernel split-K moves no HBM
    partials and the model scores sk>1 as never better than its sk=1 twin —
    selection returns sk=1 there; on multi-core chains the Alg. 4 wave
    model restores split-K's occupancy rationale and it competes on merit
    (DESIGN.md §2-§3).
    """
    sub = hw.sublane(p.in_dtype)
    lane = hw.lane_width
    priced_grouping = bool(hw.cache_levels)
    n_cores = hw.total_cores()
    sk_gate = 16 if n_cores == 1 else max(16, 2 * n_cores)

    def useful(menu: Sequence[int], extent: int, align: int) -> List[int]:
        padded = round_up(extent, align)
        keep = [m for m in menu if m % align == 0]
        # smallest menu entry >= padded extent, plus everything below it
        cut = next((m for m in keep if m >= padded), keep[-1])
        return [m for m in keep if m <= cut]

    bms = useful(hw.bm_menu, p.M, sub)
    bns = useful(hw.bn_menu, p.N, lane)
    bks = useful(hw.bk_menu, p.K, lane)
    sks = hw.split_k_menu if allow_split_k else (1,)
    gms = hw.group_m_menu if allow_grouping else (1,)

    out: List[TileConfig] = []
    for bm, bn, bk in itertools.product(bms, bns, bks):
        base_tiles = cdiv(p.M, bm) * cdiv(p.N, bn) * p.batch
        tk = cdiv(p.K, bk)
        for sk in sks:
            if sk > 1 and (cdiv(p.K, sk) < bk or base_tiles >= sk_gate):
                continue                  # split finer than a block / no need
            for gm in gms:
                if gm > 1 and cdiv(p.M, bm) < 2:
                    continue              # nothing to group
                if gm > 1 and tk != 1 and not priced_grouping:
                    continue              # revisit can't trigger -> identical
                for sched in hw.schedule_menu:
                    if sched == "stream_k" and (n_cores == 1 or sk > 1):
                        continue          # identical twin / subsumed
                    t = TileConfig(bm=bm, bn=bn, bk=bk, split_k=sk,
                                   group_m=gm, schedule=sched)
                    if not fits_placement(t, p.in_dtype, hw):
                        continue
                    out.append(t)
    return out


_GRID_CACHE: Dict[Tuple, Tuple[np.ndarray, ...]] = {}


def _grid_identity(hw: HardwareSpec) -> Tuple:
    """The Topology fields the cached menu grid bakes in.  Keying on these
    (not just hw.name) keeps same-named ``with_calibration`` retargets from
    reusing a stale candidate filter; MemoryLevel is frozen so the levels
    tuple hashes."""
    return (hw.name, hw.levels, hw.bm_menu, hw.bn_menu, hw.bk_menu,
            hw.split_k_menu, hw.group_m_menu, hw.schedule_menu,
            hw.partitions, hw.core_count, hw.pipeline_depth,
            hw.lane_width, hw.sublane_f32)


def _menu_grid(hw: HardwareSpec, in_dtype: str) -> Tuple[np.ndarray, ...]:
    """Static part of the candidate space for (hardware, dtype): the full
    lexicographic (bm, bn, bk, sk, gm, sched) menu grid plus the
    problem-independent alignment + per-level-capacity + schedule keep-mask.
    Cached — cold selection only pays for the problem-dependent masks and
    the scoring pass."""
    key = (_grid_identity(hw), in_dtype)
    hit = _GRID_CACHE.get(key)
    if hit is not None:
        return hit
    sched_codes = np.asarray([SCHEDULES.index(s) for s in hw.schedule_menu],
                             np.int64)
    bm, bn, bk, sk, gm, sched = (g.ravel() for g in np.meshgrid(
        np.asarray(hw.bm_menu, np.int64), np.asarray(hw.bn_menu, np.int64),
        np.asarray(hw.bk_menu, np.int64),
        np.asarray(hw.split_k_menu, np.int64),
        np.asarray(hw.group_m_menu, np.int64),
        sched_codes, indexing="ij"))
    sub, lane = hw.sublane(in_dtype), hw.lane_width
    bi = DTYPE_BYTES[in_dtype]
    static_keep = (bm % sub == 0) & (bn % lane == 0) & (bk % lane == 0)
    # Per-level capacity filter (vectorized fits_placement).
    acc = bm * bn * ACC_BYTES if hw.staging.holds_accumulator else 0
    working_set = hw.pipeline_depth * (bm * bk + bk * bn) * bi + acc
    for lvl in hw.placement_levels():
        static_keep &= working_set <= lvl.budget()
    # stream_k: multi-core chains only, and only with sk == 1 (it subsumes
    # split-K); on one core it is the identical twin of the sequential grid.
    stream = sched == SCHEDULES.index("stream_k")
    static_keep &= ~(stream & ((sk > 1) | (hw.total_cores() == 1)))
    # All menu entries are powers of two: ceil-divs become shifts, and the
    # split-K / grouping gate masks are grid-static (int64 floordiv is the
    # single most expensive numpy op on the cold path).
    shifts = tuple(np.log2(c).astype(np.int64) for c in (bm, bn, bk, sk))
    masks = (sk > 1, gm > 1, gm <= 1)
    out = (bm, bn, bk, sk, gm, sched, static_keep, shifts, masks)
    _GRID_CACHE[key] = out
    return out


def _menu_cut(menu: Sequence[int], extent: int, align: int) -> int:
    """Largest useful menu entry: the smallest aligned entry >= the padded
    extent (anything above is pure padding waste) — ``useful``'s cut."""
    padded = round_up(extent, align)
    keep = [m for m in menu if m % align == 0]
    return next((m for m in keep if m >= padded), keep[-1])


def _keep_mask(p: GemmProblem, hw: HardwareSpec, allow_split_k: bool,
               allow_grouping: bool) -> np.ndarray:
    """Problem-dependent candidate filter over the full menu grid —
    candidate_tiles' usefulness / split-K / grouping rules, vectorized."""
    (bm, bn, bk, sk, gm, sched, static_keep,
     (bm_sh, bn_sh, bk_sh, sk_sh), (sk_gt1, gm_gt1, _)) = \
        _menu_grid(hw, p.in_dtype)
    sub = hw.sublane(p.in_dtype)
    lane = hw.lane_width
    n_cores = hw.total_cores()
    sk_gate = 16 if n_cores == 1 else max(16, 2 * n_cores)

    keep = static_keep \
        & (bm <= _menu_cut(hw.bm_menu, p.M, sub)) \
        & (bn <= _menu_cut(hw.bn_menu, p.N, lane)) \
        & (bk <= _menu_cut(hw.bk_menu, p.K, lane))
    if not allow_split_k:
        keep = keep & ~sk_gt1
    if not allow_grouping:
        keep = keep & ~gm_gt1

    Tm = (p.M - 1 + bm) >> bm_sh                       # cdiv via shift
    Tn = (p.N - 1 + bn) >> bn_sh
    keep = keep & ~(sk_gt1 & ((((p.K - 1 + sk) >> sk_sh) < bk)
                              | (Tm * Tn * p.batch >= sk_gate)))
    if hw.cache_levels:
        # grouped swizzle is priced (L2 residency) -> keep for any Tk
        keep = keep & ~(gm_gt1 & (Tm < 2))
    else:
        keep = keep & ~(gm_gt1 & ((((p.K - 1 + bk) >> bk_sh) != 1)
                                  | (Tm < 2)))
    return keep


def candidate_arrays(
    p: GemmProblem,
    hw: HardwareSpec = TPU_V5E,
    *,
    allow_split_k: bool = True,
    allow_grouping: bool = True,
) -> Tuple[np.ndarray, ...]:
    """``candidate_tiles`` fully vectorized: returns (bm, bn, bk, split_k,
    group_m, schedule) int64 column arrays (schedule as ``SCHEDULES``
    indices) with the SAME filters and the SAME enumeration order, without
    materializing TileConfig objects — the cold selection path builds only
    the winning config."""
    bm, bn, bk, sk, gm, sched = _menu_grid(hw, p.in_dtype)[:6]
    keep = _keep_mask(p, hw, allow_split_k, allow_grouping)
    return bm[keep], bn[keep], bk[keep], sk[keep], gm[keep], sched[keep]


_STATIC_TERMS: Dict[Tuple, Tuple[np.ndarray, ...]] = {}


def _static_score_terms(hw: HardwareSpec, in_dtype: str,
                        out_dtype: str) -> Tuple[np.ndarray, ...]:
    """Score terms over the full menu grid that don't depend on the problem
    shape: MXU step seconds, the VMEM-port step seconds base, bm*bn, and the
    launch+prologue+epilogue fill/drain seconds.  Cached per (hardware,
    dtypes) — the cold path computes only shape-dependent terms."""
    key = (_grid_identity(hw), in_dtype, out_dtype,
           hw.mxu_shape, hw.flops(in_dtype), hw.kernel_launch)
    hit = _STATIC_TERMS.get(key)
    if hit is not None:
        return hit
    bm, bn, bk = _menu_grid(hw, in_dtype)[:3]
    bi, bo = DTYPE_BYTES[in_dtype], DTYPE_BYTES[out_dtype]
    mm, mn, mk = hw.mxu_shape
    n_atoms = (-(-bm // mm)) * (-(-bn // mn)) * (-(-bk // mk))
    mxu_s = n_atoms * (2.0 * mm * mn * mk) / hw.flops(in_dtype)
    ab_bi = (bm * bk + bk * bn) * bi
    bmn = bm * bn
    vmem_base_s = (ab_bi + 2.0 * ACC_BYTES * bmn) / hw.vmem_bandwidth
    fill_drain = (hw.kernel_launch + 2 * hw.hbm_latency
                  + ab_bi / hw.hbm_bandwidth + bmn * bo / hw.hbm_bandwidth)
    vols = bmn * bk
    out = (mxu_s, vmem_base_s, bmn, fill_drain, vols)
    _STATIC_TERMS[key] = out
    return out


def select_fast(p: GemmProblem, hw: HardwareSpec, *,
                allow_split_k: bool = True,
                allow_grouping: bool = True) -> Tuple[TileConfig, int]:
    """The fully-vectorized cold selection: one numpy pass over the menu grid
    (static terms cached) -> (winning TileConfig, n_candidates).  Same
    model arithmetic as ``score_candidate`` and the same argmin/tie-break as
    the sequential scoring loop.

    NB: the scoring formula is deliberately inlined here (third copy, after
    ``score_candidate`` and ``score_candidate_arrays``) so the static
    per-(hw, dtypes) terms and shift-based ceil-divs can be cached — a model
    change must touch all three; ``tests/test_selector.py`` pins their
    pairwise parity."""
    (bm, bn, bk, sk, gm, sched, _,
     (bm_sh, bn_sh, bk_sh, sk_sh), (_, gm_gt1, gm_le1)) = \
        _menu_grid(hw, p.in_dtype)
    mxu_s, vmem_base_s, bmn, fill_drain, vols = _static_score_terms(
        hw, p.in_dtype, p.out_dtype)
    keep = _keep_mask(p, hw, allow_split_k, allow_grouping)
    n_cands = int(np.count_nonzero(keep))
    if n_cands == 0:
        raise ValueError(f"empty candidate space for {p} on {hw.name}")

    bi, bo = DTYPE_BYTES[p.in_dtype], DTYPE_BYTES[p.out_dtype]
    Tm = (p.M - 1 + bm) >> bm_sh                       # cdiv via shift
    Tn = (p.N - 1 + bn) >> bn_sh
    k_per_split = (p.K - 1 + sk) >> sk_sh
    Tk = ((k_per_split - 1 + bk) >> bk_sh) << sk_sh
    steps = Tm * Tn * Tk * p.batch

    ep = p.epilogue
    if ep.is_identity:
        vmem_s = vmem_base_s
        ce_bytes = float(p.M * p.N * bo)
    else:
        vmem_s = vmem_base_s + (ep.n_mn_operands * bmn
                                + int(ep.bias) * bn) * bi / Tk \
            / hw.vmem_bandwidth
        ce_bytes = float(p.M * p.N * bo
                         + (ep.n_mn_operands * p.M * p.N
                            + int(ep.bias) * p.N) * bi)

    rev = hw.total_cores() == 1
    tk1 = (Tk == 1) if rev else np.zeros(np.shape(Tk), bool)
    a_skip = (tk1 & gm_le1) * ((Tn - 1) / Tn)
    g = np.minimum(gm, Tm)
    b_skip = (tk1 & gm_gt1) * ((g - 1) / g)
    a_bytes = Tn * float(p.M * p.K * bi) * (1.0 - a_skip)
    b_bytes = Tm * float(p.K * p.N * bi) * (1.0 - b_skip)
    traffic = p.batch * (a_bytes + b_bytes + ce_bytes)

    occ = occupancy_arrays(p, hw, Tm, Tn, sk, sched, steps)
    if hw.total_cores() > 1:
        # Max-plus overlap steady state + flush cursor (multi-core chains).
        extra = _schedule_extra_arrays(p, hw, Tm, Tn, Tk, bm, bn, sk, sched)
        body, flush = overlap_pipeline_arrays(
            p, hw, Tm, Tn, bm, bn, gm, steps,
            np.maximum(mxu_s, vmem_s) * occ, hw.dma_fixed * occ,
            p.batch * a_bytes, p.batch * b_bytes, p.batch * ce_bytes, extra)
        scores = np.where(keep, fill_drain + body + flush, np.inf)
    else:
        mem_s = memory_step_seconds_arrays(p, hw, traffic, Tm, Tn, Tk,
                                           bm, bn, gm, steps,
                                           sk=sk, sched=sched)
        l_iter = np.maximum(np.maximum(mxu_s, vmem_s) * occ,
                            mem_s + hw.dma_fixed * occ)
        scores = np.where(keep, fill_drain + steps * l_iter, np.inf)
    idx = np.flatnonzero(scores <= scores.min() + 1e-15)
    i = int(idx[np.argmax(vols[idx])])
    return TileConfig(bm=int(bm[i]), bn=int(bn[i]), bk=int(bk[i]),
                      split_k=int(sk[i]), group_m=int(gm[i]),
                      schedule=SCHEDULES[int(sched[i])]), n_cands


_ALIGNED_MENUS: Dict[Tuple[Tuple[int, ...], int], Optional[np.ndarray]] = {}
_PRUNED_COLS: Dict[Tuple, Tuple[np.ndarray, ...]] = {}


def _cut_col(menu: Sequence[int], ext: np.ndarray, align: int) -> np.ndarray:
    """Vectorized ``_menu_cut`` over an (S, 1) extent column: binary-search
    the (cached) aligned ascending menu for the smallest entry >= the padded
    extent, clamping to the largest.  Falls back to the scalar scan when the
    aligned menu is not strictly ascending (the scan is order-sensitive)."""
    mkey = (tuple(menu), align)
    arr = _ALIGNED_MENUS.get(mkey, False)
    if arr is False:
        a = np.asarray([m for m in menu if m % align == 0], np.int64)
        arr = a if a.size > 1 and bool((a[1:] > a[:-1]).all()) else None
        _ALIGNED_MENUS[mkey] = arr
    if arr is not None:
        padded = (-(-ext // align)) * align
        i = np.minimum(np.searchsorted(arr, padded[:, 0]), arr.size - 1)
        return arr[i][:, None]
    return np.asarray([_menu_cut(menu, int(e), align)
                       for e in ext[:, 0]], np.int64)[:, None]


def select_fast_batch(problems: Sequence[GemmProblem], hw: HardwareSpec, *,
                      allow_split_k: bool = True,
                      allow_grouping: bool = True,
                      ) -> List[Tuple[TileConfig, int]]:
    """``select_fast`` for S problems in ONE numpy pass: the shapes stack as
    an (S, 1) column axis against the cached (P,) menu grid, so every model
    expression broadcasts to (S, P) and the whole sweep costs one scoring
    pass instead of S.  Problems must share dtypes and epilogue (the grid
    and the static score terms are per-(hw, dtypes)).

    Per-row results are BIT-IDENTICAL to S scalar ``select_fast`` calls:
    the int64 -> float64 casts are exact (products < 2**53) and every
    elementwise op runs in the same IEEE order as the scalar path —
    ``tests/test_batch_selection.py`` pins config + hex-exact latency
    parity.  Same argmin/volume tie-break, applied per row."""
    if not problems:
        return []
    pb = ShapeBatch.from_problems(problems)
    (bm, bn, bk, sk, gm, sched, static_keep,
     (bm_sh, bn_sh, bk_sh, sk_sh), (sk_gt1, gm_gt1, gm_le1)) = \
        _menu_grid(hw, pb.in_dtype)
    mxu_s, vmem_base_s, bmn, fill_drain, vols = _static_score_terms(
        hw, pb.in_dtype, pb.out_dtype)
    M, N, K, batch = pb.M, pb.N, pb.K, pb.batch      # (S, 1) int64 columns

    # _keep_mask, broadcast: per-row menu cuts + the split-K/grouping gates.
    sub = hw.sublane(pb.in_dtype)
    lane = hw.lane_width
    n_cores = hw.total_cores()
    sk_gate = 16 if n_cores == 1 else max(16, 2 * n_cores)
    cut_m, cut_n, cut_k = (_cut_col(hw.bm_menu, M, sub),
                           _cut_col(hw.bn_menu, N, lane),
                           _cut_col(hw.bk_menu, K, lane))
    # Column prune: a candidate failing the static mask or the UNION of the
    # per-row cuts (or a disabled sk/gm axis) has keep == False for every
    # row — drop it before broadcasting so the (S, P') temporaries stay
    # small.  Order-preserving compression: per-row counts, scores and the
    # first-max tie-break are untouched.  Cached per cut-maxima triple (a
    # handful of values — cuts are menu entries), so steady-state batches
    # skip the 15 gather passes.
    ckey = (_grid_identity(hw), pb.in_dtype, pb.out_dtype, hw.mxu_shape,
            hw.flops(pb.in_dtype), hw.kernel_launch,
            int(cut_m.max()), int(cut_n.max()), int(cut_k.max()),
            allow_split_k, allow_grouping)
    hit = _PRUNED_COLS.get(ckey)
    if hit is None:
        cols = static_keep & (bm <= ckey[6]) & (bn <= ckey[7]) \
            & (bk <= ckey[8])
        if not allow_split_k:
            cols = cols & ~sk_gt1
        if not allow_grouping:
            cols = cols & ~gm_gt1
        hit = tuple(a[cols] for a in (
            bm, bn, bk, sk, gm, sched, sk_gt1, gm_gt1, gm_le1,
            bm_sh, bn_sh, bk_sh, sk_sh, mxu_s, bmn, fill_drain, vols,
            vmem_base_s))
        _PRUNED_COLS[ckey] = hit
    (bm, bn, bk, sk, gm, sched, sk_gt1, gm_gt1, gm_le1,
     bm_sh, bn_sh, bk_sh, sk_sh, mxu_s, bmn, fill_drain, vols,
     vmem_base_s) = hit
    # The split-K / grouping gates usually resolve from row-scalar bounds:
    # every column has Tm >= cdiv(M, cut_m), so tiles_min >= sk_gate kills
    # ALL sk>1 columns of the row at once, and K > cut_k forces Tk != 1 for
    # every column (the no-cache chains' grouping gate).  A row the bounds
    # fully decide depends only on its (cut_m, cut_n, cut_k) triple, so rows
    # sharing a triple share ONE keep row; only "fine" rows — where a gate
    # needs the elementwise test — key on their full shape.  Each distinct
    # row is computed once on cheap 1-D (P',) columns; the formulas are the
    # scalar ``_keep_mask`` gates verbatim, so keep matches row for row.
    S = M.shape[0]
    sk_any, gm_any = bool(sk_gt1.any()), bool(gm_gt1.any())
    tiles_min = (-(-M // cut_m)) * (-(-N // cut_n)) * batch       # (S, 1)
    sk_kill = ((tiles_min >= sk_gate)[:, 0] if sk_any
               else np.ones(S, bool))
    if gm_any:
        gm_fine = ((M <= cut_m) if hw.cache_levels else (K <= cut_k))[:, 0]
    else:
        gm_fine = np.zeros(S, bool)
    fine = (~sk_kill) | gm_fine
    cm, cn, ck = cut_m[:, 0], cut_n[:, 0], cut_k[:, 0]
    groups: Dict[Tuple, int] = {}
    uidx: List[int] = []
    inv = np.empty(S, np.intp)
    for r in range(S):
        gk = ((int(cm[r]), int(cn[r]), int(ck[r]), int(M[r, 0]),
               int(N[r, 0]), int(K[r, 0]), int(batch[r, 0]))
              if fine[r] else (int(cm[r]), int(cn[r]), int(ck[r])))
        gi = groups.get(gk)
        if gi is None:
            gi = groups[gk] = len(uidx)
            uidx.append(r)
        inv[r] = gi
    keepg = np.empty((len(uidx), bm.size), bool)
    base_rows: Dict[Tuple[int, int, int], np.ndarray] = {}

    def _base(r: int) -> np.ndarray:
        tk_ = (int(cm[r]), int(cn[r]), int(ck[r]))
        b = base_rows.get(tk_)
        if b is None:
            b = base_rows[tk_] = (bm <= tk_[0]) & (bn <= tk_[1]) \
                & (bk <= tk_[2])
        return b

    # Coarse rows (bounds fully decide the gates) all share one extra mask.
    coarse_extra: Optional[np.ndarray] = None
    fidx: List[Tuple[int, int]] = []
    for gi, r in enumerate(uidx):
        r = int(r)
        if fine[r]:
            fidx.append((gi, r))
            continue
        row = _base(r)
        if sk_any or (gm_any and not hw.cache_levels):
            if coarse_extra is None:
                coarse_extra = np.ones(bm.size, bool)
                if sk_any:
                    coarse_extra &= ~sk_gt1
                if gm_any and not hw.cache_levels:
                    coarse_extra &= ~gm_gt1          # K > cut_k => Tk != 1
            row = row & coarse_extra
        keepg[gi] = row
    if fidx:
        # Fine rows: the elementwise gates run as ONE (F, P') broadcast —
        # same formulas as scalar ``_keep_mask``, selected per row by
        # np.where, so each row's booleans match the scalar branch taken.
        gis = [g for g, _ in fidx]
        rs = [r for _, r in fidx]
        rows = np.stack([_base(r) for r in rs])          # (F, P')
        Mf, Nf, Kf, Bf = M[rs, :], N[rs, :], K[rs, :], batch[rs, :]
        Tmr = (Mf - 1 + bm) >> bm_sh
        if sk_any:
            Tnr = (Nf - 1 + bn) >> bn_sh
            gate = sk_gt1 & ((((Kf - 1 + sk) >> sk_sh) < bk)
                             | (Tmr * Tnr * Bf >= sk_gate))
            rows &= ~np.where(sk_kill[rs][:, None], sk_gt1, gate)
        if gm_any:
            gmf = gm_fine[rs][:, None]
            if hw.cache_levels:
                # gate kills only when Tm < 2 — needs M <= cut_m to fire
                rows &= ~(gm_gt1 & (Tmr < 2) & gmf)
            else:
                gate = gm_gt1 & ((((Kf - 1 + bk) >> bk_sh) != 1)
                                 | (Tmr < 2))
                rows &= np.where(gmf, ~gate, ~gm_gt1)
        keepg[gis] = rows
    n_cands = np.count_nonzero(keepg, axis=1)[inv]
    if not n_cands.all():
        bad = problems[int(np.flatnonzero(n_cands == 0)[0])]
        raise ValueError(f"empty candidate space for {bad} on {hw.name}")

    # Second compression: score only columns some row keeps.  For large
    # shapes the split-K / grouping gates kill most of the grid for EVERY
    # row, so the expensive float64 scoring runs on a fraction of P.
    live = keepg.any(axis=0)
    if not live.all():
        bm, bn, bk, sk, gm, sched = (a[live] for a in
                                     (bm, bn, bk, sk, gm, sched))
        bm_sh, bn_sh, bk_sh, sk_sh = (a[live] for a in
                                      (bm_sh, bn_sh, bk_sh, sk_sh))
        gm_gt1, gm_le1 = gm_gt1[live], gm_le1[live]
        mxu_s, bmn, fill_drain, vols = (a[live] for a in
                                        (mxu_s, bmn, fill_drain, vols))
        vmem_base_s = vmem_base_s[live]
        keepg = keepg[:, live]
    keep = keepg[inv]                                     # (S, P_live)

    bi, bo = DTYPE_BYTES[pb.in_dtype], DTYPE_BYTES[pb.out_dtype]
    Tm = (M - 1 + bm) >> bm_sh                  # (S, P_live) cdiv via shift
    Tn = (N - 1 + bn) >> bn_sh
    if bool((sk == 1).all()):
        Tk = (K - 1 + bk) >> bk_sh       # sk == 1: split round-trip is id
    else:
        k_per_split = (K - 1 + sk) >> sk_sh
        Tk = ((k_per_split - 1 + bk) >> bk_sh) << sk_sh
    steps = Tm * Tn * Tk * batch

    ep = pb.epilogue
    if ep.is_identity:
        vmem_s = vmem_base_s
        ce_bytes = np.asarray(M * N * bo, np.float64)
    else:
        vmem_s = vmem_base_s + (ep.n_mn_operands * bmn
                                + int(ep.bias) * bn) * bi / Tk \
            / hw.vmem_bandwidth
        ce_bytes = np.asarray(M * N * bo
                              + (ep.n_mn_operands * M * N
                                 + int(ep.bias) * N) * bi, np.float64)

    MKbi = np.asarray(M * K * bi, np.float64)
    KNbi = np.asarray(K * N * bi, np.float64)
    tk1 = (Tk == 1) if n_cores == 1 else None
    if tk1 is not None and bool(tk1.any()):
        a_skip = (tk1 & gm_le1) * ((Tn - 1) / Tn)
        g = np.minimum(gm, Tm)
        b_skip = (tk1 & gm_gt1) * ((g - 1) / g)
        a_bytes = Tn * MKbi * (1.0 - a_skip)
        b_bytes = Tm * KNbi * (1.0 - b_skip)
    else:                       # skips all 0.0: x * (1.0 - 0.0) == x, elide
        a_bytes = Tn * MKbi
        b_bytes = Tm * KNbi
    traffic = batch * (a_bytes + b_bytes + ce_bytes)

    occ = occupancy_arrays(pb, hw, Tm, Tn, sk, sched, steps)
    if isinstance(occ, float):              # single-core chains: occ == 1.0
        mem_s = memory_step_seconds_arrays(pb, hw, traffic, Tm, Tn, Tk,
                                           bm, bn, gm, steps,
                                           sk=sk, sched=sched)
        l_iter = np.maximum(np.maximum(mxu_s, vmem_s),
                            mem_s + hw.dma_fixed)
        scores = np.where(keep, fill_drain + steps * l_iter, np.inf)
    else:
        # Max-plus overlap steady state + flush cursor (multi-core chains).
        extra = _schedule_extra_arrays(pb, hw, Tm, Tn, Tk, bm, bn, sk, sched)
        body, flush = overlap_pipeline_arrays(
            pb, hw, Tm, Tn, bm, bn, gm, steps,
            np.maximum(mxu_s, vmem_s) * occ, hw.dma_fixed * occ,
            batch * a_bytes, batch * b_bytes, batch * ce_bytes, extra)
        scores = np.where(keep, fill_drain + body + flush, np.inf)
    # Per-row argmin + volume tie-break: argmax returns the FIRST max, which
    # is exactly the scalar path's earliest-in-enumeration-order policy.
    smin = scores.min(axis=1, keepdims=True)
    elig = scores <= smin + 1e-15
    picks = np.argmax(np.where(elig, vols, -1), axis=1)
    return [(TileConfig(bm=a, bn=b, bk=c, split_k=d, group_m=e,
                        schedule=SCHEDULES[f]), n)
            for a, b, c, d, e, f, n in zip(
                bm[picks].tolist(), bn[picks].tolist(), bk[picks].tolist(),
                sk[picks].tolist(), gm[picks].tolist(),
                sched[picks].tolist(), n_cands.tolist())]


def rank_candidates(
    p: GemmProblem,
    hw: HardwareSpec = TPU_V5E,
    **kwargs,
) -> List[Tuple[TileConfig, LatencyBreakdown]]:
    """Score the whole space, best first. Deterministic tie-break: prefer the
    larger block (less issue overhead), then lexicographic config order."""
    cands = candidate_tiles(p, hw, **kwargs)
    scored = [(t, gemm_latency(p, t, hw)) for t in cands]
    scored.sort(key=lambda it: (it[1].total,
                                -(it[0].bm * it[0].bn * it[0].bk),
                                it[0].bm, it[0].bn, it[0].bk,
                                it[0].split_k, it[0].group_m,
                                it[0].schedule))
    return scored


# ---------------------------------------------------------------------------
# Fail-soft selection validation + fallback ladder (DESIGN.md §9).
#
# A Selection reaching a kernel launch may be wrong in ways the happy path
# never produces: a corrupted cache entry rehydrated into nonsense dims, a
# memo poisoned by a buggy hook, a config whose placement no longer fits a
# recalibrated topology.  ``validate_selection`` re-checks the invariants
# the selector guarantees by construction; ``fallback_ladder`` yields the
# deterministic downgrade sequence the launch layer (kernels/ops.py) walks
# when a validated config still fails to compile or launch.
# ---------------------------------------------------------------------------


def validate_selection(p: GemmProblem, t: TileConfig,
                       hw: HardwareSpec) -> Optional[str]:
    """Re-validate a config against the invariants every selector-produced
    candidate satisfies by construction.  Returns a reason string when the
    config must not be launched, None when it is safe."""
    for name, v in (("bm", t.bm), ("bn", t.bn), ("bk", t.bk),
                    ("split_k", t.split_k), ("group_m", t.group_m)):
        if not isinstance(v, int) or not _is_pow2(v):
            return f"{name}={v!r} is not a positive power of two"
    if t.schedule not in SCHEDULES:
        return f"schedule {t.schedule!r} not in {SCHEDULES}"
    if t.bm % hw.sublane(p.in_dtype) or t.bn % hw.lane_width \
            or t.bk % hw.lane_width:
        return (f"{t} misaligned for {p.in_dtype} on {hw.name} "
                f"(sublane {hw.sublane(p.in_dtype)}, lane {hw.lane_width})")
    if not fits_placement(t, p.in_dtype, hw):
        return f"{t} exceeds a placement-level budget on {hw.name}"
    lat = gemm_latency(p, t, hw)
    if not np.isfinite(lat.total) or lat.total <= 0.0:
        return f"{t} prices to a non-finite/non-positive latency on {hw.name}"
    return None


def safe_config(p: GemmProblem, hw: HardwareSpec = TPU_V5E) -> TileConfig:
    """The conservative rung of the fallback ladder: the smallest aligned
    entry of every menu, no split-K, no grouping, the sequential schedule —
    the minimum-working-set config, guaranteed to fit placement whenever
    *any* candidate does."""
    sub, lane = hw.sublane(p.in_dtype), hw.lane_width
    bm = min((m for m in hw.bm_menu if m % sub == 0), default=sub)
    bn = min((m for m in hw.bn_menu if m % lane == 0), default=lane)
    bk = min((m for m in hw.bk_menu if m % lane == 0), default=lane)
    return TileConfig(bm=bm, bn=bn, bk=bk, split_k=1, group_m=1,
                      schedule="data_parallel")


def fallback_ladder(p: GemmProblem, hw: HardwareSpec,
                    primary: TileConfig,
                    ) -> Iterator[Tuple["Selection", str]]:
    """The deterministic downgrade sequence after ``primary`` failed to
    validate or launch: the next-ranked candidate under the model, then
    the conservative :func:`safe_config`.  (The final reference-kernel
    rung is the launch layer's, not a TileConfig.)  Lazily ranks the
    space — the happy path never pays for it."""
    def _sel(t: TileConfig, n: int) -> "Selection":
        return Selection(problem=p, config=t,
                         predicted=gemm_latency(p, t, hw),
                         hardware=hw.name, n_candidates=n,
                         topo_fingerprint=topology_fingerprint(hw))

    tried = [primary]
    ranked = rank_candidates(p, hw)
    nxt = next((t for t, _ in ranked if t not in tried), None)
    if nxt is not None:
        tried.append(nxt)
        yield _sel(nxt, len(ranked)), "next"
    safe = safe_config(p, hw)
    if safe not in tried:
        yield _sel(safe, len(ranked)), "safe"


_CACHE: Dict[Tuple, Selection] = {}

# ---------------------------------------------------------------------------
# Persistent on-disk selection table.  When REPRO_SELECTION_CACHE names a
# JSON file (or load_selection_cache is called with a path), selections
# survive process boundaries: a warm-started server pays zero cold-path
# scoring for every shape any previous process already selected.  Entries
# store only the winning config — rehydration reprices it with the O(1)
# closed-form model, so a stale file can never smuggle in a stale latency.
# ---------------------------------------------------------------------------

_DISK_ENV = "REPRO_SELECTION_CACHE"
_disk_table: Optional[Dict[str, Dict]] = None
_disk_path: Optional[str] = None


def _key_str(key: Tuple) -> str:
    """Deterministic JSON key for a selection cache key (repr is stable:
    ints, strs, bools and the frozen Epilogue dataclass)."""
    return repr(key)


# Persisted with each disk entry so a recalibrated same-name topology
# invalidates the old selections instead of warm-starting from them.  The
# fingerprint function itself lives in core/topology.py (the calibration
# subsystem stamps it into calibrated-topology artifacts); this alias is
# the historical in-module name.
_topo_fingerprint = topology_fingerprint


# ---------------------------------------------------------------------------
# Selection observability hooks (calibration subsystem, DESIGN.md §8).
#
# The oracle/fidelity harness and the calibration tests need to observe
# *where* each selection came from — fresh cold scoring ("cold"), the
# persistent disk table ("disk"), the in-process memo ("memo"), or a
# fail-soft ladder step ("fallback:<rung>", kernels/ops.py) — to prove
# end-to-end that e.g. a recalibrated topology really re-scored instead
# of warm-starting stale configs, and that every degraded launch is
# observable.  A hook that raises is logged and skipped: observability
# must never abort selection (DESIGN.md §9).
# ---------------------------------------------------------------------------

_SELECTION_HOOKS: List[Callable[["Selection", str], None]] = []


def add_selection_hook(fn: Callable[["Selection", str], None]) -> None:
    """Register ``fn(selection, source)``; source in {memo, disk, cold}
    or ``fallback:<rung>`` for fail-soft ladder steps."""
    _SELECTION_HOOKS.append(fn)


def remove_selection_hook(fn: Callable[["Selection", str], None]) -> None:
    _SELECTION_HOOKS.remove(fn)


def _emit_selection(sel: "Selection", source: str) -> None:
    # Telemetry first (DESIGN.md §11): one gated counter per source and —
    # when a tracer is installed — the full selection record as a trace
    # event, including the winning LatencyBreakdown's per-level views.
    obs_metrics.inc("selections_total", labels={"source": source})
    if obs_trace.tracing_enabled():
        p, c, bd = sel.problem, sel.config, sel.predicted
        obs_trace.event(
            "select_gemm_config", cat="selection", track="selection",
            args={"source": source,
                  "shape": [p.M, p.N, p.K, p.batch],
                  "dtype": p.in_dtype,
                  "config": {"bm": c.bm, "bn": c.bn, "bk": c.bk,
                             "split_k": c.split_k, "group_m": c.group_m,
                             "schedule": c.schedule},
                  "n_candidates": sel.n_candidates,
                  "predicted_s": bd.total,
                  "bottleneck": bd.bottleneck,
                  "level_bytes": dict(bd.level_bytes),
                  "level_seconds": dict(bd.level_seconds)})
    for fn in list(_SELECTION_HOOKS):
        try:
            fn(sel, source)
        except Exception as e:                      # noqa: BLE001
            hook_name = getattr(fn, "__name__", str(fn))
            obs_metrics.inc("selection_hook_errors",
                            labels={"hook": hook_name})
            warnings.warn(
                f"selection hook {hook_name!r} raised "
                f"{e!r} on source {source!r}; hook skipped",
                RuntimeWarning, stacklevel=2)


def emit_fallback(sel: "Selection", rung: str) -> None:
    """Report a fail-soft ladder step (``kernels/ops.py``) through the
    selection hooks as source ``fallback:<rung>``; rung in
    {next, safe, reference}."""
    _emit_selection(sel, f"fallback:{rung}")


# ---------------------------------------------------------------------------
# Learned residual corrector — opt-in post-ranking stage (DESIGN.md §12).
#
# The analytical model stays the interpretable prior: with no corrector
# installed every code path above/below is untouched and selections are
# bit-identical.  With one installed (``repro.calib.residual`` fits it on
# the drift stream; core only duck-types it), the scalar path re-prices the
# top-F analytically-ranked candidates with ``corrector.correct(...)`` and
# takes the argmin over the corrected totals — the vectorized menu pass
# still does ALL the enumeration/filter/ranking work, the corrector touches
# F ≈ 8 finalists.  Residual selections memoise under a separate namespace
# (keyed by the corrector's own content fingerprint) and NEVER touch the
# persistent disk table, so analytical warm-starts can't be poisoned by a
# since-retired corrector.  A corrector whose topology fingerprint does not
# match the live topology is ignored (counted metric), exactly like a
# stale calibrated-topology artifact.
# ---------------------------------------------------------------------------

_RESIDUAL = None        # duck-typed: .fingerprint, .content_fingerprint(),
#                         .top_f, .correct(problem, configs, totals, hw)


def set_residual_corrector(res) -> object:
    """Install (or with None remove) the process-wide residual corrector;
    returns the previous one.  Duck-typed — calib owns the implementation,
    core never imports it."""
    global _RESIDUAL
    prev = _RESIDUAL
    _RESIDUAL = res
    return prev


def get_residual_corrector():
    return _RESIDUAL


def _residual_for(hw: HardwareSpec, fp: str):
    """The installed corrector iff it was fit for THIS topology's content
    fingerprint; a mismatch (recalibrated topology, wrong preset) is
    counted and the selection falls back to the pure analytical path."""
    res = _RESIDUAL
    if res is None:
        return None
    if getattr(res, "fingerprint", None) != fp:
        obs_metrics.inc("residual_fingerprint_mismatch",
                        labels={"hardware": hw.name})
        return None
    return res


def select_topk(
    p: GemmProblem,
    hw: HardwareSpec = TPU_V5E,
    k: int = 8,
    *,
    allow_split_k: bool = True,
    allow_grouping: bool = True,
) -> Tuple[List[TileConfig], np.ndarray, int]:
    """The top-``k`` candidates under the analytical model: (configs,
    their predicted totals, total candidate count).  Element 0 is exactly
    the config ``select_fast`` would return (same 1e-15 tie tolerance, same
    max-volume tie-break); the rest follow in (score, -volume, enumeration
    order) rank.  This is the residual corrector's re-pricing slate."""
    cands = candidate_tiles(p, hw, allow_split_k=allow_split_k,
                            allow_grouping=allow_grouping)
    if not cands:
        raise ValueError(f"empty candidate space for {p} on {hw.name}")
    n = len(cands)
    scores = score_candidates(p, cands, hw)
    bm = np.fromiter((t.bm for t in cands), np.int64, n)
    bn = np.fromiter((t.bn for t in cands), np.int64, n)
    bk = np.fromiter((t.bk for t in cands), np.int64, n)
    win = _argmin_index(scores, bm, bn, bk)
    # Rank by (score, -volume, enumeration order); hoist the tie-broken
    # winner to the front so corrected-argmin guards can reference it.
    order = np.lexsort((np.arange(n), -(bm * bn * bk), scores))
    head = [win] + [int(i) for i in order[:k] if int(i) != win]
    idx = head[:max(int(k), 1)]
    return [cands[i] for i in idx], scores[idx], n


def _select_residual(M: int, N: int, K: int, *, in_dtype: str,
                     out_dtype: str, batch: int, ep: Epilogue,
                     hw: HardwareSpec, fp: str, res, key: Tuple,
                     allow_split_k: bool, allow_grouping: bool,
                     ) -> "Selection":
    """The corrector-on scalar selection: memoised under a residual
    namespace, never persisted to disk, emitted as source ``residual``.
    ``predicted`` stays the analytical breakdown of the chosen config —
    drift rows keep measuring the model, not the corrector."""
    memo_key = key + (fp, "residual", res.content_fingerprint())
    hit = _CACHE.get(memo_key)
    if hit is not None:
        _emit_selection(hit, "memo")
        return hit
    p = GemmProblem(M=M, N=N, K=K, in_dtype=in_dtype,
                    out_dtype=out_dtype, batch=batch, epilogue=ep)
    top_f = int(getattr(res, "top_f", 8))
    configs, totals, n_cands = select_topk(
        p, hw, top_f, allow_split_k=allow_split_k,
        allow_grouping=allow_grouping)
    corrected = np.asarray(res.correct(p, configs, totals, hw), np.float64)
    # Switch away from the analytical winner (index 0) only when the
    # corrected advantage clears the corrector's margin — an uncertain
    # residual must not churn selections the model already got right.
    margin = float(getattr(res, "switch_margin", 0.0))
    j = int(np.argmin(corrected))
    if j != 0 and not corrected[j] < corrected[0] * (1.0 - margin):
        j = 0
    best = configs[j]
    sel = Selection(problem=p, config=best,
                    predicted=gemm_latency(p, best, hw),
                    hardware=hw.name, n_candidates=n_cands,
                    topo_fingerprint=fp)
    _CACHE[memo_key] = sel
    _emit_selection(sel, "residual")
    return sel


def load_selection_cache(path: Optional[str] = None) -> int:
    """Load (or re-load) the persistent selection table.  ``path`` resolves
    exactly like ``save_selection_cache``'s: the explicit argument, else the
    path of the last programmatic load, else ``$REPRO_SELECTION_CACHE``.
    (A bare re-load after ``load_selection_cache("/x.json")`` used to
    silently DEACTIVATE persistence when the env var was unset — even
    though save still honored the remembered path.)  With none of the
    three set, persistence deactivates; use ``unload_selection_cache`` to
    deactivate explicitly.  Returns the number of entries available for
    warm-starting."""
    global _disk_table, _disk_path
    path = path or _disk_path or os.environ.get(_DISK_ENV)
    if not path:
        _disk_table, _disk_path = None, None
        return 0
    try:
        with open(path) as f:
            table = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        table = {}
    _disk_table, _disk_path = table, path
    return len(table)


def unload_selection_cache() -> None:
    """Deactivate disk persistence: drop the in-memory disk table AND the
    remembered path.  (Since ``load_selection_cache`` resolves a bare call
    through the remembered path, this is the explicit off switch tests and
    benchmarks need after unsetting ``$REPRO_SELECTION_CACHE``.)"""
    global _disk_table, _disk_path
    _disk_table, _disk_path = None, None


def save_selection_cache(path: Optional[str] = None) -> Optional[str]:
    """Atomically write the in-memory disk table, merged over whatever is
    on disk (so concurrent processes sharing the path accumulate entries
    instead of clobbering each other; ours win on key collisions —
    selections are deterministic, so collisions agree anyway).  Returns the
    path written (None when persistence is inactive)."""
    global _disk_table
    path = path or _disk_path or os.environ.get(_DISK_ENV)
    if not path or _disk_table is None:
        return None
    try:
        with open(path) as f:
            merged = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        merged = {}
    merged.update(_disk_table)
    _disk_table = merged
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def _disk_lookup(key: Tuple) -> Optional[Dict]:
    global _disk_table
    if _disk_table is None:
        if not os.environ.get(_DISK_ENV):
            return None
        load_selection_cache()
    if _disk_table is None:
        return None
    return _disk_table.get(_key_str(key))


_FLUSH_EVERY = 32
_atexit_registered = False


def _disk_entry(sel: Selection, hw: HardwareSpec) -> Dict:
    """The persisted form of one selection: winning config + candidate count
    + the topology content fingerprint that invalidates it on recalibration
    (rehydration reprices the latency, so it is never stored)."""
    c = sel.config
    return {
        "config": {"bm": c.bm, "bn": c.bn, "bk": c.bk,
                   "split_k": c.split_k, "group_m": c.group_m,
                   "schedule": c.schedule},
        "n_candidates": sel.n_candidates,
        "topo": _topo_fingerprint(hw),
    }


def _register_atexit_flush() -> None:
    global _atexit_registered
    if not _atexit_registered:
        atexit.register(save_selection_cache)
        _atexit_registered = True


def _disk_record(key: Tuple, sel: Selection, hw: HardwareSpec) -> None:
    """Record a fresh selection.  Flushes eagerly while the table is small
    (a restarted server becomes durable immediately) and every
    ``_FLUSH_EVERY`` entries thereafter — a cold sweep of N shapes pays
    O(N/32) file rewrites, not O(N); an atexit flush catches the tail."""
    if _disk_table is None:
        return
    _disk_table[_key_str(key)] = _disk_entry(sel, hw)
    _register_atexit_flush()
    n = len(_disk_table)
    if n <= _FLUSH_EVERY or n % _FLUSH_EVERY == 0:
        save_selection_cache()


def _disk_record_bulk(items: Sequence[Tuple[Tuple, Selection]],
                      hw: HardwareSpec) -> None:
    """Record N fresh selections with ONE atomic merge-on-write flush —
    the batched cold path's durability step.  N scalar ``_disk_record``
    calls pay up to N read-merge-rewrite cycles while the table is small;
    here the whole batch lands in the in-memory table first and a single
    ``save_selection_cache`` merges it over whatever concurrent writers
    put on disk meanwhile (same last-writer-wins-per-key semantics —
    selections are deterministic, so collisions agree)."""
    if _disk_table is None or not items:
        return
    for key, sel in items:
        _disk_table[_key_str(key)] = _disk_entry(sel, hw)
    _register_atexit_flush()
    save_selection_cache()


def _argmin_index(scores: np.ndarray, bm: np.ndarray, bn: np.ndarray,
                  bk: np.ndarray) -> int:
    """Deterministic tie-break: within 1e-15 s of the minimum prefer the
    larger block volume (less issue overhead), then the earliest candidate in
    enumeration order — the same policy the scalar scoring loop applied."""
    idx = np.flatnonzero(scores <= scores.min() + 1e-15)
    vols = bm[idx] * bn[idx] * bk[idx]
    return int(idx[np.argmax(vols)])


def argmin_candidate(p: GemmProblem, cands: Sequence[TileConfig],
                     hw: HardwareSpec) -> TileConfig:
    """Vectorized argmin over an explicit candidate list."""
    scores = score_candidates(p, cands, hw)
    n = len(cands)
    bm = np.fromiter((t.bm for t in cands), np.int64, n)
    bn = np.fromiter((t.bn for t in cands), np.int64, n)
    bk = np.fromiter((t.bk for t in cands), np.int64, n)
    return cands[_argmin_index(scores, bm, bn, bk)]


def select_gemm_config(
    M: int,
    N: int,
    K: int,
    *,
    in_dtype: str = "bfloat16",
    out_dtype: str = "float32",
    batch: int = 1,
    epilogue: Optional[Epilogue] = None,
    hw: HardwareSpec = TPU_V5E,
    allow_split_k: bool = True,
    allow_grouping: bool = True,
) -> Selection:
    """The paper's API: problem shape in, near-optimal TileConfig out.

    Zero autotuning. Deterministic. Memoised per (problem, hardware).
    ``epilogue`` prices the fused flush work (extra operand reads) so
    candidates are ranked against the *fused* traffic."""
    ep = epilogue or EPILOGUE_NONE
    key = (M, N, K, in_dtype, out_dtype, batch, ep, hw.name,
           allow_split_k, allow_grouping)
    # The in-process memo carries the content fingerprint on top of the
    # disk key: a calibrated topology served under its preset name in the
    # SAME process must cold-rescore, exactly like the disk table's
    # per-entry fingerprint forces across processes.  The fingerprint is
    # identity-memoized on the Topology, so a memo hit stays O(1).
    fp = topology_fingerprint(hw)
    res = _residual_for(hw, fp)
    if res is not None:
        return _select_residual(M, N, K, in_dtype=in_dtype,
                                out_dtype=out_dtype, batch=batch, ep=ep,
                                hw=hw, fp=fp, res=res, key=key,
                                allow_split_k=allow_split_k,
                                allow_grouping=allow_grouping)
    memo_key = key + (fp,)
    hit = _CACHE.get(memo_key)
    if hit is not None:
        _emit_selection(hit, "memo")
        return hit

    p = GemmProblem(M=M, N=N, K=K, in_dtype=in_dtype,
                    out_dtype=out_dtype, batch=batch, epilogue=ep)
    sel = _rehydrate_disk_entry(p, key, hw)
    if sel is not None:
        _CACHE[memo_key] = sel
        _emit_selection(sel, "disk")
        return sel
    # Fast O(P) scoring pass (Table II claim): enumeration, filtering and
    # scoring are all one numpy batch — only the winning TileConfig is ever
    # materialized; full latency breakdown for the winner only.
    best, n_cands = select_fast(p, hw, allow_split_k=allow_split_k,
                                allow_grouping=allow_grouping)
    sel = Selection(problem=p, config=best, predicted=gemm_latency(p, best, hw),
                    hardware=hw.name, n_candidates=n_cands,
                    topo_fingerprint=fp)
    _CACHE[memo_key] = sel
    _disk_record(key, sel, hw)
    _emit_selection(sel, "cold")
    return sel


def _rehydrate_disk_entry(p: GemmProblem, key: Tuple,
                          hw: HardwareSpec) -> Optional[Selection]:
    """Warm start from the persistent table: the winning config persisted by
    a previous process, repriced O(1) — no enumeration, no scoring pass.
    A missing/malformed entry, one recorded under different topology
    constants (the key carries hw.name, the entry a content fingerprint —
    recalibration changes the argmin), or one whose config fails the
    selection invariants (placement budget, alignment, power-of-two dims —
    a tampered-but-parseable cache entry) returns None and the caller falls
    through to cold scoring."""
    entry = _disk_lookup(key)
    if entry is None:
        return None
    try:
        best = TileConfig(**entry["config"])
        n_cands = int(entry["n_candidates"])
        legal = (entry.get("topo") == _topo_fingerprint(hw)
                 and validate_selection(p, best, hw) is None)
    except (KeyError, TypeError, ValueError):
        legal = False
    if not legal:
        return None
    return Selection(problem=p, config=best,
                     predicted=gemm_latency(p, best, hw),
                     hardware=hw.name, n_candidates=n_cands,
                     topo_fingerprint=_topo_fingerprint(hw))


def select_gemm_config_batch(
    shapes: Sequence[Sequence[int]],
    *,
    in_dtype: str = "bfloat16",
    out_dtype: str = "float32",
    batch: int = 1,
    epilogue: Optional[Epilogue] = None,
    hw: HardwareSpec = TPU_V5E,
    allow_split_k: bool = True,
    allow_grouping: bool = True,
) -> List[Selection]:
    """``select_gemm_config`` for many shapes at once — the serving warm-up
    / bucket-pricing entry point.  ``shapes`` is a sequence of ``(M, N, K)``
    or ``(M, N, K, batch)`` tuples sharing dtypes and epilogue.

    Per-shape results are bit-identical to S scalar calls (config AND
    latency — ``select_fast_batch`` broadcasts the same float64 arithmetic).
    The difference is cost, not output: memo/disk hits resolve per shape as
    usual (hooks fire with the same sources), and ALL cold shapes share one
    (S, P) scoring pass plus one bulk merge-on-write disk flush instead of
    S passes and up to S file rewrites.  Duplicate cold shapes are scored
    once and share the resulting Selection (one "cold" hook emission)."""
    ep = epilogue or EPILOGUE_NONE
    fp = topology_fingerprint(hw)
    if _residual_for(hw, fp) is not None:
        # Corrector-on batches route through the scalar path: the residual
        # re-prices per-shape finalists anyway, and the scalar memo
        # namespace keeps hit/miss telemetry consistent with it.
        return [select_gemm_config(int(s[0]), int(s[1]), int(s[2]),
                                   in_dtype=in_dtype, out_dtype=out_dtype,
                                   batch=int(s[3]) if len(s) > 3 else batch,
                                   epilogue=ep, hw=hw,
                                   allow_split_k=allow_split_k,
                                   allow_grouping=allow_grouping)
                for s in shapes]
    out: List[Optional[Selection]] = [None] * len(shapes)
    cold: Dict[Tuple, List[int]] = {}      # key -> indices awaiting scoring
    cold_probs: Dict[Tuple, GemmProblem] = {}
    # One availability probe for the whole batch: ``_disk_lookup`` would
    # re-check the environment per shape only to return None every time.
    disk_on = _disk_table is not None or bool(os.environ.get(_DISK_ENV))
    for i, s in enumerate(shapes):
        M, N, K = int(s[0]), int(s[1]), int(s[2])
        b = int(s[3]) if len(s) > 3 else batch
        key = (M, N, K, in_dtype, out_dtype, b, ep, hw.name,
               allow_split_k, allow_grouping)
        memo_key = key + (fp,)
        hit = _CACHE.get(memo_key)
        if hit is not None:
            _emit_selection(hit, "memo")
            out[i] = hit
            continue
        if key in cold:                    # duplicate cold shape: share it
            cold[key].append(i)
            continue
        p = GemmProblem(M=M, N=N, K=K, in_dtype=in_dtype,
                        out_dtype=out_dtype, batch=b, epilogue=ep)
        sel = _rehydrate_disk_entry(p, key, hw) if disk_on else None
        if sel is not None:
            _CACHE[memo_key] = sel
            _emit_selection(sel, "disk")
            out[i] = sel
            continue
        cold[key] = [i]
        cold_probs[key] = p
    if cold:
        keys = list(cold)
        results = select_fast_batch(
            [cold_probs[k] for k in keys], hw,
            allow_split_k=allow_split_k, allow_grouping=allow_grouping)
        records: List[Tuple[Tuple, Selection]] = []
        breakdowns = gemm_latency_batch(
            [cold_probs[k] for k in keys], [r[0] for r in results], hw)
        for key, (best, n_cands), bd in zip(keys, results, breakdowns):
            p = cold_probs[key]
            sel = Selection(problem=p, config=best, predicted=bd,
                            hardware=hw.name, n_candidates=n_cands,
                            topo_fingerprint=fp)
            _CACHE[key + (fp,)] = sel
            records.append((key, sel))
            for i in cold[key]:
                out[i] = sel
            _emit_selection(sel, "cold")
        _disk_record_bulk(records, hw)
    return out  # type: ignore[return-value]


def clear_selection_cache() -> None:
    _CACHE.clear()


def selection_cache_size() -> int:
    return len(_CACHE)
