"""Deterministic, zero-autotuning GEMM config selection (paper contribution #1).

``select_gemm_config`` enumerates the candidate tiling space — the same space
an autotuner would compile-and-benchmark — scores every candidate with the
closed-form latency model (O(1) each, so O(P) total), and returns the argmin.
Results are memoised exactly like the paper's cached selections (§V-B):
first call ~tens of µs, repeat calls ~1 µs.

The candidate space is TPU-shaped (DESIGN.md §2): block dims are MXU/lane
aligned, capped by the VMEM capacity filter (the paper's LDS filter), with
power-of-two sizes mirroring Triton's constraint noted in paper §V-C.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import DTYPE_BYTES, TPU_V5E, HardwareSpec
from repro.core.latency import (
    GemmProblem,
    LatencyBreakdown,
    TileConfig,
    cdiv,
    gemm_latency,
    grid_shape,
    round_up,
    score_candidate,
    vmem_working_set,
)

# Candidate block-dimension menus. bn/bk live on the 128-lane axis; bm may
# drop to the sublane granularity for skinny-M problems (padding waste would
# otherwise dominate — the paper's tile-quantization discussion, §V-C).
_BM_MENU = (8, 16, 32, 64, 128, 256, 512, 1024)
_BN_MENU = (128, 256, 512, 1024)
_BK_MENU = (128, 256, 512, 1024, 2048)
_SPLIT_K_MENU = (1, 2, 4, 8)
_GROUP_M_MENU = (1, 8)


@dataclass(frozen=True)
class Selection:
    problem: GemmProblem
    config: TileConfig
    predicted: LatencyBreakdown
    hardware: str
    n_candidates: int

    @property
    def predicted_tflops(self) -> float:
        return self.problem.flops / self.predicted.total / 1e12

    def __str__(self) -> str:
        p, c = self.problem, self.config
        return (f"[{p.M}x{p.N}x{p.K} {p.in_dtype}] -> {c} "
                f"({self.predicted.total*1e6:.1f}us, "
                f"{self.predicted_tflops:.1f} TF/s, "
                f"bound={self.predicted.bottleneck})")


def candidate_tiles(
    p: GemmProblem,
    hw: HardwareSpec = TPU_V5E,
    *,
    allow_split_k: bool = True,
    allow_grouping: bool = True,
) -> List[TileConfig]:
    """Enumerate the legal candidate space for one problem.

    Filters (in order):
      1. alignment — bm multiple of the dtype sublane, bn/bk of the lane width;
      2. usefulness — a block dim at most one menu step beyond the padded
         problem dim (bigger is pure padding waste);
      3. VMEM capacity — pipeline-buffered working set fits the budget;
      4. model-equivalence pruning — group_m only changes behaviour when the
         revisit model can trigger (Tk == 1); split_k only when the grid is
         small enough for fill/drain to matter (deterministic, part of the
         model, keeps P near the paper's 50-150).
    """
    sub = hw.sublane(p.in_dtype)
    lane = hw.lane_width
    budget = hw.vmem_budget()

    def useful(menu: Sequence[int], extent: int, align: int) -> List[int]:
        padded = round_up(extent, align)
        keep = [m for m in menu if m % align == 0]
        # smallest menu entry >= padded extent, plus everything below it
        cut = next((m for m in keep if m >= padded), keep[-1])
        return [m for m in keep if m <= cut]

    bms = useful(_BM_MENU, p.M, sub)
    bns = useful(_BN_MENU, p.N, lane)
    bks = useful(_BK_MENU, p.K, lane)
    sks = _SPLIT_K_MENU if allow_split_k else (1,)
    gms = _GROUP_M_MENU if allow_grouping else (1,)

    out: List[TileConfig] = []
    for bm, bn, bk in itertools.product(bms, bns, bks):
        base_tiles = cdiv(p.M, bm) * cdiv(p.N, bn) * p.batch
        tk = cdiv(p.K, bk)
        for sk in sks:
            if sk > 1 and (cdiv(p.K, sk) < bk or base_tiles >= 16):
                continue                  # split finer than a block / no need
            for gm in gms:
                if gm > 1 and (tk != 1 or cdiv(p.M, bm) < 2):
                    continue              # revisit can't trigger -> identical
                t = TileConfig(bm=bm, bn=bn, bk=bk, split_k=sk, group_m=gm)
                if vmem_working_set(t, p.in_dtype, hw) > budget:
                    continue
                out.append(t)
    return out


def rank_candidates(
    p: GemmProblem,
    hw: HardwareSpec = TPU_V5E,
    **kwargs,
) -> List[Tuple[TileConfig, LatencyBreakdown]]:
    """Score the whole space, best first. Deterministic tie-break: prefer the
    larger block (less issue overhead), then lexicographic config order."""
    cands = candidate_tiles(p, hw, **kwargs)
    scored = [(t, gemm_latency(p, t, hw)) for t in cands]
    scored.sort(key=lambda it: (it[1].total,
                                -(it[0].bm * it[0].bn * it[0].bk),
                                it[0].bm, it[0].bn, it[0].bk,
                                it[0].split_k, it[0].group_m))
    return scored


_CACHE: Dict[Tuple, Selection] = {}


def select_gemm_config(
    M: int,
    N: int,
    K: int,
    *,
    in_dtype: str = "bfloat16",
    out_dtype: str = "float32",
    batch: int = 1,
    hw: HardwareSpec = TPU_V5E,
    allow_split_k: bool = True,
    allow_grouping: bool = True,
) -> Selection:
    """The paper's API: problem shape in, near-optimal TileConfig out.

    Zero autotuning. Deterministic. Memoised per (problem, hardware)."""
    key = (M, N, K, in_dtype, out_dtype, batch, hw.name,
           allow_split_k, allow_grouping)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit

    p = GemmProblem(M=M, N=N, K=K, in_dtype=in_dtype,
                    out_dtype=out_dtype, batch=batch)
    cands = candidate_tiles(p, hw, allow_split_k=allow_split_k,
                            allow_grouping=allow_grouping)
    if not cands:
        raise ValueError(f"empty candidate space for {p} on {hw.name}")
    # Fast O(P) scoring pass (Table II claim); full breakdown for winner only.
    best, best_score = None, None
    for t in cands:
        s = score_candidate(p, t, hw)
        if best_score is None or s < best_score - 1e-15 or (
                abs(s - best_score) <= 1e-15
                and (t.bm * t.bn * t.bk) > (best.bm * best.bn * best.bk)):
            best, best_score = t, s
    sel = Selection(problem=p, config=best, predicted=gemm_latency(p, best, hw),
                    hardware=hw.name, n_candidates=len(cands))
    _CACHE[key] = sel
    return sel


def clear_selection_cache() -> None:
    _CACHE.clear()


def selection_cache_size() -> int:
    return len(_CACHE)
