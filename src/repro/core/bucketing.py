"""Model-priced shape bucketing for ragged serving workloads.

A serving engine cannot compile a kernel per request shape: ragged token
batches (the GEMM M extent) must be padded up to a small set of *bucket
edges*, one compiled executable per edge.  The classic policy pads to powers
of two — shape-blind, and on multi-core chips it routinely parks an edge
just past a wave boundary, where the tail-wave quantization the occupancy
model prices (Alg. 4; reproduced by ``benchmarks/wave_quantization.py`` as
38-47% throughput dips) wastes most of a wave.

Here the bucket set itself is an output of the analytical model.  For a
measured M-distribution the planner prices every candidate edge with the
real selection pipeline — one :func:`repro.core.select_gemm_config_batch`
call for the whole ``candidates x gemms`` grid — and a small DP picks the
edge set minimizing model-predicted *total* serving time:

    total(edges) = sum_m  w(m) * step_cost(edge(m))         padding waste
                 + n_edges * bucket_overhead_s              compile/warm-up

``step_cost(e)`` is the modeled latency of one transformer step's GEMMs at
M = e, so a cliff edge (occupancy dip) prices itself out and the chosen
edges land on wave boundaries instead of powers of two.  Per-bucket edge
choice is independent: a bucket covering sizes up to s needs only
``edge >= s``, and the best such edge is a pure argmin over the priced
candidates — the DP composes those argmins over contiguous size ranges.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.selector import Selection, select_gemm_config_batch
from repro.core.topology import Topology
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def step_gemms(d_model: int, d_ff: int, *, kv_dim: Optional[int] = None,
               vocab: Optional[int] = None, swiglu: bool = True
               ) -> List[Tuple[int, int]]:
    """The (N, K) extents of one decoder step's GEMMs — the per-token work a
    bucket edge multiplies.  Mirrors ``configs.llama3_shapes`` structure:
    fused QKV, attention output, up (doubled when the MLP is gated), down,
    and optionally the LM head."""
    kv = kv_dim if kv_dim is not None else d_model
    gemms = [
        (d_model + 2 * kv, d_model),          # fused QKV projection
        (d_model, d_model),                   # attention output
        ((2 if swiglu else 1) * d_ff, d_model),  # MLP up (+gate when gated)
        (d_model, d_ff),                      # MLP down
    ]
    if vocab:
        gemms.append((vocab, d_model))
    return gemms


@dataclass(frozen=True)
class BucketPlan:
    """A priced bucket policy: ascending pad targets + the model's receipts.

    ``edges`` are the only M extents the engine ever launches; ``bucket_for``
    maps a ragged size to its pad target.  ``modeled_total_s`` is the DP
    objective value (padding waste + per-bucket overhead) for the planning
    distribution; ``edge_step_s`` the per-request step cost at each edge —
    kept so serving stats can attribute measured time to modeled time."""
    edges: Tuple[int, ...]
    policy: str
    modeled_total_s: float
    modeled_request_s: float            # weighted mean step cost per request
    pad_fraction: float                 # padded-away share of launched rows
    bucket_overhead_s: float
    edge_step_s: Dict[int, float] = field(default_factory=dict, repr=False)
    selections: Dict[int, Tuple[Selection, ...]] = field(
        default_factory=dict, repr=False, compare=False)

    def bucket_for(self, size: int) -> int:
        """Smallest edge >= size.  Sizes beyond the largest edge raise —
        admission must clamp/chunk before asking for a bucket."""
        i = bisect.bisect_left(self.edges, size)
        if i == len(self.edges):
            raise ValueError(
                f"request size {size} exceeds largest bucket edge "
                f"{self.edges[-1]}")
        return self.edges[i]


def _price_edges(candidates: Sequence[int], gemms: Sequence[Tuple[int, int]],
                 hw: Topology, in_dtype: str, out_dtype: str
                 ) -> Tuple[Dict[int, float], Dict[int, Tuple[Selection, ...]]]:
    """Model-predicted one-step cost at M = each candidate edge — ONE
    batched selection call for the whole (edge x gemm) grid."""
    shapes = [(e, n, k) for e in candidates for (n, k) in gemms]
    sels = select_gemm_config_batch(shapes, in_dtype=in_dtype,
                                    out_dtype=out_dtype, hw=hw)
    g = len(gemms)
    cost: Dict[int, float] = {}
    per_edge: Dict[int, Tuple[Selection, ...]] = {}
    for i, e in enumerate(candidates):
        block = sels[i * g:(i + 1) * g]
        cost[e] = sum(s.predicted.total for s in block)
        per_edge[e] = tuple(block)
    return cost, per_edge


def _normalize(sizes: Sequence[int], weights: Optional[Sequence[float]]
               ) -> Tuple[List[int], List[float]]:
    if len(sizes) == 0:
        raise ValueError("plan_buckets needs at least one request size")
    w = [1.0] * len(sizes) if weights is None else [float(x) for x in weights]
    if len(w) != len(sizes):
        raise ValueError(f"{len(sizes)} sizes but {len(w)} weights")
    agg: Dict[int, float] = {}
    for s, ww in zip(sizes, w):
        s = int(s)
        if s < 1:
            raise ValueError(f"request size {s} < 1")
        if ww < 0:
            raise ValueError(f"negative weight {ww}")
        agg[s] = agg.get(s, 0.0) + ww
    ss = sorted(agg)
    return ss, [agg[s] for s in ss]


def _plan_stats(ss: List[int], ws: List[float], edges: List[int],
                cost: Dict[int, float], overhead: float
                ) -> Tuple[float, float, float]:
    tot_w = sum(ws)
    total = len(edges) * overhead
    req_s = 0.0
    padded_rows = real_rows = 0.0
    for s, w in zip(ss, ws):
        e = edges[bisect.bisect_left(edges, s)]
        total += w * cost[e]
        req_s += w * cost[e]
        padded_rows += w * e
        real_rows += w * s
    pad_frac = 1.0 - real_rows / padded_rows if padded_rows else 0.0
    return total, req_s / tot_w if tot_w else 0.0, pad_frac


def plan_buckets(sizes: Sequence[int], weights: Optional[Sequence[float]]
                 = None, *, gemms: Sequence[Tuple[int, int]],
                 hw: Topology, max_buckets: int = 8,
                 bucket_overhead_s: float = 1e-3,
                 granularity: int = 8,
                 in_dtype: str = "bfloat16", out_dtype: str = "float32"
                 ) -> BucketPlan:
    """Pick <= max_buckets pad targets minimizing model-predicted total time.

    Candidate edges are every multiple of ``granularity`` covering the size
    range (plus the exact maximum), all priced in one batched selection
    pass.  DP over the sorted distinct sizes: a bucket covers a contiguous
    size range and pays ``weight * best_cost(range max)`` where
    ``best_cost(s) = min over candidates e >= s of step_cost(e)`` — the
    per-bucket best-edge independence that makes the DP exact.  Because
    ``step_cost`` is *not* monotone in M on multi-core chips (tail-wave
    cliffs), best_cost frequently picks an edge above the minimal cover —
    that is the model steering edges onto wave boundaries."""
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    if granularity < 1:
        raise ValueError(f"granularity must be >= 1, got {granularity}")
    with obs_trace.span("plan_buckets", cat="bucketing", track="bucketing",
                        args={"n_sizes": len(sizes),
                              "max_buckets": max_buckets}) as _sp:
        plan = _plan_buckets(sizes, weights, gemms=gemms, hw=hw,
                             max_buckets=max_buckets,
                             bucket_overhead_s=bucket_overhead_s,
                             granularity=granularity,
                             in_dtype=in_dtype, out_dtype=out_dtype)
        if _sp is not None:
            _sp.args["edges"] = list(plan.edges)
            _sp.args["modeled_total_s"] = plan.modeled_total_s
            _sp.args["pad_fraction"] = plan.pad_fraction
    obs_metrics.set_gauge("bucket_plan_edges", len(plan.edges))
    obs_metrics.set_gauge("bucket_plan_pad_fraction", plan.pad_fraction)
    obs_metrics.set_gauge("bucket_plan_modeled_total_s",
                          plan.modeled_total_s)
    return plan


def _plan_buckets(sizes: Sequence[int], weights: Optional[Sequence[float]]
                  = None, *, gemms: Sequence[Tuple[int, int]],
                  hw: Topology, max_buckets: int = 8,
                  bucket_overhead_s: float = 1e-3,
                  granularity: int = 8,
                  in_dtype: str = "bfloat16", out_dtype: str = "float32"
                  ) -> BucketPlan:
    ss, ws = _normalize(sizes, weights)
    hi = ss[-1]
    # Candidates: every granularity multiple covering the range, with 25%
    # headroom above the max — when a cliff sits exactly at the max size,
    # padding PAST it can be cheaper than landing on it — plus the exact max.
    top = ((hi + hi // 4) // granularity + 1) * granularity
    cands = sorted(set(range(granularity, top + 1, granularity)) | {hi})
    cost, per_edge = _price_edges(cands, gemms, hw, in_dtype, out_dtype)

    # best edge covering size >= s, for every distinct size (suffix argmin
    # over candidates — cliffs make this genuinely non-trivial).
    carr = np.asarray([cost[e] for e in cands])
    best_edge_for: Dict[int, int] = {}
    suffix_best: List[int] = [0] * len(cands)
    bi_ = len(cands) - 1
    suffix_best[-1] = len(cands) - 1
    for i in range(len(cands) - 2, -1, -1):
        bi_ = i if carr[i] <= carr[suffix_best[i + 1]] else suffix_best[i + 1]
        suffix_best[i] = bi_
    for s in ss:
        j = bisect.bisect_left(cands, s)
        best_edge_for[s] = cands[suffix_best[j]]

    n = len(ss)
    kmax = min(max_buckets, n)
    # dp[j][i]: min cost covering sizes[0:i] with exactly j buckets.
    w_pref = np.concatenate(([0.0], np.cumsum(ws)))
    INF = float("inf")
    dp = np.full((kmax + 1, n + 1), INF)
    dp[0][0] = 0.0
    choice = np.zeros((kmax + 1, n + 1), np.int64)
    for j in range(1, kmax + 1):
        for i in range(j, n + 1):
            best, arg = INF, i - 1
            e_cost_cache = cost[best_edge_for[ss[i - 1]]]
            for sp in range(j - 1, i):
                if dp[j - 1][sp] == INF:
                    continue
                c = dp[j - 1][sp] \
                    + (w_pref[i] - w_pref[sp]) * e_cost_cache \
                    + bucket_overhead_s
                if c < best:
                    best, arg = c, sp
            dp[j][i] = best
            choice[j][i] = arg
    # ^ note the bucket's edge depends only on its top size ss[i-1] — the
    #   per-bucket best-edge independence argument above.
    jbest = int(np.argmin(dp[1:, n])) + 1
    edges: List[int] = []
    i = n
    for j in range(jbest, 0, -1):
        edges.append(best_edge_for[ss[i - 1]])
        i = int(choice[j][i])
    edges = sorted(set(edges))
    total, req_s, pad_frac = _plan_stats(ss, ws, edges, cost,
                                         bucket_overhead_s)
    return BucketPlan(edges=tuple(edges), policy="model_priced",
                      modeled_total_s=total, modeled_request_s=req_s,
                      pad_fraction=pad_frac,
                      bucket_overhead_s=bucket_overhead_s,
                      edge_step_s={e: cost[e] for e in edges},
                      selections={e: per_edge[e] for e in edges})


def pow2_plan(sizes: Sequence[int], weights: Optional[Sequence[float]]
              = None, *, gemms: Sequence[Tuple[int, int]], hw: Topology,
              bucket_overhead_s: float = 1e-3,
              in_dtype: str = "bfloat16", out_dtype: str = "float32"
              ) -> BucketPlan:
    """The shape-blind baseline: pad every request to the next power of two.
    Priced with the same model so the comparison is apples-to-apples."""
    ss, ws = _normalize(sizes, weights)
    edges = sorted({1 << (int(s) - 1).bit_length() for s in ss})
    cost, per_edge = _price_edges(edges, gemms, hw, in_dtype, out_dtype)
    total, req_s, pad_frac = _plan_stats(ss, ws, edges, cost,
                                         bucket_overhead_s)
    return BucketPlan(edges=tuple(edges), policy="pow2",
                      modeled_total_s=total, modeled_request_s=req_s,
                      pad_fraction=pad_frac,
                      bucket_overhead_s=bucket_overhead_s,
                      edge_step_s=cost,
                      selections=per_edge)
