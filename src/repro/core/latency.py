"""The analytical GEMM latency model — paper §IV, Algorithms 3-9, TPU-adapted.

The paper decomposes GEMM latency into hierarchical compute and memory stages
and scores a tiling candidate as

    L_total = waves x ( prologue + epilogue + iters x max(L_compute, L_mem) )

On TPU (see DESIGN.md §2) the same structure holds with these substitutions:

* Alg. 3  (compute latency of a shared-memory tile)  ->  MXU-atom count of a
  VMEM block, plus the VMEM<->VREG port term (the paper's "software managed
  memory bandwidth bound").
* Alg. 4  (active CUs / wave quantization)           ->  partial-block padding
  waste within a core (ceil terms) + chip-level wave quantization used by the
  distributed layer (`chip_waves`).
* Alg. 5  (cache hit rate)                           ->  two locality terms:
  the deterministic Pallas *revisit* model (the fetch into staging memory is
  skipped when a block index repeats between consecutive grid steps), plus a
  generic reuse/footprint recurrence over the topology's cache levels
  (``level_traffic``): a re-read whose reuse-window footprint fits in level
  ℓ is served from ℓ, otherwise it spills to ℓ+1 — the paper's Alg. 5-7
  cache-tile factorization.  On a 1-level chain (TPU: no cache between HBM
  and VMEM) the recurrence is inert and the model reduces bit-for-bit to
  the seed's HBM revisit model.
* Alg. 7  (memory latency of a loop iteration)       ->  per-grid-step DMA
  bytes / HBM bandwidth, plus the fixed DMA-issue cost (the "load/store issue
  rate" axis) and first-byte latency at the prologue.
* Alg. 8/9 (pipeline + total)                        ->  Pallas's grid pipeline
  is continuous across output tiles, so total = launch + fill +
  sum over grid steps of max(L_compute, L_mem) + drain.

Everything is closed-form and O(1) per candidate — this is what makes
selection O(P) instead of the autotuner's O(P·M·N·K) (paper §V-B).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.dtypes import ACC_BYTES, DTYPE_BYTES
from repro.core.topology import SCHEDULES, HardwareSpec, MemoryLevel, Topology


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


_ACTIVATIONS = (None, "gelu", "silu", "swiglu_gate")


@dataclass(frozen=True)
class Epilogue:
    """Post-GEMM work executed inside the kernel's accumulator flush.

    Flush-order semantics (all in the f32 accumulator, DESIGN.md §3):

        y = acc
        y = y + bias                       (bias:       (N,) operand)
        y = act(y)                         (gelu | silu)
        y = silu(y) * gate                 (swiglu_gate: (M, N) operand)
        y = y + residual                   (residual:   (M, N) operand)
        out = cast(y, out_dtype)

    Fusing these removes one full-output HBM round trip per post-op that XLA
    would otherwise run as a separate elementwise kernel — the cost model
    prices exactly that delta (``epilogue_unfused_extra_bytes``).
    """

    bias: bool = False
    activation: Optional[str] = None     # None | gelu | silu | swiglu_gate
    residual: bool = False

    def __post_init__(self):
        if self.activation not in _ACTIVATIONS:
            raise ValueError(
                f"unknown epilogue activation {self.activation!r}; "
                f"choose from {_ACTIVATIONS}")

    @property
    def is_identity(self) -> bool:
        return not (self.bias or self.activation or self.residual)

    @property
    def n_mn_operands(self) -> int:
        """Extra full (M, N) operands the flush must read (gate, residual)."""
        return int(self.activation == "swiglu_gate") + int(self.residual)

    @property
    def n_ops(self) -> int:
        """Separate XLA elementwise kernels the unfused formulation needs."""
        return (int(self.bias) + int(self.activation is not None)
                + int(self.residual))

    def __str__(self) -> str:
        if self.is_identity:
            return "none"
        parts = ([] if not self.bias else ["bias"]) \
            + ([self.activation] if self.activation else []) \
            + (["residual"] if self.residual else [])
        return "+".join(parts)


EPILOGUE_NONE = Epilogue()


@dataclass(frozen=True)
class GemmProblem:
    """C[M,N] = epilogue(A[M,K] @ B[K,N]), optionally batched (leading dim)."""

    M: int
    N: int
    K: int
    in_dtype: str = "bfloat16"
    out_dtype: str = "float32"
    batch: int = 1
    epilogue: Epilogue = EPILOGUE_NONE

    def __post_init__(self):
        if min(self.M, self.N, self.K, self.batch) < 1:
            raise ValueError(f"degenerate GEMM problem {self}")

    @property
    def flops(self) -> float:
        return 2.0 * self.batch * self.M * self.N * self.K

    @property
    def min_bytes(self) -> float:
        """Compulsory traffic: read A, B and epilogue operands once, write C
        once."""
        bi, bo = DTYPE_BYTES[self.in_dtype], DTYPE_BYTES[self.out_dtype]
        ep = self.epilogue
        e_bytes = (ep.n_mn_operands * self.M * self.N
                   + (self.N if ep.bias else 0)) * bi
        return self.batch * ((self.M * self.K + self.K * self.N) * bi
                             + self.M * self.N * bo + e_bytes)

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / self.min_bytes


@dataclass(frozen=True, eq=False)
class ShapeBatch:
    """Column view of S GEMM problems sharing dtypes/epilogue — the batched
    problem axis ``selector.select_fast_batch`` broadcasts over.

    ``M``/``N``/``K``/``batch`` are (S, 1) int64 columns; broadcast against
    the (P,) candidate menu columns they yield (S, P) scored arrays whose
    rows are elementwise-identical to S scalar scoring passes (every int
    product stays < 2**53, so the int64 -> float64 casts inside the model
    are exact and the IEEE op order is unchanged).  Duck-types the
    ``GemmProblem`` fields the vectorized model functions read."""

    M: np.ndarray
    N: np.ndarray
    K: np.ndarray
    batch: np.ndarray
    in_dtype: str = "bfloat16"
    out_dtype: str = "float32"
    epilogue: Epilogue = EPILOGUE_NONE

    @classmethod
    def from_problems(cls, problems: Sequence["GemmProblem"]) -> "ShapeBatch":
        p0 = problems[0]
        for p in problems:
            if (p.in_dtype, p.out_dtype, p.epilogue) != \
                    (p0.in_dtype, p0.out_dtype, p0.epilogue):
                raise ValueError(
                    "ShapeBatch requires uniform dtypes/epilogue; got "
                    f"{p} vs {p0}")
        cols = np.asarray([(p.M, p.N, p.K, p.batch) for p in problems],
                          np.int64).reshape(len(problems), 4, 1)
        return cls(M=cols[:, 0], N=cols[:, 1], K=cols[:, 2],
                   batch=cols[:, 3], in_dtype=p0.in_dtype,
                   out_dtype=p0.out_dtype, epilogue=p0.epilogue)


@dataclass(frozen=True)
class TileConfig:
    """One point of the candidate space (the paper's tiling hierarchy knobs).

    bm, bn, bk: the VMEM block (paper: workgroup/shared-memory tile).
    split_k   : k-parallel partial-accumulation factor.
    group_m   : grouped grid-iteration order (paper: cache-tile factorization;
                on TPU it controls which operand the revisit-skip applies to).
    schedule  : how work units map onto cores (the occupancy stage):
                ``data_parallel`` — one unit per (output tile, k-shard),
                wave-quantized over ``Topology.total_cores()``;
                ``stream_k`` — persistent kernel, the flattened k-step space
                split into one contiguous strip per core (no tile-granular
                tail wave; strip-boundary tiles pay a partial fixup).
                On single-core chains (TPU) both schedules execute — and are
                priced — identically; the kernel lowers stream_k to the
                existing sequential split-K grid.
    """

    bm: int
    bn: int
    bk: int
    split_k: int = 1
    group_m: int = 1
    schedule: str = "data_parallel"

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; choose from {SCHEDULES}")

    def __str__(self) -> str:
        s = f"{self.bm}x{self.bn}x{self.bk}"
        if self.split_k > 1:
            s += f"/sk{self.split_k}"
        if self.group_m > 1:
            s += f"/g{self.group_m}"
        if self.schedule == "stream_k":
            s += "/streamk"
        return s


@dataclass(frozen=True)
class LatencyBreakdown:
    """Scored candidate with the paper's bottleneck taxonomy (§IV-D)."""

    total: float                  # seconds
    compute: float                # steady-state MXU term per step (summed)
    vmem: float                   # staging<->register port term (summed)
    hbm: float                    # backing-memory DMA term (summed)
    issue: float                  # fixed DMA-issue term (summed)
    fill_drain: float             # prologue + epilogue + launch
    hbm_traffic: float            # bytes served from backing memory
    padded_flops: float           # FLOPs incl. MXU-atom padding
    bottleneck: str               # one of BOTTLENECKS (+ per-level names)
    # Per-level views (topology refactor): bytes served from each memory
    # level of the chain (backing + caches) and the summed bandwidth term
    # of each level's port.  On a 1-level chain these hold the HBM entry
    # only and `hbm`/`hbm_traffic` above are their single values.
    level_bytes: Mapping[str, float] = field(default_factory=dict)
    level_seconds: Mapping[str, float] = field(default_factory=dict)
    # Occupancy stage (Alg. 4 chip-wide): schedulable work units, waves over
    # total_cores, and the tail-wave efficiency units / (waves * cores) in
    # (0, 1].  Single-core chains report units == waves, occupancy == 1.0.
    units: int = 0
    waves: int = 0
    occupancy: float = 1.0
    # Max-plus overlap pricing (multi-core chains): seconds the output /
    # epilogue / partial-accumulator flush cursor adds after the overlapped
    # steady-state loop.  0.0 on single-core chains, where the seed's mean
    # memory-step model is retained bit-for-bit.
    flush: float = 0.0

    @property
    def efficiency(self) -> float:
        """Fraction of total spent in useful MXU compute."""
        return self.compute / self.total if self.total > 0 else 0.0


BOTTLENECKS = (
    "mxu_compute",        # paper: max-parallelism compute bound
    "vmem_bandwidth",     # paper: software-managed memory bandwidth bound
    "hbm_bandwidth",      # paper: cache/memory bandwidth bound
    "dma_issue",          # paper: load/store issue rate bound
    "pipeline_fill",      # paper: under-occupied compute bound
)
# Multi-level topologies additionally report "<level>_bandwidth" (e.g.
# "l2_bandwidth") when an intermediate cache port dominates.


def grid_shape(p: GemmProblem, t: TileConfig) -> Tuple[int, int, int]:
    """(Tm, Tn, Tk) grid; split_k multiplies Tk and divides the k extent."""
    k_per_split = cdiv(p.K, t.split_k)
    return cdiv(p.M, t.bm), cdiv(p.N, t.bn), cdiv(k_per_split, t.bk) * t.split_k


# ---------------------------------------------------------------------------
# Alg. 4 — chip-wide occupancy / wave model.
#
# The paper prices wave quantization over ALL CUs of the chip; until this
# stage the model ran one core of one partition, so GPU presets selected as
# if the chip had a single CU.  Work units are scheduled round-robin over
# ``Topology.total_cores()``: under ``data_parallel`` a unit is one
# (output tile, k-shard) — split-K multiplies units, which is exactly its
# GPU rationale — and under ``stream_k`` the flattened k-step space is cut
# into one contiguous strip per core, erasing the tile-granular tail wave
# at the cost of a partial fixup for strip-boundary tiles.
#
# The quantization factor waves * cores / units >= 1 scales every per-core
# term (MXU, staging port, DMA issue); chip-shared memory ports are not
# scaled — a tail wave leaves bandwidth idle, not busy.  On a single-core
# chain the factor is exactly 1.0, reproducing the PR 2 model bit-for-bit.
# ---------------------------------------------------------------------------

def wave_model(p: GemmProblem, t: TileConfig, hw: HardwareSpec,
               grid: Optional[Tuple[int, int, int]] = None
               ) -> Tuple[int, int, float]:
    """Returns (units, waves, quantization factor == waves * cores / units).

    ``data_parallel``: units = output tiles x split_k (each k-shard is an
    independently schedulable workgroup on a multi-core chip).
    ``stream_k``: units = total k-steps — occupancy is quantized at k-step
    granularity, so the factor is ~1 for any problem with >= cores steps.
    Single-core chains: units == waves, factor == 1.0 exactly.
    """
    C = hw.total_cores()
    Tm, Tn, Tk = grid or grid_shape(p, t)
    if t.schedule == "stream_k" and C > 1:
        units = Tm * Tn * Tk * p.batch
    else:
        units = Tm * Tn * p.batch * t.split_k
    waves = cdiv(units, C)
    return units, waves, waves * C / units


# ---------------------------------------------------------------------------
# Alg. 3 — compute latency of one VMEM block (per grid step).
# ---------------------------------------------------------------------------

def step_compute_latency(p: GemmProblem, t: TileConfig, hw: HardwareSpec,
                         grid: Optional[Tuple[int, int, int]] = None
                         ) -> Tuple[float, float]:
    """Returns (mxu_seconds, vmem_seconds) for one grid step.

    MXU term: the block is consumed in ceil-padded MXU atoms (Alg. 3's
    N_MI x L_MI, with L_MI expressed through peak FLOP/s).
    VMEM term: bytes the step streams through the VMEM<->VREG port — both
    input blocks once, plus the f32 accumulator read+write (the accumulator
    lives in VMEM scratch across the k loop), plus the epilogue operands
    (read once per output tile at the flush, amortized over the tile's
    k steps).
    """
    mm, mn, mk = hw.mxu_shape
    n_atoms = cdiv(t.bm, mm) * cdiv(t.bn, mn) * cdiv(t.bk, mk)
    atom_flops = 2.0 * mm * mn * mk
    mxu = n_atoms * atom_flops / hw.flops(p.in_dtype)

    bi = DTYPE_BYTES[p.in_dtype]
    in_bytes = (t.bm * t.bk + t.bk * t.bn) * bi
    acc_bytes = 2 * t.bm * t.bn * ACC_BYTES  # f32 accumulator read + write
    ep = p.epilogue
    _, _, Tk = grid or grid_shape(p, t)
    e_bytes = (ep.n_mn_operands * t.bm * t.bn
               + (t.bn if ep.bias else 0)) * bi / Tk
    vmem = (in_bytes + acc_bytes + e_bytes) / hw.vmem_bandwidth
    return mxu, vmem


# ---------------------------------------------------------------------------
# Alg. 5 adaptation — deterministic revisit/locality model.
# ---------------------------------------------------------------------------

def revisit_fractions(p: GemmProblem, t: TileConfig,
                      grid: Optional[Tuple[int, int, int]] = None
                      ) -> Tuple[float, float]:
    """Fraction of grid steps at which the (A, B) block fetch is *skipped*.

    Iteration order is (m outer, n middle, k inner) with group_m swizzling.
    Pallas skips the HBM->VMEM copy when a block index is unchanged between
    consecutive steps:

    * A block index (i_m, i_k): unchanged iff k and m both unchanged — only
      possible when Tk == 1 and we advance n within the same m.
    * B block index (i_k, i_n): unchanged iff k and n both unchanged — only
      possible when Tk == 1 and we advance m within a group (group_m > 1
      walks m innermost within a group of rows).
    """
    Tm, Tn, Tk = grid or grid_shape(p, t)
    if Tk != 1:
        return 0.0, 0.0
    if t.group_m <= 1:
        # n advances innermost: A revisited for Tn-1 of each row's Tn steps.
        a_skip = (Tn - 1) / Tn if Tn > 0 else 0.0
        return a_skip, 0.0
    # grouped: m advances innermost within groups of size group_m.
    g = min(t.group_m, Tm)
    b_skip = (g - 1) / g
    return 0.0, b_skip


def hbm_traffic(p: GemmProblem, t: TileConfig, *, revisit: bool = True,
                grid: Optional[Tuple[int, int, int]] = None) -> float:
    """Exact fetched+written bytes for the whole GEMM (the all-HBM base).

    Without revisits: A is fetched Tn times over, B Tm times over
    (the paper's "uncached reads" U, Alg. 5, with hit rate applied).
    ``revisit=False`` disables the Pallas revisit skip — on multi-core
    chains consecutive grid steps run on *different* cores, so there is no
    staging-persistence to skip into; the re-reads instead become cache-hit
    candidates (one-tile reuse windows) priced by ``level_traffic``.

    Split-K runs *in-kernel* on a single-core chain (one ``pallas_call``,
    grid ``(tiles, sk, Tk)``, k-shards accumulated in VMEM scratch, single
    flush) so it moves no HBM partials there; multi-core partial/combine
    and stream-K fixup traffic is priced by ``schedule_extra_classes``.
    Epilogue operands (bias / gate / residual) are read once per output
    tile; fused, the output is still written exactly once.
    """
    Tm, Tn, Tk = grid or grid_shape(p, t)
    bi, bo = DTYPE_BYTES[p.in_dtype], DTYPE_BYTES[p.out_dtype]
    a_skip, b_skip = (revisit_fractions(p, t, (Tm, Tn, Tk)) if revisit
                      else (0.0, 0.0))
    # Padded fetch sizes: DMA moves whole blocks (edge blocks move real bytes;
    # we model the exact edge in the simulator, the mean here).
    a_bytes = Tn * (p.M * p.K) * bi * (1.0 - a_skip)
    b_bytes = Tm * (p.K * p.N) * bi * (1.0 - b_skip)
    c_bytes = p.M * p.N * bo
    ep = p.epilogue
    e_bytes = (ep.n_mn_operands * p.M * p.N + (p.N if ep.bias else 0)) * bi
    return p.batch * (a_bytes + b_bytes + c_bytes + e_bytes)


# ---------------------------------------------------------------------------
# Alg. 5-7 generalization — per-level reuse/footprint recurrence.
#
# ``hbm_traffic`` above is the 1-level base: every fetch the revisit model
# does not skip is billed to backing memory.  On a multi-level chain, each
# *re-read* (a fetch of bytes touched before) has a deterministic reuse
# window — the bytes streamed between consecutive uses under the kernel's
# (m outer, n middle, k inner; m innermost within a group) iteration order.
# A re-read whose window fits in cache level ℓ is served from ℓ; otherwise
# it spills to the next-farther level, ultimately to backing memory.  This
# is the paper's cache-tile factorization: it prices group_m as L2 residency
# of the re-walked operand instead of a free menu entry.
#
# The recurrence is formulated as a SUBTRACTION from the all-HBM base so
# that a chain with no cache levels reproduces the seed model bit-for-bit.
# ---------------------------------------------------------------------------

def _spill_classes(p: GemmProblem, t: TileConfig, revisit: bool = True,
                   grid: Optional[Tuple[int, int, int]] = None
                   ) -> List[Tuple[float, float]]:
    """Re-read classes not absorbed by the revisit skip, per batch element.

    Returns ``(bytes, window_bytes)`` pairs.  Iteration order determines the
    windows:

    * ungrouped (g<=1): an A row-panel is re-read on each n-advance with a
      one-tile window (A panel + one B panel); a B column-panel is re-read
      on each m-advance with a full-row window (A panel + ALL B panels).
    * grouped (g>1): A re-reads see a group-pass window (g A panels + one
      B panel); B re-reads within a group see the one-tile window; B
      re-reads across groups see a full group-sweep window.

    With ``revisit=True`` (single-core chains) the classes the Pallas
    revisit model already skips (Tk == 1 cases priced by
    ``revisit_fractions``) are omitted — those fetches never leave staging.
    On multi-core chains (``revisit=False``) no fetch is skipped, so those
    classes join the recurrence with their one-tile windows (they become
    near-certain cache hits instead of free revisits).
    """
    Tm, Tn, Tk = grid or grid_shape(p, t)
    bi = DTYPE_BYTES[p.in_dtype]
    g = min(t.group_m, Tm)
    tile_window = (t.bm + t.bn) * p.K * bi
    out: List[Tuple[float, float]] = []
    if g <= 1:
        if Tn > 1 and (Tk != 1 or not revisit):
            out.append(((Tn - 1) * p.M * p.K * bi, tile_window))
        if Tm > 1:
            out.append(((Tm - 1) * p.K * p.N * bi,
                        (t.bm * p.K + p.K * p.N) * bi))
    else:
        if Tn > 1:
            out.append(((Tn - 1) * p.M * p.K * bi,
                        (g * t.bm + t.bn) * p.K * bi))
        if Tk != 1 or not revisit:
            out.append(((g - 1) / g * Tm * p.K * p.N * bi, tile_window))
        if Tm > g:
            out.append(((Tm / g - 1) * p.K * p.N * bi,
                        (g * t.bm * p.K + p.K * p.N) * bi))
    return out


def _window_scale(hw: HardwareSpec, lvl: MemoryLevel) -> float:
    """Fraction of the chip-wide reuse-window byte stream a cache *instance*
    at this level observes.  Work is scheduled partition-blocked (units
    round-robin over cores, cores blocked per partition), so a
    partition-scoped cache (the MI300X per-XCD L2) sees only its
    1/partitions share of the stream — per-partition L2 scoping.  On a
    single-core chain everything flows through one instance: scale 1.0,
    preserving the PR 2 recurrence bit-for-bit."""
    if hw.total_cores() == 1:
        return 1.0
    if lvl.scope == "partition":
        return 1.0 / hw.partitions
    return 1.0


def _serving_cache(window: float, hw: HardwareSpec
                   ) -> Optional[MemoryLevel]:
    """Nearest cache level whose budget covers the (scope-scaled) reuse
    window, else None (the re-read spills all the way to backing memory)."""
    for lvl in reversed(hw.cache_levels):
        if window * _window_scale(hw, lvl) <= lvl.budget():
            return lvl
    return None


def schedule_extra_classes(p: GemmProblem, t: TileConfig, hw: HardwareSpec,
                           grid: Optional[Tuple[int, int, int]] = None
                           ) -> List[Tuple[float, float]]:
    """Partial-accumulator traffic the schedule adds on multi-core chains,
    as ``(bytes, window)`` pairs for the cache recurrence (whole GEMM,
    batch included).  Empty on single-core chains — split-K is in-kernel
    there and moves no partials.

    * ``data_parallel`` with split_k > 1: a tile's k-shards run on
      different cores, so each shard writes a full f32 block partial and
      the combine re-reads all of them — 2 x split_k x padded-output
      block-bytes.  The combine runs as soon as a tile's last shard lands,
      so the footprint is the tile's split_k partials.
    * ``stream_k``: only tiles split across a strip boundary pay a partial
      write + read.  Strips are ``ceil(steps / cores)`` k-steps; a boundary
      at step ``m*q`` splits a tile iff it is not tile-aligned
      (``m*q % Tk != 0``) — counted exactly via gcd.
    """
    C = hw.total_cores()
    if C == 1:
        return []
    Tm, Tn, Tk = grid or grid_shape(p, t)
    block_acc = t.bm * t.bn * ACC_BYTES
    if t.schedule == "stream_k":
        steps = Tm * Tn * Tk * p.batch
        q = cdiv(steps, C)                       # strip length (k-steps)
        nb = cdiv(steps, q) - 1                  # interior strip boundaries
        aligned = nb // (Tk // math.gcd(q, Tk))  # boundaries at tile edges
        n_split = nb - aligned
        if n_split <= 0:
            return []
        return [(2.0 * n_split * block_acc, float(block_acc))]
    if t.split_k > 1:
        tiles = Tm * Tn * p.batch
        return [(2.0 * t.split_k * tiles * block_acc,
                 float(t.split_k * block_acc))]
    return []


def level_traffic(p: GemmProblem, t: TileConfig, hw: HardwareSpec,
                  grid: Optional[Tuple[int, int, int]] = None
                  ) -> Dict[str, float]:
    """Bytes served from each memory level (backing + caches), whole GEMM:
    the all-HBM base (revisit model on single-core chains) re-routed by the
    reuse/footprint recurrence, plus the schedule's partial/fixup traffic.

    Output writes and epilogue operand reads always go to backing memory
    (write-through; compulsory).  On a 1-level chain the single entry equals
    ``hbm_traffic`` exactly.
    """
    revisit = hw.total_cores() == 1
    served = {lvl.name: 0.0 for lvl in hw.levels[:-1]}
    base = hbm_traffic(p, t, revisit=revisit, grid=grid)
    served[hw.backing.name] = base
    if hw.cache_levels:
        for bytes_, window in _spill_classes(p, t, revisit, grid):
            lvl = _serving_cache(window, hw)
            if lvl is not None:
                b = bytes_ * p.batch
                served[lvl.name] += b
                served[hw.backing.name] -= b
        served[hw.backing.name] = max(served[hw.backing.name], 0.0)
    for bytes_, window in schedule_extra_classes(p, t, hw, grid):
        lvl = _serving_cache(window, hw) if hw.cache_levels else None
        served[lvl.name if lvl is not None else hw.backing.name] += bytes_
    return served


def level_step_seconds(hw: HardwareSpec, served: Mapping[str, float],
                       steps: float) -> Dict[str, float]:
    """Per-grid-step seconds on each level's port.  The hierarchy is
    inclusive: bytes served at level ℓ also cross every port nearer than ℓ,
    so a cache port carries its own hits plus all farther-level traffic."""
    out: Dict[str, float] = {}
    through = 0.0
    for lvl in hw.levels[:-1]:
        through += served.get(lvl.name, 0.0)
        out[lvl.name] = through / lvl.bandwidth / steps
    return out


def epilogue_unfused_extra_bytes(p: GemmProblem) -> float:
    """Extra HBM bytes when the epilogue runs as separate XLA elementwise ops
    after the GEMM instead of inside the flush (DESIGN.md §3).

    Each post-op re-reads and re-writes the full (M, N) output; gate and
    residual ops additionally read their (M, N) operand, bias its (N,) row.
    The fused kernel pays only the operand reads (already in
    ``hbm_traffic``), so the *fusion saving* is exactly this value minus the
    operand reads — i.e. 2*M*N*out_bytes per post-op.
    """
    ep = p.epilogue
    bi, bo = DTYPE_BYTES[p.in_dtype], DTYPE_BYTES[p.out_dtype]
    mn = p.batch * p.M * p.N
    extra = 2.0 * ep.n_ops * mn * bo                 # read + write per op
    extra += ep.n_mn_operands * mn * bi              # gate / residual reads
    if ep.bias:
        extra += p.batch * p.N * bi
    return extra


def reuse_fraction(p: GemmProblem, t: TileConfig,
                   hw: Optional[HardwareSpec] = None) -> float:
    """Paper Alg. 5's hit rate h in [0,1]: 1 - compulsory/actual traffic.

    Pass ``hw`` to price the traffic the selector actually used for that
    chain — the revisit skip is inert on multi-core topologies, and the
    schedule's partial/fixup bytes count as traffic there."""
    if hw is None or hw.total_cores() == 1:
        actual = hbm_traffic(p, t)
    else:
        actual = hbm_traffic(p, t, revisit=False) \
            + sum(b for b, _ in schedule_extra_classes(p, t, hw))
    return max(0.0, min(1.0, 1.0 - p.min_bytes / actual)) if actual else 0.0


# ---------------------------------------------------------------------------
# Alg. 7 — memory latency of a loop iteration (per grid step, averaged).
# ---------------------------------------------------------------------------

def step_memory_latency(p: GemmProblem, t: TileConfig, hw: HardwareSpec,
                        grid: Optional[Tuple[int, int, int]] = None
                        ) -> Tuple[Dict[str, float], float, Dict[str, float]]:
    """Returns (per-level step seconds, issue_seconds, per-level served
    bytes) averaged over grid steps.

    Output writes are folded in amortized: each (m,n) tile writes bm*bn once
    per Tk steps. The fixed DMA-issue cost is the paper's load/store
    issue-rate axis.  Memory levels pipeline against each other, so the
    effective memory-side step time is the max of the per-level entries.
    """
    Tm, Tn, Tk = grid or grid_shape(p, t)
    steps = Tm * Tn * Tk * p.batch
    served = level_traffic(p, t, hw, (Tm, Tn, Tk))
    return level_step_seconds(hw, served, steps), hw.dma_fixed, served


# ---------------------------------------------------------------------------
# Max-plus DMA/compute overlap pricing (multi-core steady state).
#
# The seed model prices the memory side of a grid step as a per-level MEAN:
# all traffic a level serves over the whole GEMM, divided by its bandwidth
# and the step count, with levels pipelining as a max over ports.  That mean
# hides the phase structure of the fetch stream: under the (m outer,
# n middle, k inner; m innermost within a group) iteration order, each
# operand's re-read alternates between a *hit phase* — the reuse window fits
# a cache, the fetch streams at that cache's bandwidth — and a *miss phase*
# at backing-memory bandwidth (the first touch of each panel).  Because the
# grid pipeline double-buffers (the DMA of block i+1 overlaps the compute of
# block i — the same discipline the event simulator prices), the steady
# state is the max-plus recurrence
#
#     t_i = max(t_{i-1} + compute, dma_done_i)
#   => step = max(compute_occ, a_step/bw_A + b_step/bw_B + issue_occ)
#
# evaluated per *phase pair* (which level serves A x which serves B) and
# mixed by the phase frequencies — NOT a single mean over the whole loop.
# The phase classes follow ``_spill_classes``; the reuse windows start from
# its sequential footprints and add the flush bytes the exact-LRU stack in
# the event simulator measures between reuses (see the window block in
# ``overlap_pipeline_arrays``):
#
#   A: hit phase on every n-advance, weight (Tn-1)/Tn, window
#      (g*bm + bn)*K*bi grouped / (bm + bn)*K*bi ungrouped; miss phase
#      (first column of each row-panel pass) weight 1/Tn at backing.
#   B: ungrouped — hit on every m-advance, weight (Tm-1)/Tm, window
#      (bm*K + K*N)*bi; grouped — in-group hit weight (g-1)/g with the
#      one-tile window, cross-group hit weight (Tm/g-1)/Tm with the
#      group-sweep window (g*bm*K + K*N)*bi; miss weight 1/Tm at backing.
#
# Output writes, epilogue operand reads and the schedule's partial/fixup
# bytes ride their own flush cursor: they overlap the fetch pipeline (a
# write posts while the next fetch streams) but their bytes still have to
# drain through their serving port, so they price as an ADDITIVE term at
# the serving level's bandwidth instead of inflating every step.
#
# Single-core chains (TPU) never enter this path — the selector keeps the
# seed's mean model bit-for-bit there (goldens pin this).
# ---------------------------------------------------------------------------

def _serve_bandwidth_arrays(hw: HardwareSpec, win) -> np.ndarray:
    """Bandwidth serving a re-read with reuse-window footprint ``win``: the
    nearest cache level whose (scope-scaled) budget covers the window, else
    backing memory — the array form of ``_serving_cache``.  Accepts scalars
    or any broadcastable window array."""
    caches = hw.cache_levels
    bw = np.full(np.shape(win), float(hw.backing.bandwidth))
    assigned = np.zeros(np.shape(win), bool)
    for li in range(len(caches) - 1, -1, -1):          # nearest cache first
        fit = ~assigned & (win * _window_scale(hw, caches[li])
                           <= caches[li].budget())
        bw = np.where(fit, caches[li].bandwidth, bw)
        assigned |= fit
    return bw


def overlap_pipeline_arrays(p, hw: HardwareSpec, Tm, Tn, bm, bn, gm, steps,
                            cs_occ, issue_occ, a_traffic, b_traffic,
                            flush_base, extra):
    """Price the multi-core steady-state grid loop with the max-plus
    DMA/compute overlap recurrence (see the block comment above).

    ``p`` supplies ``K``/``N``/``in_dtype`` and may be a scalar
    :class:`GemmProblem` or a :class:`ShapeBatch` of columns; all other
    arguments are scalars or mutually broadcastable arrays, so one helper
    serves the scalar, per-candidate-vector and (S, P)-batched scoring
    copies with elementwise-identical arithmetic.

    ``cs_occ``   — occupancy-scaled compute side max(mxu, vmem) * occ.
    ``issue_occ``— occupancy-scaled fixed DMA-issue cost per step.
    ``a_traffic``/``b_traffic`` — whole-GEMM fetched bytes per operand
    (revisit-free: nothing persists in staging across cores).
    ``flush_base`` — compulsory flush bytes (output writes + epilogue
    operand reads), always served by backing memory.
    ``extra`` — ``(bytes, window)`` pairs from ``schedule_extra_classes``
    (or its array form), flushed at their serving level's bandwidth.

    Returns ``(steps_seconds, flush_seconds)``.
    """
    bi = DTYPE_BYTES[p.in_dtype]
    K, N = p.K, p.N
    Kbi = np.asarray(K * bi, np.float64)
    KN = np.asarray(K * N, np.float64)
    g = np.minimum(np.maximum(gm, 1), Tm).astype(np.float64)
    gle1 = g <= 1
    ggt1 = ~gle1
    Tmf = np.asarray(Tm, np.float64)
    Tnf = np.asarray(Tn, np.float64)

    # Phase windows: ``_spill_classes``'s sequential-reuse footprints PLUS
    # the bytes the event simulator's exact LRU stack actually measures
    # between reuses and the seed windows omit — the output/epilogue flush
    # of every tile retired inside the window (``record_use("wb", ...)``
    # keys circulate through the same stack as the panels) and, for the
    # cross-row/cross-band B windows, the NEXT row's A panels (touched
    # before the B panel comes back around).  On the H100-like preset the
    # L2 budget sits inside the gap: a (bm=256, bn=128) sweep measures
    # ~41 MB between B reuses (spills) where the seed window said 35 MB
    # (fits), while (bm=128, bn=256) measures ~35 MB and genuinely fits —
    # the flush-blind windows priced both as hits and flipped the argmin.
    bo = DTYPE_BYTES[p.out_dtype]
    ep = p.epilogue
    wbe = float(bo + ep.n_mn_operands * bi)  # flush bytes per output element
    bias_bi = float(int(ep.bias) * bi)
    wb_tile = bm * bn * wbe + bn * bias_bi   # one tile's flush footprint
    wb_row = bm * N * wbe + N * bias_bi      # a full row-sweep (Tn tiles)
    win_a = np.where(ggt1,
                     (g * bm + bn) * Kbi + g * wb_tile,
                     (bm + bn) * Kbi + wb_tile)
    win_b1 = np.where(gle1,
                      (2.0 * bm * K + K * N) * float(bi) + wb_row,
                      (bm + bn) * Kbi + wb_tile)
    win_b2 = (2.0 * g * bm * K + KN) * bi + g * wb_row
    back_bw = float(hw.backing.bandwidth)
    # (weight, serving bandwidth) per phase; weights sum to 1 per operand.
    a_phases = (((Tnf - 1.0) / Tnf, _serve_bandwidth_arrays(hw, win_a)),
                (1.0 / Tnf, back_bw))
    b_phases = ((np.where(gle1, (Tmf - 1.0) / Tmf, (g - 1.0) / g),
                 _serve_bandwidth_arrays(hw, win_b1)),
                (np.where(gle1, 0.0, (Tmf / g - 1.0) / Tmf),
                 _serve_bandwidth_arrays(hw, win_b2)),
                (1.0 / Tmf, back_bw))
    a_ps = a_traffic / steps
    b_ps = b_traffic / steps
    body = 0.0
    for wa, bw_a in a_phases:
        for wb, bw_b in b_phases:
            body = body + wa * wb * np.maximum(
                cs_occ, a_ps / bw_a + b_ps / bw_b + issue_occ)
    flush = flush_base / back_bw
    for bytes_, win in extra:
        flush = flush + bytes_ / _serve_bandwidth_arrays(hw, win)
    return steps * body, flush


# ---------------------------------------------------------------------------
# Alg. 8 + 9 — pipeline + total latency (continuous grid pipeline).
# ---------------------------------------------------------------------------

def gemm_latency(p: GemmProblem, t: TileConfig, hw: HardwareSpec
                 ) -> LatencyBreakdown:
    grid = Tm, Tn, Tk = grid_shape(p, t)
    steps = Tm * Tn * Tk * p.batch

    mxu_s, vmem_s = step_compute_latency(p, t, hw, grid)
    level_s, issue_s, served = step_memory_latency(p, t, hw, grid)
    hbm_s = level_s[hw.backing.name]
    mem_s = max(level_s.values())

    # Alg. 4 occupancy stage: per-core terms (MXU, staging port, DMA issue)
    # pay the tail-wave quantization factor; chip-shared memory ports do
    # not.  occ == 1.0 exactly on single-core chains (PR 2 parity).
    units, waves, occ = wave_model(p, t, hw, grid)
    compute_side = max(mxu_s, vmem_s) * occ
    memory_side = mem_s + issue_s * occ
    l_iter = max(compute_side, memory_side)           # software pipeline

    # Prologue: first block fetch cannot be hidden (paper Alg. 8 L_prologue);
    # epilogue: final accumulator flush. Both once per *pipeline*, because the
    # Pallas grid pipeline is continuous across output tiles.
    bi, bo = DTYPE_BYTES[p.in_dtype], DTYPE_BYTES[p.out_dtype]
    prologue = hw.hbm_latency + (t.bm * t.bk + t.bk * t.bn) * bi / hw.hbm_bandwidth
    epilogue = hw.hbm_latency + t.bm * t.bn * bo / hw.hbm_bandwidth
    fill_drain = hw.kernel_launch + prologue + epilogue

    # Steady state: single-core chains keep the seed's mean memory-step
    # model bit-for-bit; multi-core chains price the loop with the max-plus
    # DMA/compute overlap recurrence plus the flush cursor.
    if hw.total_cores() > 1:
        epl = p.epilogue
        a_tr = p.batch * (Tn * (p.M * p.K) * bi)
        b_tr = p.batch * (Tm * (p.K * p.N) * bi)
        flush_base = p.batch * (p.M * p.N * bo
                                + (epl.n_mn_operands * p.M * p.N
                                   + (p.N if epl.bias else 0)) * bi)
        body, flush_s = overlap_pipeline_arrays(
            p, hw, Tm, Tn, t.bm, t.bn, t.group_m, float(steps),
            compute_side, issue_s * occ, a_tr, b_tr, flush_base,
            schedule_extra_classes(p, t, hw, grid))
        flush_s = float(flush_s)
        total = fill_drain + float(body) + flush_s
    else:
        flush_s = 0.0
        total = fill_drain + steps * l_iter

    mm, mn, mk = hw.mxu_shape
    padded_flops = (2.0 * p.batch
                    * round_up(p.M, t.bm) * round_up(p.N, t.bn)
                    * round_up(cdiv(p.K, t.split_k), t.bk) * t.split_k)
    # ^ padding waste: ceil to blocks (blocks then ceil to atoms; blocks are
    # atom-aligned by construction of the candidate space).

    level_seconds = {name: steps * s for name, s in level_s.items()}
    terms = {
        "mxu_compute": steps * mxu_s * occ,
        "vmem_bandwidth": steps * vmem_s * occ,
        "hbm_bandwidth": steps * hbm_s,
        "dma_issue": steps * issue_s * occ,
        "pipeline_fill": fill_drain,
    }
    for lvl in hw.cache_levels:
        terms[f"{lvl.name}_bandwidth"] = level_seconds[lvl.name]
    bottleneck = max(terms, key=terms.get)

    return LatencyBreakdown(
        total=total,
        compute=terms["mxu_compute"],
        vmem=terms["vmem_bandwidth"],
        hbm=terms["hbm_bandwidth"],
        issue=terms["dma_issue"],
        fill_drain=fill_drain,
        hbm_traffic=served[hw.backing.name],
        padded_flops=padded_flops,
        bottleneck=bottleneck,
        level_bytes=served,
        level_seconds=level_seconds,
        units=units,
        waves=waves,
        occupancy=units / (waves * hw.total_cores()),
        flush=flush_s,
    )


def gemm_latency_batch(problems: Sequence[GemmProblem],
                       tiles: Sequence[TileConfig], hw: HardwareSpec
                       ) -> List[LatencyBreakdown]:
    """``gemm_latency`` for S (problem, tile) pairs in one numpy pass —
    the repricing leg of ``selector.select_gemm_config_batch`` (each cold
    winner needs its full breakdown for the :class:`Selection` record).

    Problems must share dtypes and epilogue (the ``ShapeBatch`` contract).
    Every field of every returned breakdown is BIT-IDENTICAL to the scalar
    call: the (S,) int64/float64 columns run the exact elementwise IEEE op
    sequence of ``gemm_latency`` and its helpers — data-dependent branches
    become ``np.where`` selections whose taken values match the scalar
    branch, absent traffic classes contribute exact 0.0 terms, and the
    per-level serve/subtract order of ``level_traffic`` is preserved class
    by class.  ``tests/test_batch_selection.py`` pins hex-exact parity."""
    S = len(problems)
    if S == 0:
        return []
    p0 = problems[0]
    for p in problems:
        if (p.in_dtype, p.out_dtype, p.epilogue) != \
                (p0.in_dtype, p0.out_dtype, p0.epilogue):
            raise ValueError(
                f"gemm_latency_batch requires uniform dtypes/epilogue; "
                f"got {p} vs {p0}")
    cols = np.asarray(
        [(p.M, p.N, p.K, p.batch, t.bm, t.bn, t.bk, t.split_k, t.group_m,
          t.schedule == "stream_k") for p, t in zip(problems, tiles)],
        np.int64).T
    M, N, K, B, bm, bn, bk, sk, gm_ = cols[:9]
    stream = cols[9].astype(bool)
    bi, bo = DTYPE_BYTES[p0.in_dtype], DTYPE_BYTES[p0.out_dtype]
    ep = p0.epilogue
    C = hw.total_cores()

    Tm = -(-M // bm)
    Tn = -(-N // bn)
    kps = -(-K // sk)
    Tk = -(-kps // bk) * sk
    steps = Tm * Tn * Tk * B

    # step_compute_latency
    mm, mn, mk = hw.mxu_shape
    n_atoms = (-(-bm // mm)) * (-(-bn // mn)) * (-(-bk // mk))
    mxu_s = n_atoms * (2.0 * mm * mn * mk) / hw.flops(p0.in_dtype)
    in_bytes = (bm * bk + bk * bn) * bi
    acc_b = 2 * bm * bn * ACC_BYTES
    e_vmem = (ep.n_mn_operands * bm * bn
              + (bn if ep.bias else 0)) * bi / Tk
    vmem_s = (in_bytes + acc_b + e_vmem) / hw.vmem_bandwidth

    # hbm_traffic base (revisit_fractions inert on multi-core chains)
    revisit = C == 1
    if revisit:
        tk1 = Tk == 1
        gmin = np.minimum(gm_, Tm)
        a_skip = np.where(tk1 & (gm_ <= 1), (Tn - 1) / Tn, 0.0)
        b_skip = np.where(tk1 & (gm_ > 1), (gmin - 1) / gmin, 0.0)
    else:
        a_skip = b_skip = 0.0
    a_b = Tn * (M * K) * bi * (1.0 - a_skip)
    b_b = Tm * (K * N) * bi * (1.0 - b_skip)
    c_b = M * N * bo
    e_b = (ep.n_mn_operands * M * N + (N if ep.bias else 0)) * bi
    base = B * (a_b + b_b + c_b + e_b)

    # schedule extras (empty on single-core chains), as zero-padded classes
    extra: List[Tuple[np.ndarray, np.ndarray]] = []
    if C > 1:
        block_acc = (bm * bn * ACC_BYTES).astype(np.float64)
        if stream.any():
            q = -(-steps // C)
            nb = -(-steps // q) - 1
            aligned = nb // (Tk // np.gcd(q, Tk))
            n_split = np.where(stream, nb - aligned, 0)
            extra.append((2.0 * n_split * block_acc, block_acc))
        comb = (~stream) & (sk > 1)
        if comb.any():
            tiles_n = Tm * Tn * B
            extra.append((np.where(comb, 2.0 * sk * tiles_n * block_acc,
                                   0.0), sk * block_acc))

    # level_traffic: serve spill classes nearest-cache-first, subtracting
    # each served class from backing in class order (scalar op order).
    served: Dict[str, np.ndarray] = {
        lvl.name: np.zeros(S, np.float64) for lvl in hw.levels[:-1]}
    backing = hw.backing.name
    served[backing] = base + np.zeros(S, np.float64)
    caches = hw.cache_levels
    if caches:
        gsp = np.minimum(np.maximum(gm_, 1), Tm)     # _spill_classes' g
        gle1 = gsp <= 1
        ggt1 = ~gle1
        tk1s = (Tk == 1) if revisit else np.zeros(S, bool)
        MKbi = np.asarray(M * K * bi, np.float64)
        KNbi = np.asarray(K * N * bi, np.float64)
        Kbi = np.asarray(K * bi, np.float64)
        KN = np.asarray(K * N, np.float64)
        sp_a = np.where(gle1 & tk1s, 0.0, (Tn - 1) * MKbi)
        sp_a_win = np.where(ggt1, (gsp * bm + bn) * Kbi, (bm + bn) * Kbi)
        sp_b1 = np.where(gle1, (Tm - 1) * KNbi,
                         np.where(tk1s, 0.0,
                                  (gsp - 1) / gsp * Tm * K * N * bi))
        sp_b1_win = np.where(gle1, (bm * K + K * N) * float(bi),
                             (bm + bn) * Kbi)
        sp_b2 = np.where(ggt1,
                         np.maximum(Tm / gsp - 1.0, 0.0) * K * N * bi, 0.0)
        sp_b2_win = (gsp * bm * K + KN) * bi
        scales = [_window_scale(hw, lvl) for lvl in caches]
        for bytes_, win in ((sp_a * B, sp_a_win), (sp_b1 * B, sp_b1_win),
                            (sp_b2 * B, sp_b2_win)):
            assigned = np.zeros(S, bool)
            for li in range(len(caches) - 1, -1, -1):  # nearest cache first
                fit = ~assigned & (win * scales[li] <= caches[li].budget())
                served[caches[li].name] = served[caches[li].name] \
                    + np.where(fit, bytes_, 0.0)
                assigned |= fit
            served[backing] = served[backing] \
                - np.where(assigned, bytes_, 0.0)
        served[backing] = np.maximum(served[backing], 0.0)
        for bytes_, win in extra:
            assigned = np.zeros(S, bool)
            for li in range(len(caches) - 1, -1, -1):
                fit = ~assigned & (win * scales[li] <= caches[li].budget())
                served[caches[li].name] = served[caches[li].name] \
                    + np.where(fit, bytes_, 0.0)
                assigned |= fit
            served[backing] = served[backing] \
                + np.where(assigned, 0.0, bytes_)
    else:
        for bytes_, _ in extra:
            served[backing] = served[backing] + bytes_

    # level_step_seconds (inclusive hierarchy) + mem_s = max over ports
    level_s: Dict[str, np.ndarray] = {}
    through = np.zeros(S, np.float64)
    for lvl in hw.levels[:-1]:
        through = through + served[lvl.name]
        level_s[lvl.name] = through / lvl.bandwidth / steps
    hbm_s = level_s[backing]
    mem_s: Optional[np.ndarray] = None
    for v in level_s.values():
        mem_s = v if mem_s is None else np.maximum(mem_s, v)

    # wave_model + pipeline (Alg. 8/9).  On single-core chains occ == 1.0
    # exactly (units == waves), so every ``* occ`` is the float identity
    # x * 1.0 == x and can be elided bit-exactly.
    issue_s = hw.dma_fixed
    if C > 1:
        units = np.where(stream, Tm * Tn * Tk * B, Tm * Tn * B * sk)
        waves = -(-units // C)
        occ = waves * C / units
        compute_side = np.maximum(mxu_s, vmem_s) * occ
        memory_side = mem_s + issue_s * occ
    else:
        units = Tm * Tn * B * sk
        waves = units
        occ = 1.0
        compute_side = np.maximum(mxu_s, vmem_s)
        memory_side = mem_s + issue_s
    l_iter = np.maximum(compute_side, memory_side)
    prologue = hw.hbm_latency + (bm * bk + bk * bn) * bi / hw.hbm_bandwidth
    epilog = hw.hbm_latency + bm * bn * bo / hw.hbm_bandwidth
    fill_drain = hw.kernel_launch + prologue + epilog
    if C > 1:
        # Max-plus overlap steady state + flush cursor (mirrors the scalar
        # ``gemm_latency`` branch op for op — hex parity is pinned).
        pb_view = ShapeBatch(M=M, N=N, K=K, batch=B, in_dtype=p0.in_dtype,
                             out_dtype=p0.out_dtype, epilogue=ep)
        a_tr = B * (Tn * (M * K) * bi)
        b_tr = B * (Tm * (K * N) * bi)
        flush_base = B * (c_b + e_b)
        body, flush_a = overlap_pipeline_arrays(
            pb_view, hw, Tm, Tn, bm, bn, gm_, steps, compute_side,
            issue_s * occ, a_tr, b_tr, flush_base, extra)
        total = fill_drain + body + flush_a
        flush_l = np.broadcast_to(flush_a, (S,)).tolist()
    else:
        total = fill_drain + steps * l_iter
        flush_l = [0.0] * S
    padded_flops = (2.0 * B
                    * (-(-M // bm) * bm) * (-(-N // bn) * bn)
                    * (-(-(-(-K // sk)) // bk) * bk) * sk)

    # Per-row assembly: extract columns once; the bottleneck argmax is
    # vectorized (np.argmax first-max tie-break == dict-insertion-order max
    # of the scalar ``terms`` dict, built in the identical key order).
    cache_names = [lvl.name for lvl in caches]
    lvl_names = [lvl.name for lvl in hw.levels[:-1]]
    served_l = {n: served[n].tolist() for n in lvl_names}
    level_sec = {n: steps * level_s[n] for n in lvl_names}
    level_sec_l = {n: level_sec[n].tolist() for n in lvl_names}
    t_mxu_a = steps * mxu_s * occ if C > 1 else steps * mxu_s
    t_vmem_a = steps * vmem_s * occ if C > 1 else steps * vmem_s
    t_issue_a = steps * issue_s * occ if C > 1 else steps * issue_s
    term_names = ["mxu_compute", "vmem_bandwidth", "hbm_bandwidth",
                  "dma_issue", "pipeline_fill"] \
        + [f"{n}_bandwidth" for n in cache_names]
    term_cols = [t_mxu_a, t_vmem_a, level_sec[backing], t_issue_a,
                 fill_drain] + [level_sec[n] for n in cache_names]
    bot_idx = np.argmax(np.stack(term_cols), axis=0).tolist()
    t_mxu = t_mxu_a.tolist()
    t_vmem = t_vmem_a.tolist()
    t_hbm = level_sec_l[backing]
    t_issue = t_issue_a.tolist()
    fd_l = fill_drain.tolist()
    tot_l = total.tolist()
    pf_l = padded_flops.tolist()
    units_l, waves_l = units.tolist(), waves.tolist()
    occup_l = ((units / (waves * C)).tolist() if C > 1 else [1.0] * S)
    out: List[LatencyBreakdown] = []
    for i in range(S):
        out.append(LatencyBreakdown(
            total=tot_l[i],
            compute=t_mxu[i],
            vmem=t_vmem[i],
            hbm=t_hbm[i],
            issue=t_issue[i],
            fill_drain=fd_l[i],
            hbm_traffic=served_l[backing][i],
            padded_flops=pf_l[i],
            bottleneck=term_names[bot_idx[i]],
            level_bytes={n: served_l[n][i] for n in lvl_names},
            level_seconds={n: level_sec_l[n][i] for n in lvl_names},
            units=units_l[i],
            waves=waves_l[i],
            occupancy=occup_l[i],
            flush=flush_l[i],
        ))
    return out


def score_candidate(p: GemmProblem, t: TileConfig, hw: HardwareSpec) -> float:
    """Fast path of ``gemm_latency`` returning only total seconds.

    Identical arithmetic, no dataclass allocation — used to rank the whole
    candidate space in O(P) with per-candidate cost in the ~µs range (the
    paper's selection-overhead claim, Table II).

    NB: this formula exists in three hand-synced copies — here, the
    vectorized ``score_candidates``/``score_candidate_arrays`` below, and
    the static-term-cached ``selector.select_fast`` — change all three;
    parity is pinned by tests/test_selector.py."""
    bm, bn, bk = t.bm, t.bn, t.bk
    Tm = -(-p.M // bm)
    Tn = -(-p.N // bn)
    k_per_split = -(-p.K // t.split_k)
    Tk = -(-k_per_split // bk) * t.split_k
    steps = Tm * Tn * Tk * p.batch

    mm, mn, mk = hw.mxu_shape
    n_atoms = (-(-bm // mm)) * (-(-bn // mn)) * (-(-bk // mk))
    mxu_s = n_atoms * (2.0 * mm * mn * mk) / hw.flops(p.in_dtype)

    bi = DTYPE_BYTES[p.in_dtype]
    bo = DTYPE_BYTES[p.out_dtype]
    ep = p.epilogue
    n_mn, has_bias = ep.n_mn_operands, int(ep.bias)
    e_vmem = (n_mn * bm * bn + has_bias * bn) * bi / Tk
    vmem_s = ((bm * bk + bk * bn) * bi + 2.0 * ACC_BYTES * bm * bn
              + e_vmem) / hw.vmem_bandwidth

    # revisit fractions (inlined; inert on multi-core chains — consecutive
    # grid steps run on different cores, nothing persists in staging)
    revisit = hw.total_cores() == 1
    if Tk != 1 or not revisit:
        a_skip = b_skip = 0.0
    elif t.group_m <= 1:
        a_skip, b_skip = ((Tn - 1) / Tn if Tn else 0.0), 0.0
    else:
        g = min(t.group_m, Tm)
        a_skip, b_skip = 0.0, (g - 1) / g
    a_bytes = Tn * (p.M * p.K) * bi * (1.0 - a_skip)
    b_bytes = Tm * (p.K * p.N) * bi * (1.0 - b_skip)
    c_bytes = p.M * p.N * bo
    e_bytes = (n_mn * p.M * p.N + has_bias * p.N) * bi
    traffic = p.batch * (a_bytes + b_bytes + c_bytes + e_bytes)

    extra = schedule_extra_classes(p, t, hw)
    _, _, occ = wave_model(p, t, hw)
    prologue = hw.hbm_latency + (bm * bk + bk * bn) * bi / hw.hbm_bandwidth
    epilogue = hw.hbm_latency + bm * bn * bo / hw.hbm_bandwidth
    if hw.total_cores() > 1:
        # Max-plus overlap steady state + flush cursor (multi-core chains).
        body, flush = overlap_pipeline_arrays(
            p, hw, Tm, Tn, bm, bn, t.group_m, float(steps),
            max(mxu_s, vmem_s) * occ, hw.dma_fixed * occ,
            p.batch * a_bytes, p.batch * b_bytes,
            p.batch * (c_bytes + e_bytes), extra)
        return (hw.kernel_launch + prologue + epilogue
                + float(body) + float(flush))
    if hw.cache_levels:
        # reuse/footprint recurrence: cache-served re-reads leave HBM.
        absorbed: Dict[str, float] = {}
        hbm_bytes = traffic
        for bytes_, window in _spill_classes(p, t, revisit):
            lvl = _serving_cache(window, hw)
            if lvl is not None:
                served = bytes_ * p.batch
                absorbed[lvl.name] = absorbed.get(lvl.name, 0.0) + served
                hbm_bytes -= served
        hbm_bytes = max(hbm_bytes, 0.0)
        for bytes_, window in extra:
            lvl = _serving_cache(window, hw)
            if lvl is not None:
                absorbed[lvl.name] = absorbed.get(lvl.name, 0.0) + bytes_
            else:
                hbm_bytes += bytes_
        mem_s = hbm_bytes / hw.hbm_bandwidth / steps
        through = hbm_bytes
        for lvl in hw.cache_levels:
            through += absorbed.get(lvl.name, 0.0)
            mem_s = max(mem_s, through / lvl.bandwidth / steps)
    else:
        traffic += sum(b for b, _ in extra)
        mem_s = traffic / hw.hbm_bandwidth / steps
    l_iter = max(max(mxu_s, vmem_s) * occ, mem_s + hw.dma_fixed * occ)
    return hw.kernel_launch + prologue + epilogue + steps * l_iter


def _schedule_extra_arrays(p: GemmProblem, hw: HardwareSpec,
                           Tm: np.ndarray, Tn: np.ndarray, Tk: np.ndarray,
                           bm: np.ndarray, bn: np.ndarray, sk: np.ndarray,
                           sched: np.ndarray
                           ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Vectorized ``schedule_extra_classes``: (bytes, window) column pairs
    for the data-parallel split-K combine and the stream-K strip fixup.
    Empty on single-core chains."""
    C = hw.total_cores()
    if C == 1:
        return []
    block_acc = (bm * bn * ACC_BYTES).astype(np.float64)
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    stream = sched == SCHEDULES.index("stream_k")
    if stream.any():
        steps_i = Tm * Tn * Tk * p.batch
        q = -(-steps_i // C)
        nb = -(-steps_i // q) - 1
        aligned = nb // (Tk // np.gcd(q, Tk))
        n_split = np.where(stream, nb - aligned, 0)
        out.append((2.0 * n_split * block_acc, block_acc))
    comb = (~stream) & (sk > 1)
    if comb.any():
        tiles = Tm * Tn * p.batch
        out.append((np.where(comb, 2.0 * sk * tiles * block_acc, 0.0),
                    sk * block_acc))
    return out


def memory_step_seconds_arrays(p: GemmProblem, hw: HardwareSpec,
                               traffic: np.ndarray, Tm: np.ndarray,
                               Tn: np.ndarray, Tk: np.ndarray,
                               bm: np.ndarray, bn: np.ndarray,
                               gm: np.ndarray, steps: np.ndarray,
                               sk: Optional[np.ndarray] = None,
                               sched: Optional[np.ndarray] = None
                               ) -> np.ndarray:
    """Vectorized memory-side step seconds over candidate column arrays:
    the per-level reuse/footprint recurrence (``_spill_classes`` +
    ``_serving_cache``) plus the schedule's partial/fixup traffic, in one
    numpy pass — shared by ``score_candidate_arrays`` and
    ``selector.select_fast``.

    ``traffic`` is the all-HBM base (revisit model applied by the caller —
    inert on multi-core chains).  ``sk``/``sched`` feed the combine/fixup
    classes; omitted they default to sk=1 data_parallel.  Chains with no
    cache level return the seed's exact expression — bit-for-bit parity on
    1-level topologies.

    ``p`` may be a scalar :class:`GemmProblem` or a :class:`ShapeBatch`
    of (S, 1) columns — with (S, P)-broadcast ``Tm``/``Tn``/... the same
    expressions score S problems in one pass, rows bit-identical to S
    scalar calls (``selector.select_fast_batch``)."""
    if sk is None:
        sk = np.ones_like(Tm)
    if sched is None:
        sched = np.zeros_like(Tm)
    extra = _schedule_extra_arrays(p, hw, Tm, Tn, Tk, bm, bn, sk, sched)
    if not hw.cache_levels:
        if extra:
            traffic = traffic + sum(b for b, _ in extra)
        return traffic / hw.hbm_bandwidth / steps
    revisit = hw.total_cores() == 1
    bi = DTYPE_BYTES[p.in_dtype]
    M, N, K = p.M, p.N, p.K
    # Shape dims may be python ints (GemmProblem) or (S, 1) int64 columns
    # (ShapeBatch).  np.asarray(..., float64) covers both and is exact for
    # either (every product < 2**53), preserving the scalar path's IEEE op
    # order bit-for-bit.
    MKbi = np.asarray(M * K * bi, np.float64)
    KNbi = np.asarray(K * N * bi, np.float64)
    Kbi = np.asarray(K * bi, np.float64)
    KN = np.asarray(K * N, np.float64)
    g = np.minimum(np.maximum(gm, 1), Tm).astype(np.float64)
    gle1 = g <= 1          # clamped, matching _spill_classes' g = min(gm, Tm)
    ggt1 = ~gle1
    # Revisit only suppresses re-read classes on single-core chains.
    tk1 = (Tk == 1) if revisit else np.zeros(np.shape(Tk), bool)
    # Re-read classes: bytes (per batch element) + reuse-window footprints,
    # mirroring _spill_classes.  Revisit-skipped classes zero out.
    a_bytes = np.where(gle1 & tk1, 0.0, (Tn - 1) * MKbi)
    a_win = np.where(ggt1, (g * bm + bn) * Kbi,
                     (bm + bn) * Kbi)
    b1_bytes = np.where(
        gle1, (Tm - 1) * KNbi,
        np.where(tk1, 0.0, (g - 1) / g * Tm * KNbi))
    b1_win = np.where(gle1, (bm * K + K * N) * float(bi),
                      (bm + bn) * Kbi)
    b2_bytes = np.where(ggt1,
                        np.maximum(Tm / g - 1.0, 0.0) * KNbi,
                        0.0)
    b2_win = (g * bm * K + KN) * bi
    caches = hw.cache_levels
    scales = [_window_scale(hw, lvl) for lvl in caches]
    absorbed: List = [0.0] * len(caches)
    # Spill classes: cache-served re-reads LEAVE the all-HBM base.
    for b, win in ((a_bytes * p.batch, a_win), (b1_bytes * p.batch, b1_win),
                   (b2_bytes * p.batch, b2_win)):
        assigned = np.zeros(np.shape(win), bool)
        for li in range(len(caches) - 1, -1, -1):      # nearest cache first
            fit = ~assigned & (win * scales[li] <= caches[li].budget())
            absorbed[li] = absorbed[li] + np.where(fit, b, 0.0)
            assigned |= fit
    hbm_bytes = np.maximum(traffic - sum(ab for ab in absorbed), 0.0)
    # Schedule extras were never in the base: ADD them at the serving level
    # (or to HBM when no cache window fits).
    for b, win in extra:
        assigned = np.zeros(np.shape(win), bool)
        for li in range(len(caches) - 1, -1, -1):
            fit = ~assigned & (win * scales[li] <= caches[li].budget())
            absorbed[li] = absorbed[li] + np.where(fit, b, 0.0)
            assigned |= fit
        hbm_bytes = hbm_bytes + np.where(assigned, 0.0, b)
    mem = hbm_bytes / hw.hbm_bandwidth
    through = hbm_bytes
    for li, lvl in enumerate(caches):
        through = through + absorbed[li]
        mem = np.maximum(mem, through / lvl.bandwidth)
    return mem / steps


def occupancy_arrays(p: GemmProblem, hw: HardwareSpec, Tm: np.ndarray,
                     Tn: np.ndarray, sk: np.ndarray,
                     sched: np.ndarray, steps_i: np.ndarray):
    """Vectorized ``wave_model`` quantization factor (waves*cores/units >= 1)
    over candidate columns.  Returns the scalar 1.0 on single-core chains so
    multiplying by it is bit-exact (PR 2 parity)."""
    C = hw.total_cores()
    if C == 1:
        return 1.0
    stream = sched == SCHEDULES.index("stream_k")
    units = np.where(stream, steps_i, Tm * Tn * p.batch * sk)
    waves = -(-units // C)
    return waves * C / units


def score_candidates(p: GemmProblem, tiles: Sequence[TileConfig],
                     hw: HardwareSpec) -> np.ndarray:
    """Vectorized ``score_candidate``: one numpy pass over the whole candidate
    array instead of a Python loop — this is what makes *cold* selection cheap
    (the paper's Table II selection-overhead claim; the cached path was always
    ~1 µs).  Returns total seconds per candidate, same arithmetic as the
    scalar path (float64 throughout, identical operation structure)."""
    n = len(tiles)
    bm = np.fromiter((t.bm for t in tiles), np.int64, n)
    bn = np.fromiter((t.bn for t in tiles), np.int64, n)
    bk = np.fromiter((t.bk for t in tiles), np.int64, n)
    sk = np.fromiter((t.split_k for t in tiles), np.int64, n)
    gm = np.fromiter((t.group_m for t in tiles), np.int64, n)
    sched = np.fromiter((SCHEDULES.index(t.schedule) for t in tiles),
                        np.int64, n)
    return score_candidate_arrays(p, bm, bn, bk, sk, gm, hw, sched=sched)


def score_candidate_arrays(p: GemmProblem, bm: np.ndarray, bn: np.ndarray,
                           bk: np.ndarray, sk: np.ndarray, gm: np.ndarray,
                           hw: HardwareSpec,
                           sched: Optional[np.ndarray] = None) -> np.ndarray:
    """``score_candidates`` on raw int64 column arrays (no TileConfig
    objects) — the selector's fully-vectorized cold path feeds the enumerated
    candidate columns straight in.  ``sched`` holds ``SCHEDULES`` indices
    (omitted: all data_parallel)."""
    Tm = -(-p.M // bm)
    Tn = -(-p.N // bn)
    k_per_split = -(-p.K // sk)
    Tk = -(-k_per_split // bk) * sk
    if sched is None:
        sched = np.zeros_like(bm)
    steps_i = Tm * Tn * Tk * p.batch
    steps = steps_i.astype(np.float64)

    mm, mn, mk = hw.mxu_shape
    n_atoms = (-(-bm // mm)) * (-(-bn // mn)) * (-(-bk // mk))
    mxu_s = n_atoms * (2.0 * mm * mn * mk) / hw.flops(p.in_dtype)

    bi = DTYPE_BYTES[p.in_dtype]
    bo = DTYPE_BYTES[p.out_dtype]
    ep = p.epilogue
    n_mn, has_bias = ep.n_mn_operands, int(ep.bias)
    e_vmem = (n_mn * bm * bn + has_bias * bn) * bi / Tk
    vmem_s = ((bm * bk + bk * bn) * bi + 2.0 * ACC_BYTES * bm * bn
              + e_vmem) / hw.vmem_bandwidth

    # revisit fractions (vectorized): A skipped on n-advance (ungrouped),
    # B skipped on m-advance within a group (grouped), both need Tk == 1
    # AND a single-core chain (multi-core: nothing persists in staging).
    rev = hw.total_cores() == 1
    a_skip = np.where(rev & (Tk == 1) & (gm <= 1) & (Tn > 0),
                      (Tn - 1) / np.maximum(Tn, 1), 0.0)
    g = np.minimum(gm, Tm)
    b_skip = np.where(rev & (Tk == 1) & (gm > 1),
                      (g - 1) / np.maximum(g, 1), 0.0)
    a_bytes = Tn * (p.M * p.K) * bi * (1.0 - a_skip)
    b_bytes = Tm * (p.K * p.N) * bi * (1.0 - b_skip)
    c_bytes = p.M * p.N * bo
    e_bytes = (n_mn * p.M * p.N + has_bias * p.N) * bi
    traffic = p.batch * (a_bytes + b_bytes + c_bytes + e_bytes)

    occ = occupancy_arrays(p, hw, Tm, Tn, sk, sched, steps_i)
    prologue = hw.hbm_latency + (bm * bk + bk * bn) * bi / hw.hbm_bandwidth
    epilogue = hw.hbm_latency + bm * bn * bo / hw.hbm_bandwidth
    if hw.total_cores() > 1:
        # Max-plus overlap steady state + flush cursor (multi-core chains).
        extra = _schedule_extra_arrays(p, hw, Tm, Tn, Tk, bm, bn, sk, sched)
        body, flush = overlap_pipeline_arrays(
            p, hw, Tm, Tn, bm, bn, gm, steps,
            np.maximum(mxu_s, vmem_s) * occ, hw.dma_fixed * occ,
            p.batch * a_bytes, p.batch * b_bytes,
            p.batch * (c_bytes + e_bytes), extra)
        return hw.kernel_launch + prologue + epilogue + body + flush
    mem_s = memory_step_seconds_arrays(p, hw, traffic, Tm, Tn, Tk,
                                       bm, bn, gm, steps, sk=sk, sched=sched)
    l_iter = np.maximum(np.maximum(mxu_s, vmem_s) * occ,
                        mem_s + hw.dma_fixed * occ)
    return hw.kernel_launch + prologue + epilogue + steps * l_iter


# ---------------------------------------------------------------------------
# Alg. 4 — chip-level wave quantization (used by the distributed layer).
# ---------------------------------------------------------------------------

def chip_waves(p: GemmProblem, t: TileConfig, n_chips: int
               ) -> Tuple[int, int]:
    """(active_chips_last_wave, n_waves) when output tiles are spread over
    chips — the paper's Alg. 4 verbatim, with CUs -> chips."""
    Tm, Tn, _ = grid_shape(p, t)
    tiles = Tm * Tn * p.batch
    waves = cdiv(tiles, n_chips)
    active = tiles % n_chips or n_chips
    return active, waves


def staging_working_set(t: TileConfig, in_dtype: str,
                        hw: HardwareSpec) -> int:
    """Bytes of staging memory (VMEM / LDS / SMEM) a kernel instance claims:
    pipeline_depth-buffered input blocks, plus one f32 accumulator block on
    topologies whose staging level hosts the accumulator (TPU VMEM scratch;
    GPU accumulators live in registers instead)."""
    bi = DTYPE_BYTES[in_dtype]
    inputs = hw.pipeline_depth * (t.bm * t.bk + t.bk * t.bn) * bi
    acc = t.bm * t.bn * ACC_BYTES if hw.staging.holds_accumulator else 0
    return inputs + acc


# Legacy name (the paper's LDS-capacity filter; on TPU staging == VMEM).
vmem_working_set = staging_working_set


def fits_placement(t: TileConfig, in_dtype: str, hw: HardwareSpec) -> bool:
    """The per-level capacity filter: the kernel's pinned working set must
    fit the budget of every placement level of the chain (the staging level
    plus any deeper core-scoped level).  Generalizes the seed's flat VMEM
    filter."""
    ws = staging_working_set(t, in_dtype, hw)
    return all(ws <= lvl.budget() for lvl in hw.placement_levels())
