"""tritonBLAS-on-TPU core: the paper's analytical model + selector."""
from repro.core.hardware import (
    DTYPE_BYTES,
    PRESETS,
    TPU_V4,
    TPU_V5E,
    TPU_V5P,
    HardwareSpec,
    calibrate,
    get_hardware,
)
from repro.core.latency import (
    EPILOGUE_NONE,
    Epilogue,
    GemmProblem,
    LatencyBreakdown,
    TileConfig,
    chip_waves,
    epilogue_unfused_extra_bytes,
    gemm_latency,
    grid_shape,
    hbm_traffic,
    reuse_fraction,
    revisit_fractions,
    score_candidate,
    score_candidate_arrays,
    score_candidates,
    vmem_working_set,
)
from repro.core.roofline import (
    RooflineReport,
    cost_analysis_terms,
    parse_collective_bytes,
    roofline,
)
from repro.core.selector import (
    Selection,
    argmin_candidate,
    candidate_arrays,
    candidate_tiles,
    clear_selection_cache,
    rank_candidates,
    select_gemm_config,
    selection_cache_size,
)
from repro.core.simulator import SimResult, exhaustive_best, simulate_gemm

__all__ = [
    "DTYPE_BYTES", "PRESETS", "TPU_V4", "TPU_V5E", "TPU_V5P",
    "HardwareSpec", "calibrate", "get_hardware",
    "EPILOGUE_NONE", "Epilogue", "GemmProblem", "LatencyBreakdown",
    "TileConfig", "chip_waves", "epilogue_unfused_extra_bytes",
    "gemm_latency", "grid_shape", "hbm_traffic", "reuse_fraction",
    "revisit_fractions", "score_candidate", "score_candidate_arrays",
    "score_candidates", "vmem_working_set",
    "RooflineReport", "cost_analysis_terms", "parse_collective_bytes",
    "roofline",
    "Selection", "argmin_candidate", "candidate_arrays", "candidate_tiles",
    "clear_selection_cache", "rank_candidates", "select_gemm_config",
    "selection_cache_size",
    "SimResult", "exhaustive_best", "simulate_gemm",
]
