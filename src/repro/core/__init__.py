"""tritonBLAS-on-TPU core: the paper's analytical model + selector."""
from repro.core.hardware import (
    DTYPE_BYTES,
    PRESETS,
    TPU_V4,
    TPU_V5E,
    TPU_V5P,
    HardwareSpec,
    calibrate,
    get_hardware,
)
from repro.core.latency import (
    GemmProblem,
    LatencyBreakdown,
    TileConfig,
    chip_waves,
    gemm_latency,
    grid_shape,
    hbm_traffic,
    reuse_fraction,
    revisit_fractions,
    vmem_working_set,
)
from repro.core.roofline import (
    RooflineReport,
    cost_analysis_terms,
    parse_collective_bytes,
    roofline,
)
from repro.core.selector import (
    Selection,
    candidate_tiles,
    clear_selection_cache,
    rank_candidates,
    select_gemm_config,
    selection_cache_size,
)
from repro.core.simulator import SimResult, exhaustive_best, simulate_gemm

__all__ = [
    "DTYPE_BYTES", "PRESETS", "TPU_V4", "TPU_V5E", "TPU_V5P",
    "HardwareSpec", "calibrate", "get_hardware",
    "GemmProblem", "LatencyBreakdown", "TileConfig", "chip_waves",
    "gemm_latency", "grid_shape", "hbm_traffic", "reuse_fraction",
    "revisit_fractions", "vmem_working_set",
    "RooflineReport", "cost_analysis_terms", "parse_collective_bytes",
    "roofline",
    "Selection", "candidate_tiles", "clear_selection_cache",
    "rank_candidates", "select_gemm_config", "selection_cache_size",
    "SimResult", "exhaustive_best", "simulate_gemm",
]
