"""tritonBLAS-on-TPU core: the paper's analytical model + selector."""
from repro.core.dtypes import (
    ACC_BYTES,
    DTYPE_BYTES,
    HLO_DTYPE_BYTES,
    canonical_dtype,
    dtype_bytes,
)
from repro.core.topology import (
    HardwareSpec,
    MemoryLevel,
    Topology,
    calibration_field_names,
)
from repro.core.hardware import (
    GPU_H100_LIKE,
    GPU_MI300X_LIKE,
    PRESETS,
    TPU_V4,
    TPU_V5E,
    TPU_V5P,
    calibrate,
    get_hardware,
)
from repro.core.latency import (
    EPILOGUE_NONE,
    Epilogue,
    GemmProblem,
    LatencyBreakdown,
    TileConfig,
    chip_waves,
    epilogue_unfused_extra_bytes,
    fits_placement,
    gemm_latency,
    grid_shape,
    hbm_traffic,
    level_traffic,
    reuse_fraction,
    revisit_fractions,
    score_candidate,
    score_candidate_arrays,
    score_candidates,
    staging_working_set,
    vmem_working_set,
)
from repro.core.roofline import (
    RooflineReport,
    cost_analysis_terms,
    parse_collective_bytes,
    roofline,
)
from repro.core.selector import (
    Selection,
    argmin_candidate,
    candidate_arrays,
    candidate_tiles,
    clear_selection_cache,
    load_selection_cache,
    rank_candidates,
    save_selection_cache,
    select_gemm_config,
    selection_cache_size,
)
from repro.core.simulator import SimResult, exhaustive_best, simulate_gemm

__all__ = [
    "ACC_BYTES", "DTYPE_BYTES", "HLO_DTYPE_BYTES", "canonical_dtype",
    "dtype_bytes",
    "HardwareSpec", "MemoryLevel", "Topology", "calibration_field_names",
    "GPU_H100_LIKE", "GPU_MI300X_LIKE", "PRESETS",
    "TPU_V4", "TPU_V5E", "TPU_V5P", "calibrate", "get_hardware",
    "EPILOGUE_NONE", "Epilogue", "GemmProblem", "LatencyBreakdown",
    "TileConfig", "chip_waves", "epilogue_unfused_extra_bytes",
    "fits_placement", "gemm_latency", "grid_shape", "hbm_traffic",
    "level_traffic", "reuse_fraction", "revisit_fractions",
    "score_candidate", "score_candidate_arrays", "score_candidates",
    "staging_working_set", "vmem_working_set",
    "RooflineReport", "cost_analysis_terms", "parse_collective_bytes",
    "roofline",
    "Selection", "argmin_candidate", "candidate_arrays", "candidate_tiles",
    "clear_selection_cache", "load_selection_cache", "rank_candidates",
    "save_selection_cache", "select_gemm_config", "selection_cache_size",
    "SimResult", "exhaustive_best", "simulate_gemm",
]
