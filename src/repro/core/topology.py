"""Memory-hierarchy topology model (paper §IV, Table I, generalized).

The paper's claim is that GEMM configs can be picked analytically by
"explicitly modeling the relationship between architectural topology, matrix
shapes, and algorithmic blocking behavior".  The seed encoded that topology
as a *flat* two-level dataclass (HBM + VMEM) which could not express the
LDS + L2 + HBM hierarchies of the paper's actual GPU targets.  This module
is the generalization:

* :class:`MemoryLevel` — one level of the chain: capacity, bandwidth across
  its port, first-access latency, and *scope* (device / partition / core).
* :class:`Topology` — compute rates (MXU shape, peak FLOP/s, lane tiling),
  partition count, fixed overheads, and an ordered ``levels`` chain running
  **outermost → innermost**: ``levels[0]`` is backing memory (HBM),
  ``levels[-1]`` is the kernel's staging memory (VMEM / LDS / SMEM), and
  anything between is a cache (L2 / LLC / MALL) the latency model prices
  via its reuse/footprint recurrence (``core/latency.py::level_traffic``).

The TPU presets are the 1-level special case (no intermediate cache level:
``levels == (hbm, vmem)``) and reproduce the seed/PR-1 model bit-for-bit —
pinned by ``tests/test_topology.py``.  ``HardwareSpec`` remains as an alias
so every existing call site keeps working; the legacy flat field names
(``hbm_bandwidth``, ``vmem_bytes``, …) are derived properties of the chain
ends, and ``with_calibration`` still accepts them (paper §V-E: retarget by
swapping measured constants only).

Candidate menus are per-topology: GPU-shaped presets need smaller staging
tiles (LDS/SMEM is KB-scale where VMEM is MB-scale) and a finer ``group_m``
menu, since grouped swizzle is a *priced* L2-residency decision there, not
a free entry.  All menu entries must stay powers of two — the vectorized
selector turns every ceil-division into a shift.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings
import weakref
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

from repro.core.dtypes import DTYPE_BYTES

SCOPES = ("device", "partition", "core")

# Grid schedules the model can price (DESIGN.md §2: occupancy stage).
SCHEDULES = ("data_parallel", "stream_k")

# Default candidate menus (the TPU-shaped space of the seed; DESIGN.md §2).
DEFAULT_BM_MENU = (8, 16, 32, 64, 128, 256, 512, 1024)
DEFAULT_BN_MENU = (128, 256, 512, 1024)
DEFAULT_BK_MENU = (128, 256, 512, 1024, 2048)
DEFAULT_SPLIT_K_MENU = (1, 2, 4, 8)
DEFAULT_GROUP_M_MENU = (1, 8)
DEFAULT_SCHEDULE_MENU = ("data_parallel",)


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


@dataclass(frozen=True)
class MemoryLevel:
    """One level of the memory chain.  Sizes in bytes, rates in B/s.

    ``capacity`` is per *scope instance* (per device / per partition / per
    core) — the model runs a kernel on one core of one partition, so the
    capacity a reuse window sees is exactly this number.
    ``bandwidth`` is the byte rate across this level's port toward the
    compute side; traffic served at level ℓ also crosses every port nearer
    than ℓ (inclusive hierarchy).
    ``holds_accumulator`` marks a staging level that must also host the f32
    accumulator block (TPU VMEM scratch: yes; GPU LDS: no — accumulators
    live in registers there).
    """

    name: str
    capacity: int
    bandwidth: float
    latency: float = 0.0
    scope: str = "device"
    budget_fraction: float = 1.0
    holds_accumulator: bool = False

    def __post_init__(self):
        if self.scope not in SCOPES:
            raise ValueError(f"scope {self.scope!r} not in {SCOPES}")
        if self.capacity <= 0 or self.bandwidth <= 0:
            raise ValueError(f"non-positive capacity/bandwidth in {self}")
        if not (0.0 < self.budget_fraction <= 1.0):
            raise ValueError(f"budget_fraction out of (0,1]: {self}")

    def budget(self) -> int:
        """Bytes of this level a kernel may claim (the capacity filter)."""
        return int(self.capacity * self.budget_fraction)


# Legacy flat-field calibration aliases -> (level index, MemoryLevel field).
# Index -1 is the staging level, 0 the backing memory.
_LEVEL_ALIASES: Dict[str, Tuple[int, str]] = {
    "hbm_bandwidth": (0, "bandwidth"),
    "hbm_bytes": (0, "capacity"),
    "hbm_latency": (0, "latency"),
    "vmem_bytes": (-1, "capacity"),
    "vmem_bandwidth": (-1, "bandwidth"),
    "vmem_budget_fraction": (-1, "budget_fraction"),
}


@dataclass(frozen=True)
class Topology:
    """Calibratable machine description: compute rates + the memory chain."""

    name: str
    # MXU / tensor-core macro-atom (M, N, K): instruction-level tile.
    mxu_shape: Tuple[int, int, int]
    # Native sublane tiling (second-minor, minor) per dtype-bytes.
    lane_width: int
    sublane_f32: int
    # Peak matmul throughput per chip, FLOP/s, keyed by input dtype.
    peak_flops: Mapping[str, float]
    # Memory chain, outermost (backing memory) -> innermost (staging).
    levels: Tuple[MemoryLevel, ...]
    # Cores per partition-scope cache domain (XCDs on MI300X; 1 on TPU).
    partitions: int = 1
    # Compute cores (CUs / SMs) per partition.  total_cores() =
    # partitions * core_count is the chip-wide denominator of the Alg. 4
    # wave model; 1 keeps the seed's single-sequential-core behaviour.
    core_count: int = 1
    # Interconnect (per chip).
    ici_bandwidth: float = 0.0
    ici_links: int = 0
    # Fixed overheads (the paper's load/store "issue rate" axis).
    dma_fixed: float = 0.0
    kernel_launch: float = 0.0
    pipeline_depth: int = 2
    # Per-topology candidate menus (powers of two; selector shift trick).
    bm_menu: Tuple[int, ...] = DEFAULT_BM_MENU
    bn_menu: Tuple[int, ...] = DEFAULT_BN_MENU
    bk_menu: Tuple[int, ...] = DEFAULT_BK_MENU
    split_k_menu: Tuple[int, ...] = DEFAULT_SPLIT_K_MENU
    group_m_menu: Tuple[int, ...] = DEFAULT_GROUP_M_MENU
    schedule_menu: Tuple[str, ...] = DEFAULT_SCHEDULE_MENU

    def __post_init__(self):
        if len(self.levels) < 2:
            raise ValueError(
                f"{self.name}: need at least (backing, staging) levels")
        if self.partitions < 1 or self.core_count < 1:
            raise ValueError(
                f"{self.name}: partitions/core_count must be >= 1")
        for menu_name in ("bm_menu", "bn_menu", "bk_menu",
                          "split_k_menu", "group_m_menu"):
            menu = getattr(self, menu_name)
            if not menu or not all(_is_pow2(m) for m in menu):
                raise ValueError(
                    f"{self.name}: {menu_name} must be non-empty powers of "
                    f"two, got {menu}")
        if not self.schedule_menu or not all(
                s in SCHEDULES for s in self.schedule_menu):
            raise ValueError(
                f"{self.name}: schedule_menu entries must be from "
                f"{SCHEDULES}, got {self.schedule_menu}")

    # ---- the chain ------------------------------------------------------
    @property
    def backing(self) -> MemoryLevel:
        """Outermost level: where compulsory traffic is served (HBM)."""
        return self.levels[0]

    @property
    def staging(self) -> MemoryLevel:
        """Innermost level: where the kernel stages blocks (VMEM/LDS)."""
        return self.levels[-1]

    @property
    def cache_levels(self) -> Tuple[MemoryLevel, ...]:
        """Intermediate levels (L2/LLC …), outermost -> innermost.  Empty on
        the TPU 1-level special case."""
        return self.levels[1:-1]

    def total_cores(self) -> int:
        """Chip-wide compute cores — the Alg. 4 wave denominator."""
        return self.partitions * self.core_count

    def placement_levels(self) -> Tuple[MemoryLevel, ...]:
        """Levels whose capacity gates candidate legality: every level the
        kernel *pins* working state in — the staging level, plus any deeper
        core-scoped level a topology might model."""
        return tuple(l for l in self.levels[1:]
                     if l is self.staging or l.scope == "core")

    # ---- legacy flat-field views (the whole repo reads these) -----------
    @property
    def hbm_bandwidth(self) -> float:
        return self.backing.bandwidth

    @property
    def hbm_bytes(self) -> int:
        return self.backing.capacity

    @property
    def hbm_latency(self) -> float:
        return self.backing.latency

    @property
    def vmem_bytes(self) -> int:
        return self.staging.capacity

    @property
    def vmem_bandwidth(self) -> float:
        return self.staging.bandwidth

    @property
    def vmem_budget_fraction(self) -> float:
        return self.staging.budget_fraction

    def vmem_budget(self) -> int:
        return self.staging.budget()

    # ---- derived helpers -------------------------------------------------
    def flops(self, dtype: str) -> float:
        """Peak FLOP/s for ``dtype``.  Unknown dtypes raise (the seed fell
        back to bf16 peak silently, mispricing every unknown-dtype GEMM)."""
        try:
            return self.peak_flops[dtype]
        except KeyError:
            raise KeyError(
                f"{self.name} has no peak-FLOPs entry for dtype {dtype!r}; "
                f"known dtypes: {sorted(self.peak_flops)}") from None

    def sublane(self, dtype: str) -> int:
        # Packing: second-minor native tile scales inversely with dtype width.
        return self.sublane_f32 * (4 // min(DTYPE_BYTES[dtype], 4))

    def ici_bandwidth_total(self) -> float:
        return self.ici_bandwidth * self.ici_links

    def with_calibration(self, **updates) -> "Topology":
        """Paper §V-E: retarget by swapping measured constants only.

        Accepts real ``Topology`` fields, the legacy flat aliases
        (``hbm_bandwidth`` … ``vmem_budget_fraction``) which update the
        chain ends, and ``levels`` itself for whole-chain swaps.
        """
        level_updates: Dict[int, Dict[str, object]] = {}
        direct: Dict[str, object] = {}
        for key, value in updates.items():
            alias = _LEVEL_ALIASES.get(key)
            if alias is not None:
                idx, fname = alias
                idx = idx % len(self.levels)
                level_updates.setdefault(idx, {})[fname] = value
            else:
                direct[key] = value
        if level_updates:
            levels = tuple(
                dataclasses.replace(l, **level_updates[i])
                if i in level_updates else l
                for i, l in enumerate(self.levels))
            direct["levels"] = levels
        return dataclasses.replace(self, **direct)

    # ---- serialization ---------------------------------------------------
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["peak_flops"] = dict(self.peak_flops)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "Topology":
        d = dict(d)
        d["levels"] = tuple(MemoryLevel(**lv) for lv in d["levels"])
        d["mxu_shape"] = tuple(d["mxu_shape"])
        for menu_name in ("bm_menu", "bn_menu", "bk_menu",
                          "split_k_menu", "group_m_menu", "schedule_menu"):
            if menu_name in d:
                d[menu_name] = tuple(d[menu_name])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Topology":
        return cls.from_dict(json.loads(text))


def calibration_field_names(topo: Topology) -> Tuple[str, ...]:
    """Names ``with_calibration``/``calibrate`` accept for this topology."""
    real = tuple(f.name for f in dataclasses.fields(topo))
    return real + tuple(_LEVEL_ALIASES)


def reference_dtype(peak_flops: Mapping[str, float]) -> str:
    """The dtype the wave probe times and the fit's static-share / unit
    sizing divide by: bfloat16 when the topology has it, else the first
    known dtype in sorted order.  One shared rule so probes, fits, and the
    simulator's wave primitive can never disagree on a bf16-less chain."""
    return "bfloat16" if "bfloat16" in peak_flops else sorted(peak_flops)[0]


# Fingerprints are content hashes of immutable Topology objects, so they
# are memoized by identity (Topology holds a dict field and is therefore
# unhashable; id() plus a liveness-checked weakref is the safe key — a
# recycled id after GC fails the ``is`` check and recomputes).  The memo
# keeps the per-selection fingerprint check out of the hot memo path.
_FP_MEMO: Dict[int, Tuple] = {}


def topology_fingerprint(hw: Topology) -> str:
    """Content fingerprint of everything GEMM selection depends on — levels
    (capacities AND rates), compute rates, menus, overheads.  Deliberately
    name-blind: a ``with_calibration`` retarget keeps the preset name but
    changes the fingerprint, which is how the persistent selection table
    invalidates warm starts after recalibration and how calibrated-topology
    artifacts prove which constants a selection was made against."""
    memo = _FP_MEMO.get(id(hw))
    if memo is not None and memo[0]() is hw:
        return memo[1]
    ident = (hw.levels, hw.mxu_shape, tuple(sorted(hw.peak_flops.items())),
             hw.bm_menu, hw.bn_menu, hw.bk_menu, hw.split_k_menu,
             hw.group_m_menu, hw.schedule_menu, hw.partitions,
             hw.core_count, hw.dma_fixed, hw.kernel_launch,
             hw.pipeline_depth, hw.lane_width, hw.sublane_f32)
    fp = hashlib.md5(repr(ident).encode()).hexdigest()[:16]
    try:
        _FP_MEMO[id(hw)] = (
            weakref.ref(hw, lambda _, i=id(hw): _FP_MEMO.pop(i, None)), fp)
    except TypeError:
        pass
    return fp


# ---------------------------------------------------------------------------
# Calibrated-topology artifacts (DESIGN.md §8).
#
# A calibration run (repro.calib: probes -> fit) produces a topology whose
# measured constants replace the hand-estimated preset values, wrapped in a
# JSON document that carries full provenance: which device was probed, the
# raw probe samples, per-fit residuals, and the fingerprint of the fitted
# topology (the same fingerprint the selection cache stores per entry, so a
# served artifact invalidates stale warm starts end-to-end).
# ---------------------------------------------------------------------------

CALIBRATED_TOPOLOGY_SCHEMA = "repro/calibrated-topology/v1"


def calibrated_topology_dict(topo: Topology,
                             provenance: Optional[Mapping] = None) -> Dict:
    """The calibrated-topology artifact document (see DESIGN.md §8 for the
    schema).  ``provenance`` is free-form JSON-serializable metadata from
    the fit pipeline (device, probes, residuals, fitted fields); the
    topology fingerprint is always (re)stamped here so artifacts cannot
    carry a stale one."""
    prov = dict(provenance or {})
    prov["fingerprint"] = topology_fingerprint(topo)
    return {"schema": CALIBRATED_TOPOLOGY_SCHEMA,
            "topology": topo.to_dict(),
            "provenance": prov}


def calibrated_topology_json(topo: Topology,
                             provenance: Optional[Mapping] = None) -> str:
    return json.dumps(calibrated_topology_dict(topo, provenance),
                      indent=1, sort_keys=True)


def load_calibrated_topology(text: str) -> Tuple[Topology, Dict]:
    """Parse a calibrated-topology artifact -> (topology, provenance).

    Validates the schema tag and the provenance fingerprint against the
    recomputed fingerprint of the parsed topology — a hand-edited artifact
    whose constants no longer match its recorded fingerprint is rejected
    (it would silently defeat the selection cache's invalidation)."""
    doc = json.loads(text)
    schema = doc.get("schema")
    if schema != CALIBRATED_TOPOLOGY_SCHEMA:
        raise ValueError(
            f"not a calibrated-topology artifact: schema={schema!r}, "
            f"expected {CALIBRATED_TOPOLOGY_SCHEMA!r}")
    topo = Topology.from_dict(doc["topology"])
    prov = dict(doc.get("provenance", {}))
    recorded = prov.get("fingerprint")
    actual = topology_fingerprint(topo)
    if recorded != actual:
        raise ValueError(
            f"calibrated-topology artifact for {topo.name!r} is corrupt: "
            f"recorded fingerprint {recorded!r} != recomputed {actual!r} "
            f"(constants were edited after the fit)")
    return topo, prov


class DegradedModeWarning(UserWarning):
    """A component fell back to a degraded-but-safe mode (stock preset,
    conservative config, reference kernel) instead of raising.  Emitted as
    a structured warning so serving stacks can count/route it without
    string-matching log lines (DESIGN.md §9)."""


def quarantine_artifact(path: str) -> str:
    """Move a rejected artifact aside to a ``.quarantined`` sidecar (never
    delete evidence: the sidecar is what a post-mortem fits the fault
    from).  An existing sidecar is overwritten — the newest rejection is
    the one worth keeping."""
    sidecar = path + ".quarantined"
    os.replace(path, sidecar)
    return sidecar


def load_calibrated_topology_guarded(
    path: str,
    fallback: Topology,
    *,
    max_residual: Optional[float] = 0.5,
    quarantine: bool = True,
) -> Tuple[Topology, Dict]:
    """Fail-soft artifact loading for serving paths (DESIGN.md §9).

    :func:`load_calibrated_topology` raises on a truncated / tampered /
    wrong-schema artifact — correct for tools, fatal for a server whose
    calibration file rotted on disk.  This wrapper never raises on a bad
    artifact: the file is quarantined to a ``.quarantined`` sidecar, a
    :class:`DegradedModeWarning` is emitted, and the ``fallback`` preset
    is returned so serving continues on stock constants.

    ``max_residual`` additionally rejects artifacts whose recorded fit
    residuals (rel RMS per fitted field) exceed the threshold — a fit that
    barely described its own measurements must not silently steer every
    selection.  Pass ``None`` to skip the residual gate.

    Returns ``(topology, provenance)``; a degraded load's provenance
    carries ``degraded`` (the reason) and ``quarantined`` (sidecar path,
    or None when quarantining was disabled or impossible).
    """
    def _degrade(reason: str) -> Tuple[Topology, Dict]:
        sidecar = None
        if quarantine and os.path.exists(path):
            try:
                sidecar = quarantine_artifact(path)
            except OSError:
                pass
        warnings.warn(
            f"calibrated-topology artifact {path!r} rejected ({reason}); "
            f"serving on stock preset {fallback.name!r}"
            + (f"; artifact quarantined to {sidecar!r}" if sidecar else ""),
            DegradedModeWarning, stacklevel=3)
        return fallback, {"degraded": reason, "quarantined": sidecar}

    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        # Nothing to quarantine — the file is unreadable/absent.
        warnings.warn(
            f"calibrated-topology artifact {path!r} unreadable ({e}); "
            f"serving on stock preset {fallback.name!r}",
            DegradedModeWarning, stacklevel=2)
        return fallback, {"degraded": f"unreadable: {e}", "quarantined": None}
    try:
        topo, prov = load_calibrated_topology(text)
    except (ValueError, KeyError, TypeError) as e:
        return _degrade(str(e) or type(e).__name__)
    if max_residual is not None:
        residuals = prov.get("residuals") or {}
        worst = max(residuals.values(), default=0.0)
        if worst > max_residual:
            worst_field = max(residuals, key=residuals.get)
            return _degrade(
                f"fit residual out of tolerance: {worst_field} = "
                f"{worst:.3g} > {max_residual:.3g}")
    return topo, prov


# Backward-compatible name: the whole repo grew up calling this HardwareSpec.
HardwareSpec = Topology
