"""Hardware presets for the analytical model (paper §IV, Table I).

The paper parameterizes its model by "measurable hardware rates (bandwidths,
instruction latencies, and matrix-core shapes)" so it can be retargeted by
calibration alone (paper §V-E / Fig. 5).  We keep exactly that contract,
now expressed through :mod:`repro.core.topology`: a :class:`Topology` is a
frozen dataclass of compute rates plus an ordered :class:`MemoryLevel`
chain.  Retargeting = new preset.

Preset families (DESIGN.md §2):

* **TPU** (v5e primary — the container's roofline constants; v5p, v4): the
  1-level special case ``HBM → VMEM`` with no intermediate cache — cache
  locality is the deterministic Pallas *revisit* model instead.
* **GPU-shaped** (``gpu_mi300x_like``, ``gpu_h100_like``): multi-level
  chains (``HBM → MALL → L2-per-XCD → LDS`` and ``HBM → L2 → SMEM``) that
  exercise the paper's actual Table-I hierarchy.  Constants approximate the
  public datasheets — these presets exist so the model's per-level terms
  (``benchmarks/hierarchy_sweep.py``) have a real shape to bite on, hence
  the ``_like`` suffix; on-silicon calibration would refine them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Mapping, Optional

from repro.core.dtypes import DTYPE_BYTES  # re-export (legacy import path)
from repro.core.topology import (
    HardwareSpec,
    MemoryLevel,
    Topology,
    calibration_field_names,
)

__all__ = [
    "DTYPE_BYTES", "HardwareSpec", "MemoryLevel", "Topology",
    "TPU_V5E", "TPU_V5P", "TPU_V4", "GPU_MI300X_LIKE", "GPU_H100_LIKE",
    "PRESETS", "get_hardware", "calibrate", "validate_measured",
]

# ---------------------------------------------------------------------------
# TPU presets.  v5e numbers match the roofline constants mandated for this
# repo: 197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s/link ICI.  VMEM
# bandwidth is modeled at ~22x HBM (scaling-book ratio).
# ---------------------------------------------------------------------------

TPU_V5E = Topology(
    name="tpu_v5e",
    mxu_shape=(128, 128, 128),
    lane_width=128,
    sublane_f32=8,
    peak_flops={
        "bfloat16": 197e12,
        "float16": 197e12,          # modeled at the bf16 rate
        "float32": 197e12 / 4,      # no native f32 matmul path
        "int8": 394e12,
        "float8_e4m3fn": 394e12,
    },
    levels=(
        MemoryLevel(name="hbm", capacity=16 * 1024**3, bandwidth=819e9,
                    latency=1.0e-6, scope="device"),
        MemoryLevel(name="vmem", capacity=128 * 1024**2,
                    bandwidth=22 * 819e9, scope="core",
                    budget_fraction=0.5, holds_accumulator=True),
    ),
    ici_bandwidth=50e9,
    ici_links=4,                    # 2D torus
    dma_fixed=1.0e-7,
    kernel_launch=2.0e-6,
    pipeline_depth=2,
)

TPU_V5P = TPU_V5E.with_calibration(
    name="tpu_v5p",
    peak_flops={
        "bfloat16": 459e12,
        "float16": 459e12,
        "float32": 459e12 / 4,
        "int8": 918e12,
        "float8_e4m3fn": 918e12,
    },
    hbm_bandwidth=2765e9,
    hbm_bytes=95 * 1024**3,
    vmem_bandwidth=22 * 2765e9,
    ici_bandwidth=90e9,
    ici_links=6,                    # 3D torus
)

TPU_V4 = TPU_V5E.with_calibration(
    name="tpu_v4",
    peak_flops={
        "bfloat16": 275e12,
        "float16": 275e12,
        "float32": 275e12 / 4,
        "int8": 275e12,
        "float8_e4m3fn": 275e12,
    },
    hbm_bandwidth=1228e9,
    hbm_bytes=32 * 1024**3,
    vmem_bandwidth=22 * 1228e9,
    ici_bandwidth=50e9,
    ici_links=6,
)

# ---------------------------------------------------------------------------
# GPU-shaped multi-level presets.  Staging (LDS/SMEM) holds only the
# double-buffered input blocks — accumulators live in registers, so
# holds_accumulator=False widens the legal tile space exactly as on silicon.
# Menus are finer than the TPU's: KB-scale staging wants smaller blocks, and
# group_m spans 1..16 because grouped swizzle is priced (L2 residency of the
# re-walked operand), not gated on the Pallas revisit trick.
# partitions x core_count is the Alg. 4 wave denominator (DESIGN.md §2
# occupancy stage): tail-wave shapes on these presets select split_k > 1 or
# the stream_k schedule, which is why schedule_menu carries both.
# ---------------------------------------------------------------------------

GPU_MI300X_LIKE = Topology(
    name="gpu_mi300x_like",
    mxu_shape=(16, 16, 16),         # MFMA macro-atom
    lane_width=32,
    sublane_f32=8,
    peak_flops={
        "bfloat16": 1307e12,
        "float16": 1307e12,
        "float32": 163e12,
        "int8": 2614e12,
        "float8_e4m3fn": 2614e12,
    },
    levels=(
        MemoryLevel(name="hbm", capacity=192 * 1024**3, bandwidth=5.3e12,
                    latency=8.0e-7, scope="device"),
        # Cache levels carry budget_fraction < 1: a shared cache never
        # gives one kernel its full capacity (conflict misses, other
        # streams), so reuse windows within ~25% of nominal capacity are
        # treated as spills — keeps the closed-form ideal-LRU windows and
        # the simulator's byte-clock distance proxy agreeing at the
        # residency boundary (the fidelity harness's marginal cases).
        MemoryLevel(name="mall", capacity=256 * 1024**2, bandwidth=14.0e12,
                    scope="device", budget_fraction=0.75),  # Infinity Cache
        MemoryLevel(name="l2", capacity=4 * 1024**2, bandwidth=25.0e12,
                    scope="partition", budget_fraction=0.75),  # 4MiB per XCD
        MemoryLevel(name="lds", capacity=64 * 1024, bandwidth=80.0e12,
                    scope="core"),                       # 64 KiB per CU
    ),
    partitions=8,                   # XCDs
    core_count=38,                  # CUs per XCD -> 304 chip-wide
    ici_bandwidth=64e9,             # xGMI per link
    ici_links=7,
    dma_fixed=1.0e-9,               # issue cost amortizes over parallel CUs
    kernel_launch=3.0e-6,
    pipeline_depth=2,
    bm_menu=(16, 32, 64, 128, 256),
    bn_menu=(32, 64, 128, 256),
    bk_menu=(32, 64, 128),
    split_k_menu=(1, 2, 4, 8),
    group_m_menu=(1, 2, 4, 8, 16),
    schedule_menu=("data_parallel", "stream_k"),
)

GPU_H100_LIKE = Topology(
    name="gpu_h100_like",
    mxu_shape=(64, 64, 16),         # wgmma macro-atom
    lane_width=32,
    sublane_f32=8,
    peak_flops={
        "bfloat16": 989e12,
        "float16": 989e12,
        "float32": 494e12,          # tf32 tensor-core path
        "int8": 1979e12,
        "float8_e4m3fn": 1979e12,
    },
    levels=(
        MemoryLevel(name="hbm", capacity=80 * 1024**3, bandwidth=3.35e12,
                    latency=7.0e-7, scope="device"),
        # budget_fraction < 1: see the MI300X-like preset note.
        MemoryLevel(name="l2", capacity=50 * 1024**2, bandwidth=12.0e12,
                    scope="device", budget_fraction=0.75),
        MemoryLevel(name="smem", capacity=228 * 1024, bandwidth=30.0e12,
                    scope="core"),                       # 228 KiB per SM
    ),
    partitions=1,
    core_count=132,                 # SMs (one L2 partition modeled)
    ici_bandwidth=50e9,             # NVLink4 per link
    ici_links=18,
    dma_fixed=1.0e-9,               # issue cost amortizes over parallel SMs
    kernel_launch=3.0e-6,
    pipeline_depth=2,
    bm_menu=(32, 64, 128, 256),
    bn_menu=(32, 64, 128, 256),
    bk_menu=(32, 64, 128),
    split_k_menu=(1, 2, 4, 8),
    group_m_menu=(1, 2, 4, 8, 16),
    schedule_menu=("data_parallel", "stream_k"),
)

PRESETS: Dict[str, Topology] = {
    "tpu_v5e": TPU_V5E,
    "tpu_v5p": TPU_V5P,
    "tpu_v4": TPU_V4,
    "gpu_mi300x_like": GPU_MI300X_LIKE,
    "gpu_h100_like": GPU_H100_LIKE,
}


def get_hardware(name: str) -> Topology:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown hardware {name!r}; presets: {sorted(PRESETS)}")


# Numeric calibration fields that must be strictly positive — a measured
# rate/size of zero (or below) means the microbenchmark failed, and feeding
# it onward would either crash MemoryLevel validation with an unhelpful
# message or (worse, e.g. peak_flops) silently poison every selection.
# Everything else numeric (latencies, fixed overheads, ici terms) may
# legitimately measure 0.0 but never negative or NaN.
_POSITIVE_MARKERS = ("bandwidth", "bytes", "capacity", "fraction", "flops")
_POSITIVE_FIELDS = frozenset(
    {"partitions", "core_count", "pipeline_depth", "lane_width",
     "sublane_f32"})


def validate_measured(field_name: str, value) -> None:
    """Reject a non-finite / non-positive measured value with an error that
    names the offending field — shared by :func:`calibrate` (hand-supplied
    microbenchmarks) and the ``repro.calib`` fit pipeline (fitted values).

    Non-numeric calibration payloads (``levels`` tuples, menus, names) pass
    through; ``peak_flops`` mappings are validated per dtype entry."""
    if isinstance(value, Mapping):
        for k, v in value.items():
            validate_measured(f"{field_name}.{k}", v)
        return
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        return
    if not math.isfinite(value):
        raise ValueError(
            f"calibration for field {field_name!r} measured a non-finite "
            f"value ({value!r}); the microbenchmark failed — refusing to "
            f"build a topology from it")
    base = field_name.rsplit(".", 1)[-1]
    needs_positive = (base in _POSITIVE_FIELDS
                      or any(m in field_name for m in _POSITIVE_MARKERS))
    if needs_positive and value <= 0:
        raise ValueError(
            f"calibration for field {field_name!r} measured a non-positive "
            f"value ({value!r}); rates, capacities and fractions must be "
            f"> 0 — the microbenchmark failed")
    if not needs_positive and value < 0:
        raise ValueError(
            f"calibration for field {field_name!r} measured a negative "
            f"value ({value!r}); overheads/latencies must be >= 0")


def calibrate(
    base: Topology,
    microbenchmarks: Optional[Mapping[str, Callable[[], float]]] = None,
    *,
    device=None,
    **fit_kwargs,
) -> Topology:
    """Calibration entry point (paper contribution #2 / §V-E retargeting).

    Two modes:

    * ``microbenchmarks`` maps field names — real :class:`Topology` fields
      or the legacy flat aliases (``hbm_bandwidth`` …) — to zero-arg
      callables returning a measured value.  Unknown names raise
      ``KeyError`` listing what is calibratable; non-finite or
      non-positive measurements raise ``ValueError`` naming the field
      (:func:`validate_measured`).
    * ``device`` (a :class:`repro.calib.device.Device`) delegates to the
      full probe → fit pipeline (``repro.calib.fit.fit_topology``), which
      measures per-level stream bandwidths, per-dtype issue rates, and the
      wave/launch/issue overheads, returning the fitted topology.  Pass
      ``fit_kwargs`` (e.g. ``dtypes=...``) through to the fit.  Use
      ``repro.calib.fit.fit_topology`` directly when you also want the
      provenance artifact.
    """
    if device is not None:
        if microbenchmarks:
            raise ValueError(
                "pass either microbenchmarks or device=, not both")
        from repro.calib.fit import fit_topology
        return fit_topology(base, device, **fit_kwargs).topology
    if microbenchmarks is None:
        raise ValueError(
            "calibrate() needs either a microbenchmarks mapping or a "
            "device= to probe; calling it with neither would silently "
            "return the uncalibrated preset")
    known = calibration_field_names(base)
    measured = {}
    for field_name, bench in (microbenchmarks or {}).items():
        if field_name not in known:
            raise KeyError(
                f"not a calibratable field: {field_name!r}; "
                f"known: {sorted(known)}")
        value = bench()
        validate_measured(field_name, value)
        measured[field_name] = value
    return base.with_calibration(**measured)
