"""Hardware descriptions for the analytical model (paper §IV, Table I).

The paper parameterizes its model by "measurable hardware rates (bandwidths,
instruction latencies, and matrix-core shapes)" so it can be retargeted by
calibration alone (paper §V-E / Fig. 5).  We keep exactly that contract: a
frozen dataclass of rates, plus presets for TPU v5e (primary target — the
container's roofline constants), v5p and v4.  Retargeting = new preset.

TPU adaptation of Table I (see DESIGN.md §2):

    paper scope            TPU scope
    ------------------     --------------------------------------------
    matrix instruction     MXU systolic macro-atom (128x128x128)
    register tile          VREG accumulator tile
    shared-memory tile     Pallas BlockSpec block in VMEM
    L2 / LLC cache tile    (none on v5e) -> deterministic HBM revisit model
    device                 one TensorCore; chips multiply at the mesh level
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Tuple

DTYPE_BYTES: Dict[str, int] = {
    "bfloat16": 2,
    "float16": 2,
    "float32": 4,
    "float8_e4m3fn": 1,
    "int8": 1,
}


@dataclass(frozen=True)
class HardwareSpec:
    """Calibratable hardware rates. All times in seconds, sizes in bytes."""

    name: str
    # MXU macro-atom (M, N, K): the instruction-level tile of the hierarchy.
    mxu_shape: Tuple[int, int, int]
    # Native sublane tiling (second-minor, minor) per dtype-bytes.
    # f32 -> (8, 128), bf16 -> (16, 128), int8/fp8 -> (32, 128).
    lane_width: int
    sublane_f32: int
    # Peak matmul throughput per chip, FLOP/s, keyed by input dtype.
    peak_flops: Mapping[str, float]
    # Memory system.
    hbm_bandwidth: float          # B/s
    hbm_bytes: int                # capacity per chip
    hbm_latency: float            # Alg. 7's L_lat: first-byte latency
    vmem_bytes: int               # capacity per core
    vmem_bandwidth: float         # B/s, VMEM<->VREG
    vmem_budget_fraction: float   # fraction of VMEM a kernel may claim
    # Interconnect (per chip).
    ici_bandwidth: float          # B/s per link
    ici_links: int
    # Fixed overheads (the paper's load/store "issue rate" axis).
    dma_fixed: float              # per-grid-step DMA issue overhead
    kernel_launch: float          # one-off kernel dispatch cost
    pipeline_depth: int           # HBM->VMEM double(+)-buffering depth

    # ---- derived helpers -------------------------------------------------
    def flops(self, dtype: str) -> float:
        return self.peak_flops.get(dtype, self.peak_flops["bfloat16"])

    def vmem_budget(self) -> int:
        return int(self.vmem_bytes * self.vmem_budget_fraction)

    def sublane(self, dtype: str) -> int:
        # Packing: second-minor native tile scales inversely with dtype width.
        return self.sublane_f32 * (4 // min(DTYPE_BYTES[dtype], 4))

    def ici_bandwidth_total(self) -> float:
        return self.ici_bandwidth * self.ici_links

    def with_calibration(self, **updates) -> "HardwareSpec":
        """Paper §V-E: retarget by swapping measured constants only."""
        return dataclasses.replace(self, **updates)


# ---------------------------------------------------------------------------
# Presets.  v5e numbers match the roofline constants mandated for this repo:
# 197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s/link ICI.  VMEM bandwidth is
# modeled at ~22x HBM (scaling-book ratio).
# ---------------------------------------------------------------------------

TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    mxu_shape=(128, 128, 128),
    lane_width=128,
    sublane_f32=8,
    peak_flops={
        "bfloat16": 197e12,
        "float32": 197e12 / 4,      # no native f32 matmul path
        "int8": 394e12,
        "float8_e4m3fn": 394e12,
    },
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    hbm_latency=1.0e-6,
    vmem_bytes=128 * 1024**2,
    vmem_bandwidth=22 * 819e9,
    vmem_budget_fraction=0.5,
    ici_bandwidth=50e9,
    ici_links=4,                    # 2D torus
    dma_fixed=1.0e-7,
    kernel_launch=2.0e-6,
    pipeline_depth=2,
)

TPU_V5P = TPU_V5E.with_calibration(
    name="tpu_v5p",
    peak_flops={
        "bfloat16": 459e12,
        "float32": 459e12 / 4,
        "int8": 918e12,
        "float8_e4m3fn": 918e12,
    },
    hbm_bandwidth=2765e9,
    hbm_bytes=95 * 1024**3,
    vmem_bandwidth=22 * 2765e9,
    ici_bandwidth=90e9,
    ici_links=6,                    # 3D torus
)

TPU_V4 = TPU_V5E.with_calibration(
    name="tpu_v4",
    peak_flops={
        "bfloat16": 275e12,
        "float32": 275e12 / 4,
        "int8": 275e12,
        "float8_e4m3fn": 275e12,
    },
    hbm_bandwidth=1228e9,
    hbm_bytes=32 * 1024**3,
    vmem_bandwidth=22 * 1228e9,
    ici_bandwidth=50e9,
    ici_links=6,
)

PRESETS: Dict[str, HardwareSpec] = {
    "tpu_v5e": TPU_V5E,
    "tpu_v5p": TPU_V5P,
    "tpu_v4": TPU_V4,
}


def get_hardware(name: str) -> HardwareSpec:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown hardware {name!r}; presets: {sorted(PRESETS)}")


def calibrate(
    base: HardwareSpec,
    microbenchmarks: Mapping[str, Callable[[], float]],
) -> HardwareSpec:
    """Lightweight calibration hook (paper contribution #2).

    ``microbenchmarks`` maps HardwareSpec field names to zero-arg callables
    that return a measured rate (e.g. a stream benchmark for hbm_bandwidth).
    On real hardware these run once at install time; in this CPU container we
    use the published constants and this remains the documented entry point.
    """
    measured = {}
    for field_name, bench in microbenchmarks.items():
        if field_name not in {f.name for f in dataclasses.fields(base)}:
            raise KeyError(f"not a HardwareSpec field: {field_name}")
        measured[field_name] = bench()
    return base.with_calibration(**measured)
