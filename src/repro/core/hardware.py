"""Hardware presets for the analytical model (paper §IV, Table I).

The paper parameterizes its model by "measurable hardware rates (bandwidths,
instruction latencies, and matrix-core shapes)" so it can be retargeted by
calibration alone (paper §V-E / Fig. 5).  We keep exactly that contract,
now expressed through :mod:`repro.core.topology`: a :class:`Topology` is a
frozen dataclass of compute rates plus an ordered :class:`MemoryLevel`
chain.  Retargeting = new preset.

Preset families (DESIGN.md §2):

* **TPU** (v5e primary — the container's roofline constants; v5p, v4): the
  1-level special case ``HBM → VMEM`` with no intermediate cache — cache
  locality is the deterministic Pallas *revisit* model instead.
* **GPU-shaped** (``gpu_mi300x_like``, ``gpu_h100_like``): multi-level
  chains (``HBM → MALL → L2-per-XCD → LDS`` and ``HBM → L2 → SMEM``) that
  exercise the paper's actual Table-I hierarchy.  Constants approximate the
  public datasheets — these presets exist so the model's per-level terms
  (``benchmarks/hierarchy_sweep.py``) have a real shape to bite on, hence
  the ``_like`` suffix; on-silicon calibration would refine them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping

from repro.core.dtypes import DTYPE_BYTES  # re-export (legacy import path)
from repro.core.topology import (
    HardwareSpec,
    MemoryLevel,
    Topology,
    calibration_field_names,
)

__all__ = [
    "DTYPE_BYTES", "HardwareSpec", "MemoryLevel", "Topology",
    "TPU_V5E", "TPU_V5P", "TPU_V4", "GPU_MI300X_LIKE", "GPU_H100_LIKE",
    "PRESETS", "get_hardware", "calibrate",
]

# ---------------------------------------------------------------------------
# TPU presets.  v5e numbers match the roofline constants mandated for this
# repo: 197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s/link ICI.  VMEM
# bandwidth is modeled at ~22x HBM (scaling-book ratio).
# ---------------------------------------------------------------------------

TPU_V5E = Topology(
    name="tpu_v5e",
    mxu_shape=(128, 128, 128),
    lane_width=128,
    sublane_f32=8,
    peak_flops={
        "bfloat16": 197e12,
        "float16": 197e12,          # modeled at the bf16 rate
        "float32": 197e12 / 4,      # no native f32 matmul path
        "int8": 394e12,
        "float8_e4m3fn": 394e12,
    },
    levels=(
        MemoryLevel(name="hbm", capacity=16 * 1024**3, bandwidth=819e9,
                    latency=1.0e-6, scope="device"),
        MemoryLevel(name="vmem", capacity=128 * 1024**2,
                    bandwidth=22 * 819e9, scope="core",
                    budget_fraction=0.5, holds_accumulator=True),
    ),
    ici_bandwidth=50e9,
    ici_links=4,                    # 2D torus
    dma_fixed=1.0e-7,
    kernel_launch=2.0e-6,
    pipeline_depth=2,
)

TPU_V5P = TPU_V5E.with_calibration(
    name="tpu_v5p",
    peak_flops={
        "bfloat16": 459e12,
        "float16": 459e12,
        "float32": 459e12 / 4,
        "int8": 918e12,
        "float8_e4m3fn": 918e12,
    },
    hbm_bandwidth=2765e9,
    hbm_bytes=95 * 1024**3,
    vmem_bandwidth=22 * 2765e9,
    ici_bandwidth=90e9,
    ici_links=6,                    # 3D torus
)

TPU_V4 = TPU_V5E.with_calibration(
    name="tpu_v4",
    peak_flops={
        "bfloat16": 275e12,
        "float16": 275e12,
        "float32": 275e12 / 4,
        "int8": 275e12,
        "float8_e4m3fn": 275e12,
    },
    hbm_bandwidth=1228e9,
    hbm_bytes=32 * 1024**3,
    vmem_bandwidth=22 * 1228e9,
    ici_bandwidth=50e9,
    ici_links=6,
)

# ---------------------------------------------------------------------------
# GPU-shaped multi-level presets.  Staging (LDS/SMEM) holds only the
# double-buffered input blocks — accumulators live in registers, so
# holds_accumulator=False widens the legal tile space exactly as on silicon.
# Menus are finer than the TPU's: KB-scale staging wants smaller blocks, and
# group_m spans 1..16 because grouped swizzle is priced (L2 residency of the
# re-walked operand), not gated on the Pallas revisit trick.
# partitions x core_count is the Alg. 4 wave denominator (DESIGN.md §2
# occupancy stage): tail-wave shapes on these presets select split_k > 1 or
# the stream_k schedule, which is why schedule_menu carries both.
# ---------------------------------------------------------------------------

GPU_MI300X_LIKE = Topology(
    name="gpu_mi300x_like",
    mxu_shape=(16, 16, 16),         # MFMA macro-atom
    lane_width=32,
    sublane_f32=8,
    peak_flops={
        "bfloat16": 1307e12,
        "float16": 1307e12,
        "float32": 163e12,
        "int8": 2614e12,
        "float8_e4m3fn": 2614e12,
    },
    levels=(
        MemoryLevel(name="hbm", capacity=192 * 1024**3, bandwidth=5.3e12,
                    latency=8.0e-7, scope="device"),
        MemoryLevel(name="mall", capacity=256 * 1024**2, bandwidth=14.0e12,
                    scope="device"),                     # Infinity Cache
        MemoryLevel(name="l2", capacity=4 * 1024**2, bandwidth=25.0e12,
                    scope="partition"),                  # 4 MiB per XCD
        MemoryLevel(name="lds", capacity=64 * 1024, bandwidth=80.0e12,
                    scope="core"),                       # 64 KiB per CU
    ),
    partitions=8,                   # XCDs
    core_count=38,                  # CUs per XCD -> 304 chip-wide
    ici_bandwidth=64e9,             # xGMI per link
    ici_links=7,
    dma_fixed=1.0e-9,               # issue cost amortizes over parallel CUs
    kernel_launch=3.0e-6,
    pipeline_depth=2,
    bm_menu=(16, 32, 64, 128, 256),
    bn_menu=(32, 64, 128, 256),
    bk_menu=(32, 64, 128),
    split_k_menu=(1, 2, 4, 8),
    group_m_menu=(1, 2, 4, 8, 16),
    schedule_menu=("data_parallel", "stream_k"),
)

GPU_H100_LIKE = Topology(
    name="gpu_h100_like",
    mxu_shape=(64, 64, 16),         # wgmma macro-atom
    lane_width=32,
    sublane_f32=8,
    peak_flops={
        "bfloat16": 989e12,
        "float16": 989e12,
        "float32": 494e12,          # tf32 tensor-core path
        "int8": 1979e12,
        "float8_e4m3fn": 1979e12,
    },
    levels=(
        MemoryLevel(name="hbm", capacity=80 * 1024**3, bandwidth=3.35e12,
                    latency=7.0e-7, scope="device"),
        MemoryLevel(name="l2", capacity=50 * 1024**2, bandwidth=12.0e12,
                    scope="device"),
        MemoryLevel(name="smem", capacity=228 * 1024, bandwidth=30.0e12,
                    scope="core"),                       # 228 KiB per SM
    ),
    partitions=1,
    core_count=132,                 # SMs (one L2 partition modeled)
    ici_bandwidth=50e9,             # NVLink4 per link
    ici_links=18,
    dma_fixed=1.0e-9,               # issue cost amortizes over parallel SMs
    kernel_launch=3.0e-6,
    pipeline_depth=2,
    bm_menu=(32, 64, 128, 256),
    bn_menu=(32, 64, 128, 256),
    bk_menu=(32, 64, 128),
    split_k_menu=(1, 2, 4, 8),
    group_m_menu=(1, 2, 4, 8, 16),
    schedule_menu=("data_parallel", "stream_k"),
)

PRESETS: Dict[str, Topology] = {
    "tpu_v5e": TPU_V5E,
    "tpu_v5p": TPU_V5P,
    "tpu_v4": TPU_V4,
    "gpu_mi300x_like": GPU_MI300X_LIKE,
    "gpu_h100_like": GPU_H100_LIKE,
}


def get_hardware(name: str) -> Topology:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown hardware {name!r}; presets: {sorted(PRESETS)}")


def calibrate(
    base: Topology,
    microbenchmarks: Mapping[str, Callable[[], float]],
) -> Topology:
    """Lightweight calibration hook (paper contribution #2).

    ``microbenchmarks`` maps field names — real :class:`Topology` fields or
    the legacy flat aliases (``hbm_bandwidth`` …) — to zero-arg callables
    that return a measured rate (e.g. a stream benchmark for hbm_bandwidth).
    Unknown names raise ``KeyError`` listing what is calibratable.  On real
    hardware these run once at install time; in this CPU container we use
    the published constants and this remains the documented entry point.
    """
    known = calibration_field_names(base)
    measured = {}
    for field_name, bench in microbenchmarks.items():
        if field_name not in known:
            raise KeyError(
                f"not a calibratable field: {field_name!r}; "
                f"known: {sorted(known)}")
        measured[field_name] = bench()
    return base.with_calibration(**measured)
