"""Three-term roofline analysis for compiled dry-run artifacts.

    compute term    = HLO_FLOPs       / (chips x peak FLOP/s)
    memory term     = HLO_bytes       / (chips x HBM bandwidth)
    collective term = collective_bytes / (chips x ICI link bandwidth)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective bytes
are parsed out of the HLO text (``parse_collective_bytes``) because XLA's cost
model does not expose them.  Constants default to the mandated v5e numbers.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Mapping, Optional, Tuple

from repro.core.dtypes import HLO_DTYPE_BYTES
from repro.core.hardware import TPU_V5E, HardwareSpec

# HLO shapes look like  bf16[4096,512]{1,0:T(8,128)}  or tuples thereof.
# The short-name byte table is the shared one in core.dtypes.
_SHAPE_RE = re.compile(
    r"(" + "|".join(sorted(HLO_DTYPE_BYTES, key=len, reverse=True))
    + r")\[([0-9,]*)\]")
_DTYPE_BYTES = HLO_DTYPE_BYTES
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> float:
    """Sum byte sizes of every typed shape literal in `text`."""
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in a *partitioned* HLO
    module dump (per-device bytes, matching cost_analysis granularity).

    Instruction lines look like
        %all-reduce.1 = f32[256,1024]{1,0} all-reduce(%dot), ...
    — the shape sits between '=' and the op name (careful: the instruction
    *name* also contains the op string, so we anchor on ``= <shape> <op>(``).
    `-start` variants counted once, `-done` skipped.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in _COLLECTIVES:
            m = re.search(rf"=\s+(.*?)\s+{kind}(-start)?\(", s)
            if m:
                out[kind] += _shape_bytes(m.group(1))
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape_name: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float            # 6*N*D (dense) / 6*N_active*D (MoE)
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_flop_ratio: float      # MODEL_FLOPS / HLO_FLOPs
    roofline_s: float             # max of the three terms
    collectives: Mapping[str, float]
    # Per-level memory rooflines (topology refactor): HLO bytes pushed
    # through each memory level's port.  The outermost entry is the classic
    # memory term; inner entries bound how much a cache-resident schedule
    # could recover.  1-level chains report the HBM entry only.
    level_seconds: Mapping[str, float] = field(default_factory=dict)

    def as_dict(self) -> Dict:
        d = asdict(self)
        d["collectives"] = dict(self.collectives)
        d["level_seconds"] = dict(self.level_seconds)
        return d


def roofline(
    *,
    arch: str,
    shape_name: str,
    mesh: str,
    chips: int,
    hlo_flops: float,          # PER-DEVICE (cost_analysis of the
    hlo_bytes: float,          # partitioned module)
    collectives: Mapping[str, float],   # PER-DEVICE result bytes
    model_flops: float,        # GLOBAL 6·N·D — divided by chips here
    hw: HardwareSpec = TPU_V5E,
    dtype: str = "bfloat16",
) -> RooflineReport:
    """Three roofline terms on a per-chip basis.

    cost_analysis / the HLO dump describe ONE partition, so the terms are
      compute    = flops_dev / peak        (== HLO_FLOPs/(chips·peak) global)
      memory     = bytes_dev / HBM_bw
      collective = coll_bytes_dev / link_bw   (one ~50GB/s ICI link; ring
                   all-reduce wire bytes ≈ 2x result size — folded in)
    """
    compute_s = hlo_flops / hw.flops(dtype)
    memory_s = hlo_bytes / hw.hbm_bandwidth
    coll_bytes = float(collectives.get("total", 0.0))
    wire = (2.0 * float(collectives.get("all-reduce", 0.0))
            + sum(float(collectives.get(k, 0.0))
                  for k in _COLLECTIVES if k != "all-reduce"))
    collective_s = wire / hw.ici_bandwidth
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    model_flops_dev = model_flops / max(chips, 1)
    return RooflineReport(
        arch=arch, shape_name=shape_name, mesh=mesh, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes=coll_bytes, model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        useful_flop_ratio=(model_flops_dev / hlo_flops) if hlo_flops else 0.0,
        roofline_s=max(terms.values()),
        collectives=dict(collectives),
        level_seconds={lvl.name: hlo_bytes / lvl.bandwidth
                       for lvl in hw.levels[:-1]},
    )


def cost_analysis_terms(compiled) -> Tuple[float, float]:
    """Extract (flops, bytes accessed) from a compiled executable.

    ``cost_analysis()`` returns a dict (newer jax) or [dict]."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    # XLA reports "bytes accessed" plus per-space breakdowns.
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    return flops, bytes_accessed
