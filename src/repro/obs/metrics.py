"""Process-global metrics registry: counters / gauges / histograms
(DESIGN.md §11).

A :class:`MetricsRegistry` owns named instruments, optionally labeled
(``registry.counter("selections_total", labels={"source": "cold"})``), and
exports two ways: one JSONL record per :meth:`MetricsRegistry.jsonl_record`
call (append-friendly, the :class:`JsonlSink` convention
``runtime.metrics.MetricLogger`` shares) and the Prometheus textfile format
(:meth:`MetricsRegistry.to_prometheus`) a node-exporter textfile collector
scrapes verbatim.

Two usage modes:

* **Per-run registries** are plain objects — the serving engine builds one
  per ``run()`` so its public stats stay per-run, then
  :meth:`MetricsRegistry.merge`-publishes into the process-global registry.
* **Fire-and-forget instrumentation** uses the module helpers :func:`inc`,
  :func:`set_gauge`, :func:`observe` against the process-global
  :data:`REGISTRY`.  These are gated by :func:`enable_metrics` — off by
  default, one module-global bool check when disabled.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Mapping[str, str]]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


DEFAULT_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


class Histogram:
    __slots__ = ("name", "labels", "bounds", "counts", "sum", "count")

    def __init__(self, name: str, labels: LabelKey = (),
                 bounds: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)      # +inf bucket last
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.sum += v
        self.count += 1


class MetricsRegistry:
    """Named, optionally-labeled instruments with get-or-create semantics.
    A name is one type forever — re-registering with another type raises."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, LabelKey], Any] = {}
        self._types: Dict[str, type] = {}

    def _get(self, cls, name: str, labels: Optional[Mapping[str, str]],
             **kw):
        known = self._types.get(name)
        if known is not None and known is not cls:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{known.__name__}, requested {cls.__name__}")
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(name, key[1], **kw)
            self._types[name] = cls
        return m

    def counter(self, name: str,
                labels: Optional[Mapping[str, str]] = None) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str,
              labels: Optional[Mapping[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  labels: Optional[Mapping[str, str]] = None,
                  bounds: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def metrics(self) -> List[Any]:
        return [self._metrics[k] for k in sorted(self._metrics)]

    def clear(self) -> None:
        self._metrics.clear()
        self._types.clear()

    def merge(self, other: "MetricsRegistry") -> None:
        """Publish ``other`` into this registry: counters add, gauges take
        the other's (newer) value, histograms add bucket-wise."""
        for (name, lk), m in sorted(other._metrics.items()):
            if isinstance(m, Counter):
                self._get(Counter, name, dict(lk)).inc(m.value)
            elif isinstance(m, Gauge):
                self._get(Gauge, name, dict(lk)).set(m.value)
            else:
                h = self._get(Histogram, name, dict(lk), bounds=m.bounds)
                if h.bounds != m.bounds:
                    raise ValueError(
                        f"histogram {name!r} bucket bounds differ")
                for i, c in enumerate(m.counts):
                    h.counts[i] += c
                h.sum += m.sum
                h.count += m.count

    # -- exporters ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Flat name{labels} -> value dict (histograms: sum/count/buckets)."""
        out: Dict[str, Any] = {}
        for m in self.metrics():
            key = m.name + _label_str(m.labels)
            if isinstance(m, Histogram):
                out[key] = {"sum": m.sum, "count": m.count,
                            "buckets": dict(zip(
                                [*map(str, m.bounds), "+Inf"], m.counts))}
            else:
                out[key] = m.value
        return out

    def jsonl_record(self, **extra: Any) -> Dict[str, Any]:
        rec = dict(extra)
        rec["metrics"] = self.snapshot()
        return rec

    def write_jsonl(self, path: str, **extra: Any) -> None:
        with JsonlSink(path) as sink:
            sink.write(self.jsonl_record(**extra))

    def to_prometheus(self) -> str:
        lines: List[str] = []
        seen_type: Dict[str, str] = {}
        for m in self.metrics():
            if m.name not in seen_type:
                t = {Counter: "counter", Gauge: "gauge",
                     Histogram: "histogram"}[type(m)]
                seen_type[m.name] = t
                lines.append(f"# TYPE {m.name} {t}")
            ls = _label_str(m.labels)
            if isinstance(m, Histogram):
                acc = 0
                for b, c in zip([*self._fmt_bounds(m), "+Inf"], m.counts):
                    acc += c
                    lb = dict(m.labels)
                    lb["le"] = b
                    lines.append(
                        f"{m.name}_bucket{_label_str(_label_key(lb))} {acc}")
                lines.append(f"{m.name}_sum{ls} {m.sum}")
                lines.append(f"{m.name}_count{ls} {m.count}")
            else:
                lines.append(f"{m.name}{ls} {m.value}")
        return "\n".join(lines) + "\n"

    @staticmethod
    def _fmt_bounds(h: Histogram) -> List[str]:
        return [repr(b) for b in h.bounds]

    def write_prometheus(self, path: str) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.to_prometheus())
        os.replace(tmp, path)


class JsonlSink:
    """Append-mode JSONL writer: one ``json.dumps`` line per record,
    flushed per write (a watcher tails live), context-manager + ``__del__``
    closed.  The single file-writing primitive the metrics registry and the
    legacy ``runtime.metrics.MetricLogger`` shim share."""

    def __init__(self, path: str, mode: str = "a"):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, mode)
        self.path = path

    def write(self, record: Mapping[str, Any]) -> None:
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None and not self._f.closed:
            self._f.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):                                  # pragma: no cover
        try:
            self.close()
        except Exception:                               # noqa: BLE001
            pass


# ---------------------------------------------------------------------------
# Process-global registry + gated fire-and-forget helpers.
# ---------------------------------------------------------------------------

REGISTRY = MetricsRegistry()
_ENABLED = False


def get_registry() -> MetricsRegistry:
    return REGISTRY


def enable_metrics(on: bool = True) -> bool:
    """Switch the fire-and-forget helpers on/off; returns the previous
    state.  The registry object itself always works — this gates only the
    instrumentation call sites, so the disabled hot path costs one bool."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


def metrics_enabled() -> bool:
    return _ENABLED


def inc(name: str, n: int = 1,
        labels: Optional[Mapping[str, str]] = None) -> None:
    if _ENABLED:
        REGISTRY.counter(name, labels).inc(n)


def set_gauge(name: str, value: float,
              labels: Optional[Mapping[str, str]] = None) -> None:
    if _ENABLED:
        REGISTRY.gauge(name, labels).set(value)


def observe(name: str, value: float,
            labels: Optional[Mapping[str, str]] = None) -> None:
    if _ENABLED:
        REGISTRY.histogram(name, labels).observe(value)
