"""Model-vs-measured drift monitor (DESIGN.md §11).

Every executed GEMM (or serving step) whose latency the analytical model
priced can be checked against a measurement: ``DriftMonitor.record`` takes
``(site, shape, config, topology fingerprint, predicted_s, measured_s)``,
appends one JSONL record, and folds the pair into a *rolling fidelity
gauge*

    fidelity = mean over the window of  min(pred, meas) / max(pred, meas)

— 1.0 when the model nails every latency, dropping toward 0 as predictions
drift (an injected 40x outlier measurement visibly dents it; a non-finite
or non-positive sample scores 0.0 instead of poisoning the mean).  The
JSONL stream is exactly the ``(features, residual)`` dataset ROADMAP
item 5's learned-residual corrector trains on; the fingerprint column keys
each row to the topology constants the prediction used.

Drift records carry no wall-clock timestamp by default (``seq`` orders
them); callers that want one pass ``ts=...`` — keeping the default output
byte-deterministic under test.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Deque, Dict, Mapping, Optional

from repro.obs.metrics import MetricsRegistry, JsonlSink, get_registry

DRIFT_SCHEMA = "repro/drift/v1"


def fidelity_of(predicted_s: float, measured_s: float) -> float:
    """Symmetric accuracy ratio in [0, 1]: 1.0 iff predicted == measured."""
    if not (predicted_s > 0.0 and measured_s > 0.0):
        return 0.0
    if predicted_s != predicted_s or measured_s != measured_s:  # NaN
        return 0.0
    lo, hi = ((predicted_s, measured_s) if predicted_s <= measured_s
              else (measured_s, predicted_s))
    if hi == float("inf"):
        return 0.0
    return lo / hi


class DriftMonitor:
    """Rolling predicted-vs-measured fidelity + JSONL dataset writer.

    ``path`` (optional) appends one JSON line per record; ``registry``
    (default: the process-global) carries the ``drift_fidelity`` gauge and
    the ``drift_records_total`` counter.
    """

    def __init__(self, path: Optional[str] = None, window: int = 64,
                 registry: Optional[MetricsRegistry] = None):
        self._sink = JsonlSink(path) if path else None
        self._window: Deque[float] = deque(maxlen=max(int(window), 1))
        self._registry = registry if registry is not None else get_registry()
        self._seq = 0
        self.records_total = 0

    def record(self, *, site: str, shape, config: Optional[Mapping] = None,
               topo: str = "", predicted_s: float, measured_s: float,
               **extra: Any) -> float:
        """Fold one (predicted, measured) pair in; returns its fidelity.

        ``shape`` is an (M, N, K[, batch]) sequence or any JSON-serializable
        tag; ``config`` the executed TileConfig as a dict (or None for
        non-GEMM sites like whole serving steps); ``topo`` the topology
        fingerprint the prediction was priced against."""
        f = fidelity_of(predicted_s, measured_s)
        self._window.append(f)
        self._seq += 1
        self.records_total += 1
        rolling = self.fidelity()
        reg = self._registry
        reg.counter("drift_records_total").inc()
        reg.gauge("drift_fidelity").set(rolling)
        if self._sink is not None:
            self._sink.write({
                "schema": DRIFT_SCHEMA, "seq": self._seq, "site": site,
                "shape": list(shape) if not isinstance(shape, str)
                else shape,
                "config": dict(config) if config else None, "topo": topo,
                "predicted_s": predicted_s, "measured_s": measured_s,
                "fidelity": f, "rolling_fidelity": rolling, **extra})
        return f

    def record_selection(self, sel, measured_s: float, *,
                         site: str = "gemm", topo: str = "",
                         **extra: Any) -> float:
        """Record straight off a ``repro.core.selector.Selection`` (duck-
        typed — obs never imports core): the attached priced latency
        ``sel.predicted.total`` is the prediction, ``measured_s`` the
        device/simulator time for the SAME config.

        The ``topo`` column defaults to ``sel.topo_fingerprint`` — the
        content hash the selection was priced against — never the preset
        *name* (``sel.hardware``): a name can't be validated against the
        live topology, so name-keyed rows would silently poison the
        residual corrector's training set.  Selections predating the
        fingerprint field leave the column empty."""
        p, c = sel.problem, sel.config
        return self.record(
            site=site, shape=(p.M, p.N, p.K, p.batch),
            config={"bm": c.bm, "bn": c.bn, "bk": c.bk,
                    "split_k": c.split_k, "group_m": c.group_m,
                    "schedule": c.schedule},
            topo=topo or getattr(sel, "topo_fingerprint", "") or "",
            predicted_s=float(sel.predicted.total),
            measured_s=float(measured_s), **extra)

    def fidelity(self) -> float:
        """Rolling mean fidelity over the window (1.0 while empty — no
        evidence of drift)."""
        if not self._window:
            return 1.0
        return sum(self._window) / len(self._window)

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()

    def __enter__(self) -> "DriftMonitor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Module-global monitor: instrumented call sites' single switch (None = off).
# ---------------------------------------------------------------------------

_MONITOR: Optional[DriftMonitor] = None


def set_drift_monitor(mon: Optional[DriftMonitor]) -> Optional[DriftMonitor]:
    """Install (or with None remove) the process drift monitor; returns the
    previous one."""
    global _MONITOR
    prev = _MONITOR
    _MONITOR = mon
    return prev


def get_drift_monitor() -> Optional[DriftMonitor]:
    return _MONITOR


def record_step_drift(*, site: str, shape, predicted_s: float,
                      measured_s: float, topo: str = "",
                      config: Optional[Dict] = None, **extra: Any) -> None:
    """Fire-and-forget helper for instrumented call sites: no-op (one
    ``is None`` check) when no monitor is installed."""
    if _MONITOR is not None:
        _MONITOR.record(site=site, shape=shape, config=config, topo=topo,
                        predicted_s=predicted_s, measured_s=measured_s,
                        **extra)
