"""Unified telemetry subsystem (DESIGN.md §11).

Three dependency-free pillars, all off by default:

* :mod:`repro.obs.trace`   — structured spans/events with an injectable
  clock and deterministic sortable span ids; the Chrome/Perfetto exporter
  lives in :mod:`repro.obs.perfetto`.
* :mod:`repro.obs.metrics` — a process-global registry of counters /
  gauges / histograms with JSONL and Prometheus-textfile exporters.
* :mod:`repro.obs.drift`   — the model-vs-measured drift monitor: one
  JSONL record per executed GEMM/step plus a rolling fidelity gauge —
  the dataset the future learned-residual corrector consumes
  (ROADMAP item 5).

Import rule: ``repro.obs`` imports nothing from ``repro.core`` /
``repro.launch`` — instrumented call sites import *us*, never the other
way around, so there are no cycles and the disabled path costs one
module-global ``is None`` / ``bool`` check.
"""
from repro.obs.drift import (DriftMonitor, get_drift_monitor,
                             set_drift_monitor)
from repro.obs.metrics import (JsonlSink, MetricsRegistry, get_registry,
                               metrics_enabled)
from repro.obs.trace import Tracer, get_tracer, set_tracer, tracing_enabled

__all__ = [
    "DriftMonitor", "get_drift_monitor", "set_drift_monitor",
    "JsonlSink", "MetricsRegistry", "get_registry", "metrics_enabled",
    "Tracer", "get_tracer", "set_tracer", "tracing_enabled",
]
