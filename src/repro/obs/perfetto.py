"""Chrome / Perfetto ``trace.json`` exporter (DESIGN.md §11).

Converts :class:`repro.obs.trace.Span` lists — and the event simulator's
``(track, name, t0, t1, args)`` timeline tuples — into the Chrome Trace
Event JSON that ``chrome://tracing`` and https://ui.perfetto.dev load
directly:

* duration spans   → ``"ph": "X"`` complete events (``ts``/``dur`` in µs),
* instants         → ``"ph": "i"`` (thread-scoped),
* counter samples  → ``"ph": "C"``,
* every distinct (pid, track) pair gets a ``thread_name`` metadata event so
  Perfetto labels the rows (``selection``, ``engine``, ``core3``, ``dma``…).

Measured (tracer) and modeled (simulator) timelines export into one file
under different pids, so both schedules are inspectable side by side in
the same UI.  Pure functions over plain data — this module imports nothing
from ``repro.core``; simulator timelines arrive as the ``events`` list
``repro.core.simulator.simulate_gemm`` fills in.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import Span, sorted_spans

MEASURED_PID = 1          # tracer spans (wall-clock measured)
MODELED_PID = 2           # simulator timelines (model-priced schedule)

_US = 1e6                 # seconds -> Chrome trace microseconds


def _track_tids(tracks: Sequence[Tuple[int, str]]) -> Dict[Tuple[int, str],
                                                           int]:
    """Stable tid per (pid, track): first-seen order, counting from 1."""
    tids: Dict[Tuple[int, str], int] = {}
    for key in tracks:
        if key not in tids:
            tids[key] = len(tids) + 1
    return tids


def _meta_events(tids: Dict[Tuple[int, str], int],
                 pid_names: Dict[int, str]) -> List[Dict[str, Any]]:
    evs: List[Dict[str, Any]] = []
    for pid, name in sorted(pid_names.items()):
        evs.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": name}})
    for (pid, track), tid in sorted(tids.items(), key=lambda kv: kv[1]):
        evs.append({"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                    "args": {"name": track}})
    return evs


def chrome_trace_events(spans: Sequence[Span],
                        pid: int = MEASURED_PID) -> List[Dict[str, Any]]:
    """Tracer spans -> Chrome trace events (no metadata; see
    :func:`export_chrome_trace` for a complete file)."""
    spans = sorted_spans(spans)
    tids = _track_tids([(pid, s.track) for s in spans])
    out: List[Dict[str, Any]] = []
    for s in spans:
        tid = tids[(pid, s.track)]
        base = {"name": s.name, "cat": s.cat or "repro", "pid": pid,
                "tid": tid, "ts": s.start * _US}
        kind = s.kind
        if kind == "counter":
            base.update(ph="C", args=s.args or {"value": 0})
        elif kind == "span":
            end = s.end if s.end is not None else s.start
            base.update(ph="X", dur=(end - s.start) * _US,
                        args=s.args or {})
        else:
            base.update(ph="i", s="t", args=s.args or {})
        out.append(base)
    return out


def simulator_trace_events(events: Sequence[Tuple],
                           pid: int = MODELED_PID,
                           label: str = "") -> List[Dict[str, Any]]:
    """Simulator timeline tuples ``(track, name, t0, t1, args)`` (the
    ``events`` list ``simulate_gemm`` fills) -> Chrome "X" events, one
    Perfetto row per core / DMA engine.  ``label`` prefixes event names so
    several GEMMs can share the modeled pid without colliding."""
    tids = _track_tids([(pid, tr) for (tr, *_rest) in events])
    out: List[Dict[str, Any]] = []
    for (track, name, t0, t1, args) in events:
        out.append({"name": f"{label}{name}" if label else name,
                    "cat": "simulator", "ph": "X", "pid": pid,
                    "tid": tids[(pid, track)], "ts": t0 * _US,
                    "dur": (t1 - t0) * _US, "args": args or {}})
    return out


def export_chrome_trace(path: str, spans: Sequence[Span] = (),
                        sim_timelines: Optional[Sequence[
                            Tuple[str, Sequence[Tuple]]]] = None,
                        indent: Optional[int] = None) -> Dict[str, Any]:
    """Write a complete Perfetto-loadable ``trace.json``: measured tracer
    spans under pid 1, each ``(label, events)`` simulator timeline under
    pid 2, plus process/thread-name metadata.  Returns the document."""
    spans = sorted_spans(spans)
    tracks: List[Tuple[int, str]] = [(MEASURED_PID, s.track) for s in spans]
    sim_timelines = list(sim_timelines or [])
    for _label, evs in sim_timelines:
        tracks.extend((MODELED_PID, tr) for (tr, *_rest) in evs)
    tids = _track_tids(tracks)

    pid_names = {}
    if spans:
        pid_names[MEASURED_PID] = "measured (tracer)"
    if sim_timelines:
        pid_names[MODELED_PID] = "modeled (simulator)"
    trace_events = _meta_events(tids, pid_names)

    for s in spans:
        tid = tids[(MEASURED_PID, s.track)]
        base = {"name": s.name, "cat": s.cat or "repro", "pid": MEASURED_PID,
                "tid": tid, "ts": s.start * _US}
        kind = s.kind
        if kind == "counter":
            base.update(ph="C", args=s.args or {"value": 0})
        elif kind == "span":
            end = s.end if s.end is not None else s.start
            base.update(ph="X", dur=(end - s.start) * _US, args=s.args or {})
        else:
            base.update(ph="i", s="t", args=s.args or {})
        trace_events.append(base)

    for label, evs in sim_timelines:
        prefix = f"{label}: " if label else ""
        for (track, name, t0, t1, args) in evs:
            trace_events.append(
                {"name": prefix + name, "cat": "simulator", "ph": "X",
                 "pid": MODELED_PID, "tid": tids[(MODELED_PID, track)],
                 "ts": t0 * _US, "dur": (t1 - t0) * _US, "args": args or {}})

    doc = {"traceEvents": trace_events, "displayTimeUnit": "ms",
           "otherData": {"schema": "repro/perfetto/v1"}}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=indent, sort_keys=True)
    return doc
