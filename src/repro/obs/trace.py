"""Structured tracing: spans/events with an injectable clock (DESIGN.md §11).

A :class:`Tracer` records :class:`Span` objects — durations (``kind="span"``),
instants (``kind="event"``) and counter samples (``kind="counter"``) — each
on a named *track* (one Perfetto row: ``selection``, ``engine``, ``core3``,
``dma`` ...).  Span ids are a monotone counter, so ids sort in emission
order; the clock is injectable, so a test with a fixed fake clock gets a
byte-deterministic trace.  ``Tracer.to_json``/``from_json`` round-trip the
full schema; the Chrome/Perfetto ``trace.json`` exporter is
:mod:`repro.obs.perfetto`.

Off by default: the module-global tracer is ``None`` until
:func:`set_tracer` installs one.  The instrumentation helpers (:func:`span`,
:func:`event`, :func:`counter`) cost one global load + ``is None`` check and
allocate NOTHING on the disabled path — :func:`span` returns a module
singleton no-op context manager, and ``Span.allocated`` (a class-level
counter) lets tests pin the zero-allocation claim.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional, Sequence


class Span:
    """One trace record.  ``kind`` in {"span", "event", "counter"}; ``end``
    is None until the span closes (instants/counters keep it == start)."""

    __slots__ = ("sid", "name", "cat", "track", "start", "end", "args")
    allocated = 0              # class-level: total Span objects ever built

    def __init__(self, sid: int, name: str, cat: str, track: str,
                 start: float, end: Optional[float],
                 args: Optional[Dict[str, Any]]):
        Span.allocated += 1
        self.sid = sid
        self.name = name
        self.cat = cat
        self.track = track
        self.start = start
        self.end = end
        self.args = args

    @property
    def kind(self) -> str:
        if self.cat.startswith("counter"):
            return "counter"
        return "span" if self.end is not None and self.end != self.start \
            else "event"

    def to_dict(self) -> Dict[str, Any]:
        return {"sid": self.sid, "name": self.name, "cat": self.cat,
                "track": self.track, "start": self.start, "end": self.end,
                "args": self.args}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Span":
        return cls(int(d["sid"]), d["name"], d["cat"], d["track"],
                   float(d["start"]),
                   None if d["end"] is None else float(d["end"]),
                   d.get("args"))

    def __eq__(self, other) -> bool:
        return (isinstance(other, Span)
                and self.to_dict() == other.to_dict())

    def __repr__(self) -> str:
        return (f"Span(sid={self.sid}, name={self.name!r}, "
                f"track={self.track!r}, start={self.start}, end={self.end})")


class _OpenSpan:
    """Context manager closing one span on exit (reused per ``Tracer.span``
    call; only allocated when tracing is ON)."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, *exc) -> None:
        self._span.end = self._tracer.now()


class _NullSpan:
    """The disabled path's context manager: a module singleton, allocates
    nothing, yields None."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Collects spans.  ``clock`` is injectable (defaults to a zero-based
    ``time.perf_counter``) so tests can pin timestamps; span ids count up
    from 0 in emission order."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        if clock is None:
            t0 = time.perf_counter()
            clock = lambda: time.perf_counter() - t0        # noqa: E731
        self._clock = clock
        self._next = 0
        self.spans: List[Span] = []

    def now(self) -> float:
        return self._clock()

    def _emit(self, name: str, cat: str, track: str, start: float,
              end: Optional[float], args: Optional[Dict]) -> Span:
        s = Span(self._next, name, cat, track, start, end, args)
        self._next += 1
        self.spans.append(s)
        return s

    def span(self, name: str, cat: str = "", track: str = "main",
             args: Optional[Dict] = None) -> _OpenSpan:
        """Open a duration span; closes (stamps ``end``) on ``__exit__``."""
        return _OpenSpan(self, self._emit(name, cat, track, self.now(),
                                          None, args))

    def complete(self, name: str, cat: str, track: str, start: float,
                 end: float, args: Optional[Dict] = None) -> Span:
        """Record an already-timed span (the simulator-timeline path)."""
        return self._emit(name, cat, track, start, end, args)

    def event(self, name: str, cat: str = "", track: str = "main",
              args: Optional[Dict] = None) -> Span:
        t = self.now()
        return self._emit(name, cat, track, t, t, args)

    def counter(self, name: str, value: float,
                track: str = "counters") -> Span:
        t = self.now()
        return self._emit(name, "counter", track, t, t, {"value": value})

    # -- serialization ------------------------------------------------------

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps({"schema": "repro/trace/v1",
                           "spans": [s.to_dict() for s in self.spans]},
                          indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> List[Span]:
        d = json.loads(text)
        if d.get("schema") != "repro/trace/v1":
            raise ValueError(f"not a repro trace: schema={d.get('schema')!r}")
        return [Span.from_dict(sd) for sd in d["spans"]]


# ---------------------------------------------------------------------------
# Module-global tracer: the instrumented call sites' single switch.
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or with None remove) the process tracer; returns the
    previous one so tests/benchmarks can restore it."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def tracing_enabled() -> bool:
    return _TRACER is not None


def span(name: str, cat: str = "", track: str = "main",
         args: Optional[Dict] = None):
    """Context manager: a real span when tracing is on, the shared no-op
    singleton (zero allocations) when off."""
    if _TRACER is None:
        return NULL_SPAN
    return _TRACER.span(name, cat, track, args)


def event(name: str, cat: str = "", track: str = "main",
          args: Optional[Dict] = None) -> None:
    if _TRACER is not None:
        _TRACER.event(name, cat, track, args)


def counter(name: str, value: float, track: str = "counters") -> None:
    if _TRACER is not None:
        _TRACER.counter(name, value, track)


def sorted_spans(spans: Sequence[Span]) -> List[Span]:
    """Spans in deterministic order: by (start, sid) — sid breaks every tie
    because ids are emission-ordered."""
    return sorted(spans, key=lambda s: (s.start, s.sid))
