"""Fault-tolerant checkpointing: atomic, integrity-hashed, elastic.

* Atomic: state is written to ``<dir>/step_N.tmp`` and ``os.replace``d into
  place — a crash mid-write never corrupts the latest checkpoint.
* Hashed: a manifest records sha256 per array; restore verifies.
* Elastic: ``restore`` re-shards onto whatever mesh/sharding the restoring
  job provides (different chip count than the writer is fine) — the
  checkpoint stores fully-replicated logical arrays.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

# npz cannot serialize ml_dtypes (bfloat16, fp8): store raw-bit views and
# reconstruct from the manifest's true dtype on restore.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _savable(a: np.ndarray) -> np.ndarray:
    alt = _BITCAST.get(str(a.dtype))
    return a.view(alt) if alt is not None else a


def _unsavable(a: np.ndarray, true_dtype: str) -> np.ndarray:
    if str(a.dtype) != true_dtype and true_dtype in _BITCAST:
        import ml_dtypes
        return a.view(np.dtype(getattr(ml_dtypes, true_dtype)))
    return a


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def save(ckpt_dir: str, step: int, tree: Any,
         extra_meta: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: _savable(v) for k, v in flat.items()})
    manifest = {
        "step": step,
        "hashes": {k: _sha(v) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "meta": extra_meta or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True
            ) -> Tuple[int, Any]:
    """Restore into the structure of ``template`` (arrays or SDS tree).

    ``shardings``: optional matching tree of NamedShardings — enables
    elastic restore onto a different mesh than the writer used."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path_keys, leaf), sh in zip(paths, shard_leaves):
        key = "/".join(_key_str(k) for k in path_keys)
        a = _unsavable(arrays[key], manifest["dtypes"].get(key, ""))
        if verify and manifest["hashes"].get(key) != _sha(a):
            raise IOError(f"checkpoint corruption detected at {key}")
        want_dtype = leaf.dtype
        arr = jnp.asarray(a, dtype=want_dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        out.append(arr)
    return step, jax.tree_util.tree_unflatten(treedef, out)
